"""Markdown link checker for the repo's docs — no external deps.

    python scripts/check_markdown_links.py [FILE_OR_DIR ...]

Defaults to ``README.md`` and ``docs/`` at the repo root. For every
markdown file it validates:

- **relative links** (``[x](docs/ARCHITECTURE.md)``): the target file
  or directory must exist, resolved against the linking file's
  directory;
- **anchors** (``[x](BENCHMARKS.md#the-regression-gate)`` or
  ``[x](#local)``): the target file must contain a heading whose
  GitHub-style slug matches the fragment.

External links (``http(s)://``, ``mailto:``) are **not** fetched — CI
must not depend on network reachability — but a relative link into a
path that does not exist, or to a heading that was renamed, fails the
run. Image links (``![...](...)``) follow the same rules. Exits
non-zero listing every broken link.
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — also matches images via the preceding "!", which
# need the same existence check. Nested parens are not used in our docs.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = _CODE_FENCE.sub("", f.read())
    slugs: dict = {}
    out = set()
    for m in _HEADING.finditer(text):
        s = _slug(m.group(1))
        n = slugs.get(s, 0)
        slugs[s] = n + 1
        out.add(s if n == 0 else f"{s}-{n}")  # duplicate headings: -1, -2…
    return out


def check_file(md_path: str) -> list:
    """Returns a list of 'file: link — reason' problem strings."""
    with open(md_path, encoding="utf-8") as f:
        text = _CODE_FENCE.sub("", f.read())
    base = os.path.dirname(os.path.abspath(md_path))
    problems = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        # ../../actions/... style badge links leave the repo; GitHub
        # serves them regardless of checkout layout, so skip them
        if path.startswith("../.."):
            continue
        full = os.path.normpath(os.path.join(base, path)) if path else md_path
        if not os.path.exists(full):
            problems.append(f"{md_path}: {target} — missing file {full}")
            continue
        if frag:
            if not full.endswith(".md"):
                continue  # anchors into non-markdown: browser's problem
            if frag not in _anchors(full):
                problems.append(f"{md_path}: {target} — no heading for "
                                f"#{frag} in {full}")
    return problems


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or ["README.md",
                                                            "docs"]
    files = []
    for a in args:
        if os.path.isdir(a):
            files.extend(os.path.join(a, f) for f in sorted(os.listdir(a))
                         if f.endswith(".md"))
        else:
            files.append(a)
    problems = []
    for f in files:
        problems.extend(check_file(f))
    for p in problems:
        print(f"BROKEN {p}")
    print(f"checked {len(files)} files: "
          f"{'FAIL' if problems else 'ok'} ({len(problems)} broken)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
