#!/usr/bin/env python
"""Validate a Chrome trace-event JSON export (``Tracer.export_chrome``).

    python scripts/check_trace.py TRACE.json [--expect NAME_PREFIX]...

Checks the structural invariants Perfetto/chrome://tracing rely on and
the ones our exporter promises:

* the document parses, has ``traceEvents``, and ``otherData.open_spans``
  is 0 (every span was closed before export);
* every event is a known phase (``X`` complete, ``i`` instant, ``M``
  metadata) with numeric ``ts`` (µs) and, for ``X``, numeric ``dur >= 0``;
* instants carry the ``s`` scope field;
* span ids (``args.id``) are unique and every ``args.parent`` resolves
  to a recorded span id;
* within each track (``(pid, tid)``), timestamps are monotonically
  non-decreasing in document order — the sort the exporter guarantees;
* every ``(pid, tid)`` with events has a ``thread_name`` metadata record
  and every ``pid`` a ``process_name``;
* each ``--expect PREFIX`` (repeatable) must match at least one event
  name or track name — the CI smoke gate asserts the TeraSort export
  actually contains worker tracks, host-sync markers and bus events.

Exit code 0 when every check passes; 1 with a line per violation.
"""
from __future__ import annotations

import argparse
import json
import sys

PHASES = {"X", "i", "M"}


def check(doc: dict, expect: list) -> list:
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    open_spans = doc.get("otherData", {}).get("open_spans")
    if open_spans != 0:
        errors.append(f"otherData.open_spans = {open_spans!r}, expected 0")

    ids = set()
    parents = []         # (event-name, parent-id) to resolve after the scan
    last_ts = {}         # (pid, tid) -> last seen ts
    named_threads = set()
    named_procs = set()
    track_names = set()
    used_tracks = set()
    used_pids = set()

    for i, ev in enumerate(events):
        where = f"event[{i}] {ev.get('name')!r}"
        ph = ev.get("ph")
        if ph not in PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_threads.add((ev.get("pid"), ev.get("tid")))
                track_names.add(ev.get("args", {}).get("name"))
            elif ev.get("name") == "process_name":
                named_procs.add(ev.get("pid"))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: non-numeric ts {ts!r}")
            continue
        key = (ev.get("pid"), ev.get("tid"))
        used_tracks.add(key)
        used_pids.add(ev.get("pid"))
        if ts < last_ts.get(key, float("-inf")):
            errors.append(f"{where}: ts {ts} < previous {last_ts[key]} "
                          f"on track {key} (non-monotonic)")
        last_ts[key] = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: bad dur {dur!r}")
        else:  # instant
            if ev.get("s") not in ("t", "p", "g"):
                errors.append(f"{where}: instant missing scope 's'")
        sid = ev.get("args", {}).get("id")
        if sid is not None:
            if sid in ids:
                errors.append(f"{where}: duplicate span id {sid}")
            ids.add(sid)
        parent = ev.get("args", {}).get("parent")
        if parent is not None:
            parents.append((where, parent))

    for where, parent in parents:
        if parent not in ids:
            errors.append(f"{where}: parent {parent} does not resolve "
                          f"to a recorded span id")
    for key in used_tracks:
        if key not in named_threads:
            errors.append(f"track {key}: events but no thread_name metadata")
    for pid in used_pids:
        if pid not in named_procs:
            errors.append(f"pid {pid}: events but no process_name metadata")

    names = {ev.get("name", "") for ev in events} | \
        {n for n in track_names if n}
    for prefix in expect:
        if not any(n.startswith(prefix) for n in names):
            errors.append(f"--expect {prefix!r}: no event or track name "
                          f"starts with it")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--expect", action="append", default=[],
                    help="require an event/track name with this prefix "
                         "(repeatable)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    errors = check(doc, args.expect)
    for e in errors:
        print(f"FAIL {e}")
    n = len(doc.get("traceEvents", []))
    if errors:
        print(f"\n{args.trace}: {len(errors)} violation(s) in {n} events")
        return 1
    print(f"{args.trace}: ok ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
