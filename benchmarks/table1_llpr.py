"""Table 1 — wide-area transfer performance (LLPR) per testbed route.

Paper values: 360-615 Mb/s per route, LLPR 0.61-0.98 with UDT. We reproduce
the table from the transport model and add the TCP columns the paper argues
against (the reason Sector exists).
"""
from __future__ import annotations

from repro.sector.topology import TERAFLOW_TESTBED
from repro.sector.transport import llpr, tcp_throughput, udt_throughput

PAPER = {
    ("greenbelt", "daejeon"): (360, 0.78),
    ("chicago", "pasadena"): (550, 0.83),
    ("chicago", "greenbelt"): (615, 0.98),
    ("chicago", "tokyo"): (490, 0.61),
    ("tokyo", "pasadena"): (550, 0.83),
    ("tokyo", "chicago"): (460, 0.67),
}

NBYTES = 10 * 1024**3


def run() -> list:
    rows = []
    lan = TERAFLOW_TESTBED.local
    for (a, b), (p_mbps, p_llpr) in PAPER.items():
        wan = TERAFLOW_TESTBED.link(a, b)
        udt_mbps = udt_throughput(wan) / 1e6
        tcp_mbps = tcp_throughput(wan) / 1e6
        rows.append({
            "route": f"{a}->{b}",
            "udt_mbps": round(udt_mbps),
            "llpr_udt": round(llpr(NBYTES, wan, lan, "udt"), 2),
            "llpr_tcp": round(llpr(NBYTES, wan, lan, "tcp"), 3),
            "tcp_mbps": round(tcp_mbps, 1),
            "paper_mbps": p_mbps,
            "paper_llpr": p_llpr,
        })
    return rows


def main(smoke: bool = False, out_dir: str = ".") -> list:
    rows = run()  # analytic — already tiny, same scale in smoke mode
    print("route,udt_mbps,llpr_udt,paper_mbps,paper_llpr,tcp_mbps,llpr_tcp")
    for r in rows:
        print(f"{r['route']},{r['udt_mbps']},{r['llpr_udt']},"
              f"{r['paper_mbps']},{r['paper_llpr']},{r['tcp_mbps']},"
              f"{r['llpr_tcp']}")
    return rows


if __name__ == "__main__":
    import sys

    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("table1_llpr", main(smoke=smoke), smoke=smoke)
