"""Benchmark aggregator: one section per paper table + the roofline table.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time
import traceback


def _section(name: str, fn) -> None:
    print(f"\n== {name} " + "=" * max(1, 60 - len(name)))
    t0 = time.time()
    try:
        fn()
    except Exception as e:  # keep the harness running
        print(f"ERROR,{type(e).__name__}: {e}")
        traceback.print_exc()
    print(f"-- {name} done in {time.time() - t0:.1f}s")


def main() -> None:
    from benchmarks import table1_llpr, table2_kmeans, table3_terasort
    from benchmarks import roofline

    _section("Table 1: LLPR (UDT vs TCP over the Teraflow testbed)",
             table1_llpr.main)
    _section("Table 2: Sphere k-means scaling", table2_kmeans.main)
    _section("Table 3: TeraSort — Sphere vs Hadoop-style barrier",
             table3_terasort.main)
    _section("Roofline (from multi-pod dry-run artifacts)", roofline.main)


if __name__ == "__main__":
    main()
