"""Benchmark aggregator: one section per paper table + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [--out-dir DIR]

Each section's structured result is written to ``BENCH_<section>.json`` in
``--out-dir`` (default: current directory). ``--smoke`` runs every table at
tiny scale — the CI smoke job uses it to prove the benchmarks execute
end-to-end and to upload the JSON artifacts; any section that raises makes
the process exit non-zero.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks.bench_out import write_bench


def _section(name: str, fn, *, smoke: bool, out_dir: str) -> bool:
    print(f"\n== {name} " + "=" * max(1, 60 - len(name)))
    t0 = time.time()
    ok = True
    try:
        result = fn(smoke=smoke, out_dir=out_dir)
    except Exception as e:  # keep the harness running, fail at exit
        print(f"ERROR,{type(e).__name__}: {e}")
        traceback.print_exc()
        result = {"error": f"{type(e).__name__}: {e}"}
        ok = False
    path = write_bench(name, result, smoke=smoke, ok=ok, out_dir=out_dir)
    print(f"-- {name} done in {time.time() - t0:.1f}s -> {path}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-scale run of every table (CI smoke job)")
    ap.add_argument("--out-dir", default=".",
                    help="where to write BENCH_*.json (default: cwd)")
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    from benchmarks import (roofline, stream_window, table1_llpr,
                            table2_kmeans, table3_terasort, wan_scenario)

    sections = [
        ("table1_llpr", table1_llpr.main),
        ("table2_kmeans", table2_kmeans.main),
        ("table3_terasort", table3_terasort.main),
        ("stream_window", stream_window.main),
        ("wan", wan_scenario.main),
        ("roofline", roofline.main),
    ]
    failed = [name for name, fn in sections
              if not _section(name, fn, smoke=args.smoke,
                              out_dir=args.out_dir)]
    if failed:
        print(f"\nFAILED sections: {', '.join(failed)}")
        return 1
    print(f"\nall {len(sections)} sections ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
