"""WAN scenario benchmark — contention-aware vs contention-blind planning
on the 4-site Open Cloud Testbed (arXiv:0907.4810).

The scenario the paper's premise lives or dies on: a dataset lands at ONE
site (the ingest rack at Baltimore), compute capacity sits at three
others (StarLight, UIC, Calit2), and the planner must decide how much
work to ship over the shared 10 Gbps waves.  Two planning policies see
identical tasks, workers, and per-transfer costs:

* **blind** — the pre-contention model: every cross-site fetch is priced
  alone on a private link, so six remote workers look like six parallel
  pipes and the planner over-subscribes the three real site-pair waves;
* **aware** — per-link capacity accounting
  (:class:`repro.sector.topology.LinkSchedule`): fetches sharing a wave
  queue on it, and the candidate score of the Nth transfer on a link
  already includes the wait behind the first N-1.

Both plans are then *priced under the same contention-aware model*
(:meth:`SpherePlanner.price_plan`) — the honest comparison: what each
assignment would really take with transfers queued on shared waves.
``wan.contention_aware_speedup`` (blind's true cost / aware's true cost,
> 1 on the bottlenecked layout) is the CI-gated headline;
``wan.uncontended_parity`` pins the control: with replicas at every
site, neither planner moves a byte and the two plans price identically.

Also reported (informational): the optimistic makespan blind *believed*,
a no-offload locality-only baseline, an end-to-end engine run whose
cross-site shuffle shows up in ``SphereReport.link_wait_seconds``, and
the per-site replica shares of LLPR-weighted placement from the ingest
site.
"""
from __future__ import annotations

import sys
import tempfile
from typing import Dict, List, Tuple

from repro.core import SphereEngine
from repro.core.job import SphereJob, SphereStage
from repro.core.planner import SpherePlanner, StagePlan, TaskSpec
from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.sector.topology import OPEN_CLOUD_TESTBED

SITES = list(OPEN_CLOUD_TESTBED.sites)  # baltimore, starlight, uic, calit2
INGEST = "baltimore"

FULL = dict(chunks=96, chunk_kb=2048)
SMOKE = dict(chunks=48, chunk_kb=1024)

# huge speculate_factor: speculation would re-place stragglers mid-
# comparison and blur which *placement policy* caused the makespan
NO_SPECULATION = 1e9


def _cloud(chunk_kb: int, *, ingest_only: bool, llpr: bool = False
           ) -> Tuple[SectorMaster, SectorClient]:
    tmp = tempfile.mkdtemp(prefix="wan_")
    master = SectorMaster(topology=OPEN_CLOUD_TESTBED,
                          chunk_size=chunk_kb * 1024,
                          llpr_placement=llpr)
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", INGEST)
    if ingest_only:
        # the bottlenecked layout starts with ONLY the ingest rack: the
        # dataset lands wholly at Baltimore, remote racks join later
        master.register(ChunkServer(f"{INGEST}0", INGEST, tmp))
    else:
        for site in SITES:
            for k in range(2):
                master.register(ChunkServer(f"{site}{k}", site, tmp))
    return master, client


def _register_remote(master: SectorMaster) -> None:
    tmp = tempfile.mkdtemp(prefix="wan_r_")
    for site in SITES:
        if site == INGEST:
            continue
        for k in range(2):
            master.register(ChunkServer(f"{site}{k}", site, tmp))


def _upload(client: SectorClient, name: str, chunks: int,
            replication: int) -> None:
    csz = client.master.chunk_size
    client.upload(name, bytes(chunks * csz), replication=replication)


def _tasks(master: SectorMaster, client: SectorClient,
           name: str) -> List[TaskSpec]:
    return [TaskSpec(m.chunk_id, m.size,
                     tuple(s for s in m.locations
                           if s in master.servers and
                           master.servers[s].alive))
            for m in master.lookup(name, client.user)]


def _offloaded(plan: StagePlan) -> int:
    return sum(1 for t in plan.tasks if t.executor not in t.locs)


def _compare(engine: SphereEngine, tasks: List[TaskSpec],
             workers: List[str]) -> Dict[str, object]:
    """Plan with each policy, then price both under the aware model."""
    aware = SpherePlanner(move_time=engine._move_time,
                          link_of=engine._link_of, offload=True,
                          speculate_factor=NO_SPECULATION)
    blind = SpherePlanner(move_time=engine._move_time,
                          link_of=None, offload=True,
                          speculate_factor=NO_SPECULATION)
    local_only = SpherePlanner(move_time=engine._move_time,
                               link_of=engine._link_of, offload=False,
                               speculate_factor=NO_SPECULATION)
    p_aware = aware.plan_stage(tasks, workers)
    p_blind = blind.plan_stage(tasks, workers)
    p_local = local_only.plan_stage(tasks, workers)
    c_aware = aware.price_plan(p_aware, workers)
    c_blind = aware.price_plan(p_blind, workers)
    c_local = aware.price_plan(p_local, workers)
    return {
        # what blind BELIEVED vs what its plan really costs queued
        "blind_est_seconds": round(p_blind.seconds, 4),
        "blind_true_seconds": round(c_blind.seconds, 4),
        "aware_seconds": round(c_aware.seconds, 4),
        "local_only_seconds": round(c_local.seconds, 4),
        "blind_offloaded": _offloaded(p_blind),
        "aware_offloaded": _offloaded(p_aware),
        "blind_link_wait_seconds": round(c_blind.link_wait, 4),
        "aware_link_wait_seconds": round(c_aware.link_wait, 4),
    }


def _engine_run(chunk_kb: int) -> Dict[str, object]:
    """End-to-end engine run on the bottlenecked layout: the identity
    job's cross-site shuffle rides the three Baltimore waves, so the
    aware engine's simulated seconds exceed the blind engine's optimistic
    report and the queueing shows up in ``link_wait_seconds``."""
    out: Dict[str, object] = {}
    for mode in ("aware", "blind"):
        master, client = _cloud(chunk_kb, ingest_only=True)
        # records carry a cycling key byte so the shuffle spreads
        # buckets across every worker (all-zero records would collapse
        # the shuffle into a single flow)
        n_recs = 8 * master.chunk_size // 1024
        data = b"".join(bytes([i % 251]) + b"\0" * 1023
                        for i in range(n_recs))
        client.upload("wanjob/data", data, replication=1)
        _register_remote(master)
        engine = SphereEngine(master, client,
                              contention_aware=(mode == "aware"))
        job = SphereJob(
            "wan_identity", "wanjob/data",
            [SphereStage("id", udf=lambda recs: list(recs),
                         partitioner=lambda rec, n: rec[0] % n)],
            record_size=1024, backend="bytes")
        _, rep = engine.run(job)
        out[f"{mode}_sim_seconds"] = round(rep.sim_seconds, 4)
        if mode == "aware":
            out["link_wait_seconds"] = round(rep.link_wait_seconds, 4)
    out["shuffle_overcommit"] = round(
        out["aware_sim_seconds"] / max(out["blind_sim_seconds"], 1e-9), 3)
    return out


def _llpr_shares(chunk_kb: int, chunks: int) -> Dict[str, object]:
    """Per-site replica shares under LLPR-weighted placement, writing
    from the ingest site with replication=1 (every chunk goes to the
    single highest-scoring site, so shares track effective bandwidth)."""
    master, client = _cloud(chunk_kb, ingest_only=False, llpr=True)
    _upload(client, "llpr/data", chunks, replication=1)
    counts = {site: 0 for site in SITES}
    for ck in master.chunks.values():
        for sid in ck.locations:
            counts[master.servers[sid].site] += 1
    total = max(sum(counts.values()), 1)
    return {
        "site_shares": {s: round(c / total, 3) for s, c in counts.items()},
        "effective_gbps": {
            s: round(OPEN_CLOUD_TESTBED.effective_bandwidth_bps(INGEST, s)
                     / 1e9, 3)
            for s in SITES},
    }


def run(chunks: int, chunk_kb: int) -> dict:
    # ---- bottlenecked layout: all data at the ingest rack --------------
    master, client = _cloud(chunk_kb, ingest_only=True)
    _upload(client, "wan/data", chunks, replication=1)
    _register_remote(master)
    engine = SphereEngine(master, client)
    bottlenecked = _compare(engine, _tasks(master, client, "wan/data"),
                            engine._workers())

    # ---- uncontended control: replicas already at every site -----------
    master_u, client_u = _cloud(chunk_kb, ingest_only=False)
    _upload(client_u, "wan/data", chunks, replication=3)
    engine_u = SphereEngine(master_u, client_u)
    uncontended = _compare(engine_u, _tasks(master_u, client_u, "wan/data"),
                           engine_u._workers())

    speedup = (bottlenecked["blind_true_seconds"]
               / max(bottlenecked["aware_seconds"], 1e-9))
    parity = (uncontended["blind_true_seconds"]
              / max(uncontended["aware_seconds"], 1e-9))
    return {
        "sites": SITES, "ingest_site": INGEST,
        "chunks": chunks, "chunk_kb": chunk_kb,
        "bottlenecked": bottlenecked,
        "uncontended": uncontended,
        "engine": _engine_run(chunk_kb=256),
        "placement": _llpr_shares(chunk_kb=256, chunks=64),
        "wan": {
            # CI-gated: how much of blind's true (queued) cost the aware
            # planner avoids on the bottlenecked layout
            "contention_aware_speedup": round(speedup, 3),
            # control: identical plans when nothing needs to move
            "uncontended_parity": round(parity, 4),
            # offloading with honest link pricing still beats staying home
            "offload_gain": round(
                bottlenecked["local_only_seconds"]
                / max(bottlenecked["aware_seconds"], 1e-9), 3),
        },
    }


def main(smoke: bool = False, out_dir: str = ".") -> dict:
    result = run(**(SMOKE if smoke else FULL))
    print("bottlenecked:", result["bottlenecked"])
    print("uncontended:", result["uncontended"])
    print("engine:", result["engine"])
    print("placement:", result["placement"])
    print("wan gate:", result["wan"])
    wan = result["wan"]
    assert wan["contention_aware_speedup"] > 1.0, \
        "aware planning must beat blind planning on the bottlenecked layout"
    assert 0.99 <= wan["uncontended_parity"] <= 1.01, \
        "with replicas everywhere the two policies must price identically"
    b = result["bottlenecked"]
    assert b["aware_seconds"] <= b["local_only_seconds"] * 1.01, \
        "honest offloading must never lose to staying local-only"
    assert b["blind_true_seconds"] > b["blind_est_seconds"], \
        "blind plan's true queued cost must exceed its private-link estimate"
    return result


if __name__ == "__main__":
    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("wan", main(smoke=smoke), smoke=smoke)
