"""Roofline analysis (§Roofline): three terms per (arch x shape x mesh).

Sources — chosen for measurement fidelity on a CPU-only harness:

  * compute term  : the ANALYTIC FLOPs model (benchmarks/analytic.py),
    validated against fully-unrolled ``cost_analysis`` measurements (XLA
    counts while-loop bodies once, so scanned-graph flops under-report by
    the trip count; unrolled graphs measure correctly but cost ~5-7 min of
    compile per train cell and distort peak memory).
  * memory term   : the analytic first-order HBM-traffic model (CPU-backend
    ``bytes accessed`` reflects unfused op granularity, not TPU HBM flows).
  * collective term: parsed from the compiled (scanned) HLO with while-body
    collectives multiplied by the layer-scan trip count — the layer scan is
    the only collective-bearing loop. Cross-pod bytes are charged at DCN
    bandwidth, intra-pod at ICI.
  * fits_hbm      : measured ``memory_analysis()`` of the scanned compile
    (buffer reuse realistic).

    compute_s    = flops_global / (chips * 197e12)
    memory_s     = bytes_global / (chips * 819e9)
    collective_s = intra_dev / 50e9 + cross_dev / 25e9

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params; the
useful-compute ratio MODEL_FLOPS / flops_global flags remat/dispatch/causal
waste, and roofline_fraction = ideal_time / dominant_term is the score.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from benchmarks.analytic import Knobs, cell_bytes, cell_flops
from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch


def knobs_from(rec: dict) -> Knobs:
    k = rec.get("knobs", {})
    return Knobs(
        attn_impl=k.get("attn_impl", "scan"),
        moe_dispatch=k.get("moe_dispatch", "einsum"),
        remat=k.get("remat", "full"),
        fused_head=bool(k.get("fused_head", False)),
        cache_write=k.get("cache_write", "masked"),
    )


def analyse_record(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    k = knobs_from(rec)

    flops_global = cell_flops(cfg, shape, k)["total"]
    bytes_global = cell_bytes(cfg, shape, k)
    coll = rec.get("collectives", {})
    intra_dev = coll.get("intra_pod", coll.get("total", 0))
    cross_dev = coll.get("cross_pod", 0)

    compute_s = flops_global / (chips * PEAK_FLOPS)
    memory_s = bytes_global / (chips * HBM_BW)
    collective_s = intra_dev / ICI_BW + cross_dev / DCN_BW

    mf = model_flops(rec["arch"], rec["shape"])
    bound = max(compute_s, memory_s, collective_s)
    ideal = mf / (chips * PEAK_FLOPS)
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    temp = rec.get("memory", {}).get("temp_bytes", 0)
    return {
        "cell": rec["cell"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(flops_global, 1.0),
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "temp_gb": temp / 1e9,
        "fits_hbm": temp < 16e9,
    }


def load_all(tag: str = "") -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.loads(open(p).read())
        is_tagged = len(rec["cell"].split("__")) > 3
        if bool(tag) != is_tagged:
            continue
        if tag and not rec["cell"].endswith("__" + tag):
            continue
        row = analyse_record(rec)
        if row:
            out.append(row)
    return out


def main(smoke: bool = False, out_dir: str = ".") -> list:
    rows = load_all()  # parses whatever dry-run artifacts exist — cheap
    print("cell,compute_s,memory_s,collective_s,dominant,useful_ratio,"
          "roofline_fraction,temp_gb,fits_hbm")
    for r in rows:
        print(f"{r['cell']},{r['compute_s']:.4e},{r['memory_s']:.4e},"
              f"{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
              f"{r['temp_gb']:.1f},{r['fits_hbm']}")
    return rows


if __name__ == "__main__":
    import sys

    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("roofline", main(smoke=smoke), smoke=smoke)
