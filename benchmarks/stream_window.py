"""Stream window benchmark — per-window wall clock of the delta-planned
streaming path vs a rebuild-per-window baseline (Angle's continuous
mining, arXiv:0808.3019).

Both paths fit the same warm-startable k-means over the same sliding
windows of Sector files:

* **stream** — one :class:`SphereStream` subscribed to the path prefix;
  windows fire from ``file-created`` events as files upload, each window
  plans only the delta chunks, surviving chunks stay decoded and
  device-resident, and the stage pair traces once for the whole stream
  (warm-started centroids ride as a dynamic jit argument);
* **rebuild** — for the identical window file sets, a cold pinned
  stream per window: fresh planner/executor (every chunk re-looked-up,
  re-planned, re-fetched, re-decoded) and fresh stages (re-traced).

The ``stream`` summary block feeds the CI regression gate: steady-state
per-window record throughput (abs) and the stream-vs-rebuild wall-clock
speedup (ratio) — the gate that keeps the new subsystem's delta planning
from silently falling off.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import SphereEngine, SphereStream, WindowPolicy
from repro.core.kmeans import StreamingKMeans, encode_points
from repro.sector import ChunkServer, SectorClient, SectorMaster

DIM, K = 4, 3
FULL = dict(files=16, win=4, n_per_file=50_000, iters=4)
SMOKE = dict(files=6, win=3, n_per_file=4_000, iters=3)


def _make_cloud():
    tmp = tempfile.mkdtemp(prefix="sw_")
    master = SectorMaster(chunk_size=256 * 1024)
    for i, site in enumerate(master.topology.sites):
        master.register(ChunkServer(f"s{i}", site, tmp))
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", "chicago")
    return master, client


def run(files: int, win: int, n_per_file: int, iters: int) -> dict:
    master, client = _make_cloud()
    engine = SphereEngine(master, client)
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(K, DIM)) * 4

    # ---- streaming path: windows fire from upload events --------------
    stream = engine.stream("w/", window=WindowPolicy.sliding(win),
                           record_size=4 * DIM, backend="array")
    skm = StreamingKMeans(stream, DIM, K, iters=iters)
    window_seconds: list = []
    window_sets: list = []

    def on_window(s, idx, wfiles):
        t0 = time.perf_counter()
        skm.fit_window()
        window_seconds.append(time.perf_counter() - t0)
        window_sets.append(wfiles)

    stream.on_window(on_window)
    for i in range(files):
        pts = np.concatenate(
            [rng.normal(c, 0.4, size=(n_per_file // K, DIM))
             for c in centers]).astype(np.float32)
        client.upload(f"w/{i:04d}", encode_points(pts), replication=2)

    # ---- rebuild baseline: cold everything per window -----------------
    rebuild_seconds = []
    for wfiles in window_sets:
        t0 = time.perf_counter()
        cold = SphereStream(engine, files=wfiles, record_size=4 * DIM,
                            backend="array")
        StreamingKMeans(cold, DIM, K, iters=iters).fit_window()
        cold.close()
        rebuild_seconds.append(time.perf_counter() - t0)

    per_window_records = win * (n_per_file // K) * K
    steady = window_seconds[1:] or window_seconds  # first pays the traces
    return {
        "files": files, "window": win, "records_per_window":
            per_window_records, "iters": iters,
        "window_seconds": [round(s, 4) for s in window_seconds],
        "rebuild_seconds": [round(s, 4) for s in rebuild_seconds],
        "stream": {
            # best steady-state window: min is far less noisy than mean
            # at smoke scale, which is what the CI gate needs
            "window_rec_per_s": int(per_window_records
                                    / max(min(steady), 1e-9)),
            # per-window wall clock vs the baseline: a steady stream
            # window pays only the delta (plan/fetch/decode one file, no
            # re-trace); a rebuild window pays everything, every window.
            # Window 0 is excluded from the stream side — its one-time
            # trace cost is exactly what every rebuild window repays.
            "speedup": round(min(rebuild_seconds)
                             / max(min(steady), 1e-9), 2),
            "total_speedup": round(sum(rebuild_seconds)
                                   / max(sum(window_seconds), 1e-9), 2),
            "udf_traces": dict(skm.report.udf_traces),
            "planned_tasks": skm.report.planned_tasks,
            "reused_tasks": skm.report.reused_tasks,
        },
    }


def main(smoke: bool = False, out_dir: str = ".") -> dict:
    result = run(**(SMOKE if smoke else FULL))
    print("window_seconds:", result["window_seconds"])
    print("rebuild_seconds:", result["rebuild_seconds"])
    print("stream gate:", result["stream"])
    assert result["stream"]["udf_traces"] == {"assign": 1, "fold": 1}, \
        "streaming stages must trace once across all windows"
    return result


if __name__ == "__main__":
    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("stream_window", main(smoke=smoke), smoke=smoke)
