"""Table 2 — Sphere k-means scaling with record count (paper §5.3).

The paper clusters 500 .. 1e8 points over distributed pcap-feature files;
time scales near-linearly in records. We run the same Sphere job at CPU-
feasible sizes, report simulated wall time (the engine's deterministic cost
model over the Teraflow topology) plus real UDF execution, and fit the
scaling exponent (paper: ~1 = linear).

Runs on both record backends: ``bytes`` loops per chunk in numpy, ``array``
packs points into RecordBatches and runs one jitted assign UDF per chunk
batch. Both must converge to the same centroids (same seed, same data).
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import SphereEngine
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.sector import ChunkServer, SectorClient, SectorMaster

SIZES = [500, 5_000, 50_000, 500_000]
SMOKE_SIZES = [500, 5_000]
DIM = 8
K = 10


def _make_cloud():
    tmp = tempfile.mkdtemp(prefix="t2_")
    master = SectorMaster(chunk_size=256 * 1024)
    for i, site in enumerate(master.topology.sites):
        master.register(ChunkServer(f"s{i}", site, tmp))
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", "chicago")
    return master, client


def run(sizes=SIZES) -> list:
    rows = []
    for n in sizes:
        pts = np.random.default_rng(0).normal(size=(n, DIM)) \
            .astype(np.float32)
        row = {"records": n}
        cents = {}
        for backend in ("bytes", "array"):
            master, client = _make_cloud()
            client.upload("pts", encode_points(pts), replication=2)
            eng = SphereEngine(master, client)
            t0 = time.time()
            c, rep = kmeans_sphere(eng, "pts", dim=DIM, k=K, iters=3,
                                   backend=backend)
            cents[backend] = c
            row.update({
                "sector_files": master.stats()["chunks"],
                f"{backend}_sim_seconds": round(rep.sim_seconds, 4),
                f"{backend}_real_seconds": round(time.time() - t0, 3),
                "locality": round(rep.locality_fraction, 3),
            })
        np.testing.assert_allclose(cents["bytes"], cents["array"],
                                   rtol=1e-3, atol=1e-3)
        row["udf_speedup"] = round(row["bytes_real_seconds"]
                                   / max(row["array_real_seconds"], 1e-9), 2)
        rows.append(row)
    # scaling exponent of real UDF compute between the two largest sizes
    # (paper Table 2 is linear-in-records: 1e6 -> 1e8 records is 60x time).
    # sim_seconds stays near-flat until records saturate the 6-site cluster
    # — that's the engine parallelising dispatch, an improvement over the
    # paper's ~1.8 s/file serial master (85 min / 2850 files).
    a, b = rows[-2], rows[-1]
    expo = (np.log(b["bytes_real_seconds"]
                   / max(a["bytes_real_seconds"], 1e-9))
            / np.log(b["records"] / a["records"]))
    for r in rows:
        r["scaling_exponent_tail"] = round(float(expo), 2)
    return rows


def main(smoke: bool = False) -> list:
    rows = run(SMOKE_SIZES if smoke else SIZES)
    cols = ["records", "sector_files", "bytes_sim_seconds",
            "bytes_real_seconds", "array_real_seconds", "udf_speedup",
            "locality", "scaling_exponent_tail"]
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
