"""Table 2 — Sphere k-means scaling with record count (paper §5.3).

The paper clusters 500 .. 1e8 points over distributed pcap-feature files;
time scales near-linearly in records. We run the same Sphere job chain at
CPU-feasible sizes, report simulated wall time (the engine's deterministic
cost model over the Teraflow topology) plus real UDF execution, and fit the
scaling exponent (paper: ~1 = linear).

Three paths per size, all converging to the same centroids (same seed,
same data):

* ``bytes`` — per-chunk numpy reference through a session;
* ``array`` rebuild — the pre-session baseline: every iteration re-plans
  (fresh lookup/planner/executor) and re-traces the stage UDFs;
* ``array`` session — one :class:`SphereSession` chains all iterations:
  one lookup, one stage-0 plan, chunks decoded once, mask-aware
  reduction UDFs traced once for the whole run (``udf_traces == 1``).

The ``kmeans`` summary block (largest size) feeds the CI regression
gate: steady-state per-iteration throughput and the session-vs-rebuild
speedup, plus the per-iteration wall clock lists in each row.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from repro.core import SphereEngine
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.sector import ChunkServer, SectorClient, SectorMaster

SIZES = [500, 5_000, 50_000, 500_000]
SMOKE_SIZES = [500, 5_000]
DIM = 8
K = 10
ITERS = 5  # >= 3: iteration 1 pays the traces, the rest are steady-state


def _make_cloud():
    tmp = tempfile.mkdtemp(prefix="t2_")
    master = SectorMaster(chunk_size=256 * 1024)
    for i, site in enumerate(master.topology.sites):
        master.register(ChunkServer(f"s{i}", site, tmp))
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", "chicago")
    return master, client


def _run_kmeans(pts, backend, session, iter_seconds=None):
    master, client = _make_cloud()
    client.upload("pts", encode_points(pts), replication=2)
    eng = SphereEngine(master, client)
    t0 = time.time()
    c, rep = kmeans_sphere(eng, "pts", dim=DIM, k=K, iters=ITERS,
                           backend=backend, session=session,
                           iter_seconds=iter_seconds)
    return c, rep, time.time() - t0, master


def run(sizes=SIZES) -> dict:
    rows = []
    for n in sizes:
        pts = np.random.default_rng(0).normal(size=(n, DIM)) \
            .astype(np.float32)
        row = {"records": n}

        c_bytes, rep_b, t_bytes, master = _run_kmeans(pts, "bytes", True)
        row.update({
            "sector_files": master.stats()["chunks"],
            "bytes_sim_seconds": round(rep_b.sim_seconds, 4),
            "bytes_real_seconds": round(t_bytes, 3),
            "locality": round(rep_b.locality_fraction, 3),
        })

        # pre-session baseline: re-plan + re-trace every iteration
        c_rebuild, _, t_rebuild, _ = _run_kmeans(pts, "array", False)
        # the session chain: one plan, one trace, device-resident chunks
        iter_s: list = []
        c_sess, rep_s, t_sess, _ = _run_kmeans(pts, "array", True, iter_s)
        steady = iter_s[1:] or iter_s  # drop the trace-paying first iter
        # best steady-state iteration: min is far less noisy than mean at
        # smoke scale (ms-long iterations, host-dispatch jitter), which
        # is what the CI regression gate needs
        row.update({
            "array_sim_seconds": round(rep_s.sim_seconds, 4),
            "array_rebuild_seconds": round(t_rebuild, 3),
            "array_real_seconds": round(t_sess, 3),
            "array_iter_seconds": [round(s, 4) for s in iter_s],
            "session_iter_rec_per_s": int(n / max(min(steady), 1e-9)),
            "session_speedup": round(t_rebuild / max(t_sess, 1e-9), 2),
            "udf_traces": dict(rep_s.udf_traces),
            "udf_speedup": round(t_bytes / max(t_sess, 1e-9), 2),
        })
        np.testing.assert_allclose(c_bytes, c_sess, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(c_rebuild, c_sess, rtol=1e-4, atol=1e-4)
        rows.append(row)

    # scaling exponent of real UDF compute between the two largest sizes
    # (paper Table 2 is linear-in-records: 1e6 -> 1e8 records is 60x time).
    # sim_seconds stays near-flat until records saturate the 6-site cluster
    # — that's the engine parallelising dispatch, an improvement over the
    # paper's ~1.8 s/file serial master (85 min / 2850 files).
    a, b = rows[-2], rows[-1]
    expo = (np.log(b["bytes_real_seconds"]
                   / max(a["bytes_real_seconds"], 1e-9))
            / np.log(b["records"] / a["records"]))
    for r in rows:
        r["scaling_exponent_tail"] = round(float(expo), 2)

    # regression-gate summary from the largest size: session iteration
    # throughput (abs) and session-vs-rebuild speedup (ratio)
    tail = rows[-1]
    return {
        "rows": rows,
        "kmeans": {
            "session_iter_rec_per_s": tail["session_iter_rec_per_s"],
            "session_speedup": tail["session_speedup"],
            "udf_traces": tail["udf_traces"],
        },
    }


def main(smoke: bool = False, out_dir: str = ".") -> dict:
    result = run(SMOKE_SIZES if smoke else SIZES)
    cols = ["records", "sector_files", "bytes_sim_seconds",
            "bytes_real_seconds", "array_rebuild_seconds",
            "array_real_seconds", "session_speedup",
            "session_iter_rec_per_s", "udf_speedup", "locality",
            "scaling_exponent_tail"]
    print(",".join(cols))
    for r in result["rows"]:
        print(",".join(str(r[c]) for c in cols))
    print(f'kmeans gate: {result["kmeans"]}')
    return result


if __name__ == "__main__":
    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("table2_kmeans", main(smoke=smoke), smoke=smoke)
