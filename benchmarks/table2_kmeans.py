"""Table 2 — Sphere k-means scaling with record count (paper §5.3).

The paper clusters 500 .. 1e8 points over distributed pcap-feature files;
time scales near-linearly in records. We run the same Sphere job at CPU-
feasible sizes, report simulated wall time (the engine's deterministic cost
model over the Teraflow topology) plus real UDF execution, and fit the
scaling exponent (paper: ~1 = linear).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import SphereEngine
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.sector import ChunkServer, SectorClient, SectorMaster

SIZES = [500, 5_000, 50_000, 500_000]
DIM = 8
K = 10


def run() -> list:
    rows = []
    for n in SIZES:
        tmp = tempfile.mkdtemp(prefix="t2_")
        master = SectorMaster(chunk_size=256 * 1024)
        for i, site in enumerate(master.topology.sites):
            master.register(ChunkServer(f"s{i}", site, tmp))
        master.acl.add_member("bench")
        master.acl.grant_write("bench")
        client = SectorClient(master, "bench", "chicago")
        pts = np.random.default_rng(0).normal(size=(n, DIM)) \
            .astype(np.float32)
        client.upload("pts", encode_points(pts), replication=2)
        eng = SphereEngine(master, client)
        t0 = time.time()
        _, rep = kmeans_sphere(eng, "pts", dim=DIM, k=K, iters=3)
        rows.append({
            "records": n,
            "sector_files": master.stats()["chunks"],
            "sim_seconds": round(rep.sim_seconds, 4),
            "real_seconds": round(time.time() - t0, 3),
            "locality": round(rep.locality_fraction, 3),
        })
    # scaling exponent of real UDF compute between the two largest sizes
    # (paper Table 2 is linear-in-records: 1e6 -> 1e8 records is 60x time).
    # sim_seconds stays near-flat until records saturate the 6-site cluster
    # — that's the engine parallelising dispatch, an improvement over the
    # paper's ~1.8 s/file serial master (85 min / 2850 files).
    a, b = rows[-2], rows[-1]
    expo = (np.log(b["real_seconds"] / max(a["real_seconds"], 1e-9))
            / np.log(b["records"] / a["records"]))
    for r in rows:
        r["scaling_exponent_tail"] = round(float(expo), 2)
    return rows


def main() -> None:
    rows = run()
    print("records,sector_files,sim_seconds,real_seconds,locality,"
          "scaling_exponent_tail")
    for r in rows:
        print(f"{r['records']},{r['sector_files']},{r['sim_seconds']},"
              f"{r['real_seconds']},{r['locality']},"
              f"{r['scaling_exponent_tail']}")


if __name__ == "__main__":
    main()
