"""Analytic FLOPs / HBM-bytes model per (arch x shape x knobs) cell.

Why analytic: XLA's ``cost_analysis`` counts a while-loop body ONCE, so any
scanned graph (layers, attention chunks, microbatches) under-reports FLOPs
by the trip count; fully unrolling for measurement costs ~5-7 min of compile
per train cell on this 1-core harness and distorts peak memory. Instead the
roofline table uses this exact closed-form model — validated against fully
unrolled ``cost_analysis`` measurements in EXPERIMENTS.md §Roofline
(agreement within ~15%) — plus the trip-corrected collective parse from the
compiled (scanned) HLO.

All counts are GLOBAL (whole step, all devices); the roofline divides by
chip count. A matmul [m,k]x[k,n] counts 2mkn FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import round_up
from repro.models.xlstm import slstm_ffn_width


@dataclass
class Knobs:
    attn_impl: str = "scan"        # scan/rect (full rectangle) | triangular
    moe_dispatch: str = "einsum"
    remat: str = "full"
    fused_head: bool = False
    cache_write: str = "masked"    # masked (3x cache traffic) | scatter (1x)
    q_chunk: int = 2048
    kv_chunk: int = 2048
    capacity_factor: float = 1.25
    moe_group: int = 4096


def _attn_pairs(T: int, S: int, qc: int, kc: int, *, causal: bool,
                window: int, impl: str) -> float:
    """Number of (q,k) position pairs the implementation actually computes."""
    qc = min(qc, T)
    kc = min(kc, S)
    if window and causal and window + qc < S:
        strip = min(round_up(window + qc, 128), S)
        return float(T) * strip                      # windowed strip path
    if impl == "triangular" and causal:
        nq, ns = T // qc, S // kc
        pairs = 0
        for qi in range(nq):
            q_end = (qi + 1) * qc
            for ki in range(ns):
                if ki * kc >= q_end:
                    break
                pairs += qc * kc
        return float(pairs)
    return float(T) * S                              # full rectangle


def _attn_layer_flops(cfg: ModelConfig, T: int, S: int, k: Knobs, *,
                      causal=True, window=0, cross=False) -> float:
    d = cfg.d_model
    proj = 2.0 * T * (d * cfg.q_dim + cfg.q_dim * d)
    if not cross:
        proj += 2.0 * T * 2 * d * cfg.kv_dim
    pairs = _attn_pairs(T, S, k.q_chunk, k.kv_chunk, causal=causal,
                        window=window, impl=k.attn_impl)
    core = 2.0 * pairs * cfg.n_heads * cfg.d_head * 2   # scores + pv
    return proj + core


def _ffn_flops(cfg: ModelConfig, T: int) -> float:
    return 2.0 * T * 3 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig, T: int, k: Knobs) -> float:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    group = min(k.moe_group, T)
    C = max(8, int(group * cfg.top_k / E * k.capacity_factor) // 8 * 8)
    G = T / group
    router = 2.0 * T * d * E
    expert = 2.0 * G * E * C * 3 * d * f
    if k.moe_dispatch == "einsum":
        transport = 2.0 * 2 * G * group * E * C * d  # dispatch + combine
    else:
        transport = 0.0                               # gather/scatter
    return router + expert + transport


def _rglru_flops(cfg: ModelConfig, T: int) -> float:
    d = cfg.d_model
    w = cfg.lru_width or d
    return 2.0 * T * (2 * d * w + 2 * w * (w // 8) + w * d) + 12.0 * T * w


def _mlstm_flops(cfg: ModelConfig, T: int, chunk: int = 256) -> float:
    d = cfg.d_model
    inner = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    dh = inner // H
    L = min(chunk, T)
    proj = 2.0 * T * (2 * d * inner + inner * d) \
        + 2.0 * T * inner * cfg.mlstm_qkv_blocksize * 3 \
        + 2.0 * T * 3 * inner * H * 2
    intra = 2.0 * T * L * inner * 2                  # scores + pv
    inter = 2.0 * T * dh * inner * 3                 # qC + state update
    return proj + intra + inter


def _slstm_flops(cfg: ModelConfig, T: int) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    gates = 2.0 * T * 4 * (d * d + d * hd)
    ffn = 2.0 * T * 3 * d * slstm_ffn_width(cfg)
    return gates + ffn


def forward_flops(cfg: ModelConfig, T_total: int, S_ctx: int, k: Knobs, *,
                  decode: bool = False) -> dict:
    """One forward pass over T_total tokens (global). For decode, T_total =
    batch (one token each) and S_ctx is the cache length."""
    T = T_total
    S = S_ctx
    per_unit = {"attn": 0.0, "ffn": 0.0, "moe": 0.0, "rec": 0.0}
    for sym in cfg.block_pattern:
        if sym in ("A", "L"):
            window = cfg.local_window if sym == "L" else 0
            if decode:
                eff = min(window, S) if window else S
                proj = 2.0 * T * (cfg.d_model * cfg.q_dim
                                  + 2 * cfg.d_model * cfg.kv_dim
                                  + cfg.q_dim * cfg.d_model)
                per_unit["attn"] += proj \
                    + 2.0 * T * eff * cfg.n_heads * cfg.d_head * 2
            else:
                per_unit["attn"] += _attn_layer_flops(cfg, T, S, k,
                                                      window=window)
            if cfg.family == "moe":
                per_unit["moe"] += _moe_flops(cfg, T, k)
            else:
                per_unit["ffn"] += _ffn_flops(cfg, T)
            if cfg.is_encoder_decoder:
                per_unit["attn"] += _attn_layer_flops(cfg, T, S, k,
                                                      causal=False,
                                                      cross=True) \
                    if not decode else 2.0 * T * (
                        cfg.d_model * cfg.q_dim + cfg.q_dim * cfg.d_model) \
                    + 2.0 * T * S * cfg.n_heads * cfg.d_head * 2
        elif sym == "R":
            per_unit["rec"] += _rglru_flops(cfg, T)
            per_unit["ffn"] += _ffn_flops(cfg, T)
        elif sym == "m":
            if decode:
                # recurrent step: projections + qC + state update, no
                # intra-chunk attention
                d = cfg.d_model
                inner = int(d * cfg.mlstm_proj_factor)
                dh = inner // cfg.n_heads
                per_unit["rec"] += 2.0 * T * (3 * d * inner
                                              + 3 * dh * inner)
            else:
                per_unit["rec"] += _mlstm_flops(cfg, T)
        elif sym == "s":
            per_unit["rec"] += _slstm_flops(cfg, T)
    stack = {kk: v * cfg.n_groups for kk, v in per_unit.items()}
    if cfg.is_encoder_decoder and not decode:
        # encoder: same dims, bidirectional self-attn + ffn
        enc = (_attn_layer_flops(cfg, T, S, k, causal=False)
               + _ffn_flops(cfg, T)) * cfg.n_enc_layers
        stack["attn"] += enc
    head = 2.0 * T * cfg.d_model * cfg.padded_vocab
    stack["head"] = head
    stack["total"] = sum(stack.values())
    return stack


def cell_flops(cfg: ModelConfig, shape: ShapeConfig, k: Knobs) -> dict:
    """Whole-step global FLOPs for a dry-run cell."""
    if shape.kind == "train":
        fwd = forward_flops(cfg, shape.tokens, shape.seq_len, k)
        # bwd = 2x fwd; remat full recomputes fwd inside bwd (+1x for the
        # scanned stack); head is outside the remat region (3x), unless
        # fused (its chunk bodies are checkpointed: 4x)
        mult_stack = {"none": 3.0, "dots": 3.5, "full": 4.0}[k.remat]
        mult_head = 4.0 if k.fused_head else 3.0
        stack = (fwd["total"] - fwd["head"]) * mult_stack
        head = fwd["head"] * mult_head
        opt = 8.0 * 4 * cfg.param_count()  # adamw vector ops (fp32)
        return {"total": stack + head + opt, "fwd": fwd,
                "stack_mult": mult_stack}
    if shape.kind == "prefill":
        fwd = forward_flops(cfg, shape.tokens, shape.seq_len, k)
        return {"total": fwd["total"], "fwd": fwd}
    fwd = forward_flops(cfg, shape.global_batch, shape.seq_len, k,
                        decode=True)
    return {"total": fwd["total"], "fwd": fwd}


# ---------------------------------------------------------------------------
# First-order HBM byte model
# ---------------------------------------------------------------------------

def cell_bytes(cfg: ModelConfig, shape: ShapeConfig, k: Knobs,
               masked_cache_write: bool | None = None) -> float:
    """Principal global HBM flows of one step (first-order)."""
    if masked_cache_write is None:
        masked_cache_write = k.cache_write == "masked"
    d = cfg.d_model
    P = cfg.param_count()
    act_bytes = 2  # bf16
    B = shape.global_batch

    if shape.kind == "decode":
        total = P * act_bytes                      # stream weights once
        # KV / state traffic per layer
        for sym in cfg.block_pattern:
            n = cfg.n_groups
            if sym in ("A", "L"):
                S_eff = min(cfg.local_window, shape.seq_len) \
                    if sym == "L" and cfg.local_window else shape.seq_len
                rw = 3.0 if masked_cache_write else 1.0
                total += n * B * S_eff * cfg.n_kv_heads * cfg.d_head * 2 \
                    * act_bytes * rw
                if cfg.is_encoder_decoder:
                    total += n * B * shape.seq_len * cfg.kv_dim * 2 \
                        * act_bytes
            elif sym == "R":
                w = cfg.lru_width or d
                total += n * B * w * 4 * 4
            elif sym == "m":
                inner = int(d * cfg.mlstm_proj_factor)
                H = cfg.n_heads
                total += n * B * H * (inner // H) ** 2 * 4 * 2  # C rw
            elif sym == "s":
                total += n * B * d * 4 * 8
        total += B * cfg.padded_vocab * 2          # logits row
        return total

    T = shape.tokens
    # activations: ~6 boundary tensors per layer read+write (fwd), x2 bwd,
    # x1.5 remat recompute
    act_mult = {"none": 3.0, "dots": 3.5, "full": 4.5}[k.remat] \
        if shape.kind == "train" else 1.0
    layer_traffic = cfg.n_layers * T * d * act_bytes * 6 * act_mult
    # attention score chunks materialise pairs x heads (bf16, r+w)
    pairs = 0.0
    for sym in cfg.block_pattern:
        if sym in ("A", "L"):
            window = cfg.local_window if sym == "L" else 0
            pairs += _attn_pairs(T, shape.seq_len, k.q_chunk, k.kv_chunk,
                                 causal=True, window=window,
                                 impl=k.attn_impl)
    pairs *= cfg.n_groups
    attn_traffic = pairs * cfg.n_heads * 4 * 2 * \
        (act_mult if shape.kind == "train" else 1.0) / 4  # fused exp/sum
    # weights: fwd + bwd + remat reads, grads write+read
    w_mult = 3.0 if shape.kind == "train" else 1.0
    weight_traffic = P * act_bytes * w_mult
    head_bytes = T * cfg.padded_vocab
    if shape.kind == "train":
        head_traffic = head_bytes * (2 + 4 + 4) if not k.fused_head \
            else head_bytes * 2.5  # streamed chunks, no global materialise
        opt_traffic = P * 4 * 3 * 2 + P * 4        # m,v,master rw + grads
    else:
        head_traffic = head_bytes * 2
        opt_traffic = 0.0
    return layer_traffic + attn_traffic + weight_traffic + head_traffic \
        + opt_traffic
