"""Benchmark regression gate for CI.

    python benchmarks/check_regression.py --current bench-out \\
        [--baseline benchmarks/baseline_smoke.json] [--tolerance 0.30]
    python benchmarks/check_regression.py --current bench-out --write-baseline

Compares watched throughput metrics from a ``--smoke`` benchmark run's
``BENCH_*.json`` files against the committed baseline and exits non-zero
when any metric regressed by more than ``--tolerance`` (default 30%).
Improvements always pass (and are the cue to refresh the baseline with
``--write-baseline``).

Each ``WATCHED`` entry carries a metric kind: ``abs`` (absolute
throughput, higher is better), ``ratio`` (machine-independent speedup,
higher is better), or ``max`` (cost bound, **lower** is better — the
fresh value fails when it exceeds baseline by more than tolerance).
Ratio metrics are machine-independent; absolute throughputs wobble more
across runners, which the default tolerance absorbs; ``max`` metrics
like ``dispatches_per_round`` are structural counts that barely wobble
at all.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# (file, path-into-json, metric kind[, tolerance]); kinds "abs"/"ratio"
# are higher-is-better, "max" is lower-is-better (a gated cost bound).
# The optional 4th element overrides --tolerance for that one metric —
# used where the acceptance bound is tighter than the default wobble
# allowance (e.g. tracing overhead must stay under 5%).
WATCHED = [
    ("BENCH_table3_terasort.json",
     ("result", "partition", "array_rec_per_s"), "abs"),
    ("BENCH_table3_terasort.json",
     ("result", "partition", "speedup"), "ratio"),
    ("BENCH_table3_terasort.json",
     ("result", "host", "sphere_array", "partition_rec_per_s"), "abs"),
    ("BENCH_table3_terasort.json",
     ("result", "host", "speedup"), "ratio"),
    # dispatch-then-sync overlap: shuffle rounds per host sync on the
    # array engine path.  Healthy = 1.0 (one barrier per round); a
    # regression to per-worker-batch syncing drags it toward 1/workers
    # (~0.17 on the 6-site cloud), far past any tolerance
    ("BENCH_table3_terasort.json",
     ("result", "host", "sphere_array", "rounds_per_sync"), "ratio"),
    # fused worker-axis rounds: compiled dispatches per shuffle round on
    # the array engine path.  The fused round costs a small constant
    # (stacked apply + bounded scatter shards + harvest gather); a fall
    # back to the per-worker dispatch loop multiplies it by
    # O(tasks + workers) per round, far past any tolerance.  Lower is
    # better — baseline pinned at the high end of healthy variance.
    ("BENCH_table3_terasort.json",
     ("result", "host", "sphere_array", "dispatches_per_round"), "max"),
    # engine-level scale sweep, flagship (largest) scale: the warm
    # device-resident scatter through the whole engine must stay ahead
    # of the bytes backend (ratio) and keep its absolute throughput
    ("BENCH_table3_terasort.json",
     ("result", "host_scales", -1, "array_rec_per_s"), "abs"),
    ("BENCH_table3_terasort.json",
     ("result", "host_scales", -1, "array_over_bytes"), "ratio"),
    # k-means session path: steady-state per-iteration throughput and the
    # session-vs-per-iteration-rebuild speedup (one planner/lookup/trace
    # for the whole chain) — gated like partitioning so iteration stays
    # the fast path
    ("BENCH_table2_kmeans.json",
     ("result", "kmeans", "session_iter_rec_per_s"), "abs"),
    ("BENCH_table2_kmeans.json",
     ("result", "kmeans", "session_speedup"), "ratio"),
    # streaming path: steady-state per-window throughput and the
    # stream-vs-rebuild-per-window wall-clock speedup — gates the
    # stream subsystem's delta planning + trace-once guarantees
    ("BENCH_stream_window.json",
     ("result", "stream", "window_rec_per_s"), "abs"),
    ("BENCH_stream_window.json",
     ("result", "stream", "speedup"), "ratio"),
    # wide-area scheduling: on the bottlenecked 4-site layout,
    # contention-aware plans vs contention-blind plans both priced under
    # the per-link queueing model.  Purely simulated-clock, so it barely
    # wobbles; a fall back to private-link pricing drags it to ~1.0,
    # far past any tolerance.  Baseline pinned below the smoke value.
    ("BENCH_wan.json",
     ("result", "wan", "contention_aware_speedup"), "ratio"),
    # observability: tracing-enabled array TeraSort vs the untraced
    # baseline, steady-state best-of-N partition time.  Baseline pinned
    # at 1.0 with a 5% per-metric tolerance — the ISSUE-10 acceptance
    # bound ("tracing must be (near-)zero-cost"), far tighter than the
    # default throughput wobble allowance.
    ("BENCH_table3_terasort.json",
     ("result", "tracing", "overhead_ratio"), "max", 0.05),
]


def _unpack(entry):
    """A WATCHED row, with or without the per-metric tolerance."""
    if len(entry) == 4:
        return entry
    fname, path, kind = entry
    return fname, path, kind, None


def _dig(obj, path):
    for p in path:
        if isinstance(p, int):  # list index (negative = from the end)
            if not isinstance(obj, list) or not -len(obj) <= p < len(obj):
                return None
        elif not isinstance(obj, dict) or p not in obj:
            return None
        obj = obj[p]
    return obj


def _metric_id(fname, path):
    return f"{fname}:{'.'.join(str(p) for p in path)}"


def collect(current_dir: str) -> dict:
    out = {}
    for fname, path, _, _ in map(_unpack, WATCHED):
        fpath = os.path.join(current_dir, fname)
        if not os.path.exists(fpath):
            print(f"MISSING {fpath}")
            continue
        with open(fpath) as f:
            val = _dig(json.load(f), path)
        if isinstance(val, (int, float)):
            out[_metric_id(fname, path)] = val
        else:
            print(f"MISSING metric {_metric_id(fname, path)}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True,
                    help="directory holding this run's BENCH_*.json")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "baseline_smoke.json"))
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOLERANCE",
                                                 0.30)),
                    help="max fractional regression (default 0.30)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the baseline with this run's values")
    args = ap.parse_args(argv)

    current = collect(args.current)
    if args.write_baseline:
        missing = [_metric_id(f, p) for f, p, _, _ in map(_unpack, WATCHED)
                   if _metric_id(f, p) not in current]
        if missing:
            # a partial baseline would silently un-gate the absent
            # metrics forever (they'd SKIP on every later run)
            print(f"refusing to write baseline, watched metrics missing "
                  f"from current run: {', '.join(missing)}")
            return 1
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(current)} baseline metrics -> {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)

    failed = []
    for fname, path, kind, tol in map(_unpack, WATCHED):
        mid = _metric_id(fname, path)
        tol = args.tolerance if tol is None else tol
        base, cur = baseline.get(mid), current.get(mid)
        if base is None:
            print(f"SKIP   {mid} (not in baseline)")
            continue
        if cur is None:
            failed.append(mid)
            print(f"FAIL   {mid}: missing from current run "
                  f"(baseline {base})")
            continue
        if kind == "max":  # lower is better: fail above the ceiling
            bound = base * (1.0 + tol)
            bad = cur > bound
            print(f"{'FAIL' if bad else 'ok':6} {mid}: {cur} vs baseline "
                  f"{base} (ceiling {bound:.2f}, lower is better)")
        else:              # abs/ratio: fail below the floor
            bound = base * (1.0 - tol)
            bad = cur < bound
            print(f"{'FAIL' if bad else 'ok':6} {mid}: {cur} vs baseline "
                  f"{base} (floor {bound:.0f})")
        if bad:
            failed.append(mid)
    if failed:
        print(f"\nregression gate FAILED: {', '.join(failed)}")
        return 1
    print(f"\nregression gate ok ({len(current)} metrics, "
          f"tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
