"""Table 3 — TeraSort: Sphere vs Hadoop-style execution (paper §5.4).

Paper result: Sphere sorts 10GB/node ~2-3x faster than Hadoop on the same
6-node cluster (and Hadoop used 4 cores/node vs Sphere's 1). The structural
reasons, reproduced at two levels:

1. **Host level** (the paper's actual setting): the Sphere engine runs
   generate/partition/sort as UDF stages over Sector chunks with locality
   and pipelined shuffle; the Hadoop-style run disables locality (tasks go
   round-robin regardless of replica placement, charging WAN movement) and
   pays a materialisation barrier between map and reduce. Reported time is
   the engine's deterministic cost model over the Teraflow topology.

2. **Device level** (the TPU twin): ``distributed_sort`` (sample ->
   bucketize -> all_to_all -> local sort) vs ``barrier_sort`` (all-gather
   everything, sort, slice). On 1 physical CPU core wall-time is not
   meaningful, so the headline is exchanged bytes: all_to_all moves each
   key once; the barrier moves it n times.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile

import numpy as np

from repro.core import SphereEngine, SphereJob, SphereStage
from repro.core.shuffle import range_partitioner, sample_boundaries
from repro.sector import ChunkServer, SectorClient, SectorMaster

RECORD = 100   # TeraSort: 100-byte records, 10-byte keys
KEY = 10


def _make_cloud(no_locality: bool = False):
    tmp = tempfile.mkdtemp(prefix="t3_")
    # record-aligned chunk size (fixed-size records must not straddle chunks)
    master = SectorMaster(chunk_size=5000 * RECORD)
    for i, site in enumerate(master.topology.sites):
        master.register(ChunkServer(f"s{i}", site, tmp))
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", "chicago")
    return master, client


def _gen_records(n: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    keys = rng.bytes(n * KEY)
    out = bytearray()
    for i in range(n):
        out += keys[i * KEY:(i + 1) * KEY] + b"v" * (RECORD - KEY)
    return bytes(out)


class _NoLocalityEngine(SphereEngine):
    """Hadoop-style comparison: ignore replica placement when scheduling
    (data always moves to the compute), and double-materialise at the
    shuffle barrier."""

    def _run_stage(self, job, stage, tasks, parts, rep, *, first_stage):
        tasks = [(k, nb, []) for (k, nb, _) in tasks]  # hide locality info
        t = super()._run_stage(job, stage, tasks, parts, rep,
                               first_stage=first_stage)
        # barrier materialisation: write + read back the stage output
        nbytes = sum(sum(len(r) for r in parts[w]) for w in parts)
        return t + 2 * nbytes / 400e6  # disk write+read at 400 MB/s


def run_host_level(n_records: int = 50_000) -> dict:
    data = _gen_records(n_records)
    sample = [data[i:i + RECORD]
              for i in range(0, min(len(data), 200 * RECORD), RECORD)]
    bounds = sample_boundaries(sample, 6, key_bytes=KEY)

    def sort_udf(records):
        return sorted(records, key=lambda r: r[:KEY])

    def make_job():
        return SphereJob("terasort", "tera", [
            SphereStage("partition", lambda rs: list(rs),
                        partitioner=range_partitioner(bounds), n_buckets=6),
            SphereStage("sort", sort_udf),
        ], record_size=RECORD)

    out = {}
    for label, engine_cls in (("sphere", SphereEngine),
                              ("hadoop_style", _NoLocalityEngine)):
        master, client = _make_cloud()
        client.upload("tera", data, replication=3)
        eng = engine_cls(master, client)
        outputs, rep = eng.run(make_job())
        # verify global sortedness across buckets
        allrec = []
        for blob in outputs:
            recs = [blob[i:i + RECORD] for i in range(0, len(blob), RECORD)]
            assert recs == sorted(recs, key=lambda r: r[:KEY])
            allrec.extend(recs)
        assert len(allrec) == n_records
        out[label] = {"sim_seconds": round(rep.sim_seconds, 3),
                      "locality": round(rep.locality_fraction, 3),
                      "bytes_moved": rep.bytes_moved}
    out["speedup"] = round(out["hadoop_style"]["sim_seconds"]
                           / out["sphere"]["sim_seconds"], 2)
    return out


_DEVICE_BENCH = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.spmd import distributed_sort, barrier_sort
from repro.launch.mesh import make_flat_mesh
mesh = make_flat_mesh()
N = 1 << 18
keys = jax.random.randint(jax.random.PRNGKey(0), (N,), 0, 1 << 30,
                          dtype=jnp.uint32)
out, valid = jax.jit(lambda k: distributed_sort(k, mesh))(keys)
per = np.asarray(out).reshape(mesh.devices.size, -1)
got = np.concatenate([p[p != 0xFFFFFFFF] for p in per])
assert np.array_equal(got, np.sort(np.asarray(keys)))
outb = jax.jit(lambda k: barrier_sort(k, mesh))(keys)
assert np.array_equal(np.asarray(outb).reshape(-1), np.sort(np.asarray(keys)))
n = mesh.devices.size
print(f"{N*4},{N*4*n}")
"""


def run_device_level() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DEVICE_BENCH],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    b_s, b_h = out.stdout.strip().split("\n")[-1].split(",")
    return {"bytes_all_to_all": int(b_s), "bytes_barrier": int(b_h),
            "traffic_ratio": round(int(b_h) / int(b_s), 1),
            "correct": True}


def main() -> None:
    host = run_host_level()
    print("level,metric,value")
    for label in ("sphere", "hadoop_style"):
        for k, v in host[label].items():
            print(f"host:{label},{k},{v}")
    print(f"host,speedup,{host['speedup']}  (paper band: 2-3x)")
    dev = run_device_level()
    for k, v in dev.items():
        print(f"device,{k},{v}")


if __name__ == "__main__":
    main()
