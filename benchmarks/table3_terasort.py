"""Table 3 — TeraSort: Sphere vs Hadoop-style execution (paper §5.4).

Paper result: Sphere sorts 10GB/node ~2-3x faster than Hadoop on the same
6-node cluster (and Hadoop used 4 cores/node vs Sphere's 1). The structural
reasons, reproduced at three levels:

1. **Host level** (the paper's actual setting): the Sphere engine runs
   generate/partition/sort as UDF stages over Sector chunks with locality
   and pipelined shuffle; the Hadoop-style run disables locality (tasks go
   round-robin regardless of replica placement, charging WAN movement) and
   pays a materialisation barrier between map and reduce. Reported time is
   the engine's deterministic cost model over the Teraflow topology. Runs
   on BOTH record backends (bytes reference and the array backend built on
   the Pallas bucket-partition kernel) and checks their outputs agree
   byte-for-byte.

2. **Partition microbench**: the shuffle hot loop in isolation at >= 1M
   records — per-record Python binary search vs the analysis kernel +
   argsort/gather vs the device-resident ``scatter_batch`` path the
   engine runs. This is the records/sec speedup the array backend
   exists for. An engine-level scale sweep (``host_scales``) reports
   the same bytes-vs-array comparison through the whole engine at every
   scale, warm and cold.

3. **Device level** (the TPU twin): ``distributed_sort`` (sample ->
   bucketize -> all_to_all -> local sort) vs ``barrier_sort`` (all-gather
   everything, sort, slice). On 1 physical CPU core wall-time is not
   meaningful, so the headline is exchanged bytes: all_to_all moves each
   key once; the barrier moves it n times.
"""
from __future__ import annotations

import gc
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core import SphereEngine, SphereJob, TaskSpec, Tracer
from repro.core.records import RecordBatch, scatter_by_ids
from repro.core.shuffle import (partition_batch, range_partitioner,
                                sample_boundaries, terasort_stages)
from repro.sector import ChunkServer, SectorClient, SectorMaster

RECORD = 100   # TeraSort: 100-byte records, 10-byte keys
KEY = 10


def _make_cloud():
    tmp = tempfile.mkdtemp(prefix="t3_")
    # record-aligned chunk size (fixed-size records must not straddle chunks)
    master = SectorMaster(chunk_size=5000 * RECORD)
    for i, site in enumerate(master.topology.sites):
        master.register(ChunkServer(f"s{i}", site, tmp))
    master.acl.add_member("bench")
    master.acl.grant_write("bench")
    client = SectorClient(master, "bench", "chicago")
    return master, client


def _gen_records(n: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 256, size=(n, KEY), dtype=np.uint8)
    payload = np.full((n, RECORD - KEY), ord("v"), np.uint8)
    return np.concatenate([keys, payload], axis=1).tobytes()


class _NoLocalityEngine(SphereEngine):
    """Hadoop-style comparison: ignore replica placement when scheduling
    (data always moves to the compute), and double-materialise at the
    shuffle barrier."""

    def _schedule_view(self, tasks):
        return [TaskSpec(t.key, t.nbytes, ()) for t in tasks]

    def _stage_barrier_seconds(self, stage_output_nbytes):
        # barrier materialisation: write + read back the stage output
        return 2 * stage_output_nbytes / 400e6  # disk at 400 MB/s


def _terasort_job(bounds, backend: str) -> SphereJob:
    return SphereJob("terasort", "tera",
                     terasort_stages(bounds, backend, 6, key_bytes=KEY),
                     record_size=RECORD, backend=backend)


def _check_sorted(outputs, n_records: int) -> bytes:
    """Assert every output blob is key-sorted and return the joined
    blob for byte-exact cross-backend parity.  Checked in numpy (the
    10-byte key as a big-endian u64+u16 pair): the old per-record
    Python check left millions of small bytes objects alive across the
    sweep's timed runs, and that allocator pressure alone cost the 1M
    array timing ~10% in the full-suite process."""
    total = 0
    for blob in outputs:
        arr = np.frombuffer(blob, np.uint8).reshape(-1, RECORD)
        total += arr.shape[0]
        k1 = arr[:, :8].copy().view(">u8").ravel()
        k2 = arr[:, 8:KEY].copy().view(">u2").ravel()
        assert np.all((k1[:-1] < k1[1:])
                      | ((k1[:-1] == k1[1:]) & (k2[:-1] <= k2[1:])))
    assert total == n_records
    return b"".join(outputs)


def _sample_bounds(data: bytes, n_buckets: int = 6):
    sample = [data[i:i + RECORD]
              for i in range(0, min(len(data), 200 * RECORD), RECORD)]
    # full 10-byte TeraSort splitters: the multi-word kernel compare keeps
    # the array backend on the kernel path (see core/shuffle.py)
    return sample_boundaries(sample, n_buckets, key_bytes=KEY)


def _engine_run(engine_cls, backend: str, data: bytes, bounds,
                n_records: int, *, warm_runs: int = 0, best_of: int = 1):
    """Upload + run one TeraSort config; returns (sorted records, report).

    ``warm_runs`` extra identical runs execute first and are discarded —
    the array backend's steady-state number (the engine's real serving
    regime: sessions/streams re-run jobs against compiled kernels), with
    the one-off Pallas trace per padded block shape excluded, exactly
    like the partition microbench warms its jit before timing.
    ``best_of`` measured runs then execute and the report with the
    smallest ``partition_seconds`` wins — the partition microbench's
    min-of-N policy applied at engine level, so a single scheduler
    stall on a one-core host doesn't masquerade as a shuffle
    regression.

    ``timing_sync=True`` keeps the engine's ``partition_seconds`` honest
    under the dispatch-then-sync shuffle: the clock only stops after
    every shuffled piece is device-complete (see docs/BENCHMARKS.md,
    "timing policy")."""
    master, client = _make_cloud()
    client.upload("tera", data, replication=3)
    eng = engine_cls(master, client, timing_sync=True)
    # ONE job object reused across warm + measured runs: stage UDF jit
    # caches key on the callable's identity, so rebuilding the job per
    # run (fresh lambdas) would retrace every stage and the warm runs
    # would never actually warm anything.
    job = _terasort_job(bounds, backend)
    for _ in range(warm_runs):
        eng.run(job)
    gc.collect()   # cloud-build + warm-run garbage stays out of timing
    best = None
    for _ in range(max(best_of, 1)):
        outputs, rep = eng.run(job)
        if best is None or rep.partition_seconds < best[1].partition_seconds:
            best = (outputs, rep)
    outputs, rep = best
    return _check_sorted(outputs, n_records), rep


def _rec_per_s(rep) -> int:
    return round(rep.partitioned_records / max(rep.partition_seconds, 1e-9))


def run_host_level(n_records: int = 50_000) -> dict:
    """Sphere vs Hadoop-style on the bytes backend, plus the same Sphere
    job on the array backend (outputs must agree byte-for-byte)."""
    data = _gen_records(n_records)
    bounds = _sample_bounds(data)

    out = {}
    baseline = None
    for label, engine_cls, backend in (
            ("sphere", SphereEngine, "bytes"),
            ("hadoop_style", _NoLocalityEngine, "bytes"),
            ("sphere_array", SphereEngine, "array")):
        warm = 1 if backend == "array" else 0
        allrec, rep = _engine_run(engine_cls, backend, data, bounds,
                                  n_records, warm_runs=warm)
        if engine_cls is SphereEngine:
            if baseline is None:
                baseline = allrec
            else:
                assert allrec == baseline, "backends disagree"
        out[label] = {
            "backend": backend,
            "sim_seconds": round(rep.sim_seconds, 3),
            "locality": round(rep.locality_fraction, 3),
            "bytes_moved": rep.bytes_moved,
            "partition_seconds": round(rep.partition_seconds, 4),
            "partition_rec_per_s": _rec_per_s(rep),
            # array backend: distinct traced shapes per pad-stable stage
            # UDF (1 per stage = the jit-once guarantee held)
            "udf_traces": dict(rep.udf_traces),
            # dispatch-then-sync accounting: the array backend harvests
            # one shuffle round behind ONE host barrier, so
            # rounds_per_sync sits at 1.0 (a per-worker-sync regression
            # drags it toward 1/workers); bytes never syncs a device.
            "shuffle_rounds": rep.shuffle_rounds,
            "host_syncs": rep.host_syncs,
            "rounds_per_sync": round(rep.shuffle_rounds
                                     / rep.host_syncs, 3)
                               if rep.host_syncs else None,
            # fused worker-axis round accounting: hot-loop compiled calls
            # across the job's rounds.  The fused round holds
            # dispatches_per_round at a small constant (stacked apply +
            # bounded scatter shards + harvest gather) at any worker or
            # task count; a climb toward O(tasks + workers) means rounds
            # fell back to the per-worker loop (gated, lower is better).
            "device_dispatches": rep.device_dispatches,
            "dispatches_per_round": round(rep.device_dispatches
                                          / rep.shuffle_rounds, 2)
                                    if rep.shuffle_rounds else None,
        }
    out["speedup"] = round(out["hadoop_style"]["sim_seconds"]
                           / out["sphere"]["sim_seconds"], 2)
    return out


def run_engine_scales(scales) -> list:
    """Engine-level partition throughput, bytes vs array, at every scale.

    This is the metric the device-resident scatter exists for: the whole
    engine shuffle — per-worker RecordBatch in, bucket-sliced
    RecordBatches out — not the standalone kernel.  The array number is
    steady-state (one warm run first, then best-of-5 measured runs, see
    :func:`_engine_run`); the cold first run is also reported so the
    one-off trace cost stays visible.  ``array_over_bytes`` should be
    >= 1 at every scale — the flagship-scale engine throughput is what
    ``check_regression.py`` gates.
    """
    rows = []
    for n in scales:
        data = _gen_records(n)
        bounds = _sample_bounds(data)
        rec_b, rep_b = _engine_run(SphereEngine, "bytes", data, bounds, n)
        rec_cold, rep_cold = _engine_run(SphereEngine, "array", data,
                                         bounds, n)
        rec_a, rep_a = _engine_run(SphereEngine, "array", data, bounds, n,
                                   warm_runs=1, best_of=5)
        assert rec_a == rec_b == rec_cold, "backends disagree"
        rows.append({
            "records": n,
            "bytes_rec_per_s": _rec_per_s(rep_b),
            "array_rec_per_s": _rec_per_s(rep_a),
            "array_cold_rec_per_s": _rec_per_s(rep_cold),
            "array_over_bytes": round(_rec_per_s(rep_a)
                                      / max(_rec_per_s(rep_b), 1), 2),
        })
    return rows


def run_partition_bench(n_records: int = 1_000_000, n_buckets: int = 16,
                        repeats: int = 3) -> dict:
    """The shuffle hot loop at scale, three ways: per-record Python
    partitioning, the analysis kernel + argsort/gather, and the
    device-resident ``scatter_batch`` path the engine actually runs
    (one fused kernel pass + device epilogue, one host sync for the
    histogram).  Min-of-N wall time each; array paths are warmed once
    so jit compile is excluded — every row is steady-state throughput.
    Splitters are full 10-byte TeraSort keys: the kernel compares them
    as 3-word rows, so the headline is the multi-word path end-to-end."""
    import jax

    from repro.core.shuffle import scatter_batch

    blob = _gen_records(n_records)
    records = [blob[i:i + RECORD] for i in range(0, len(blob), RECORD)]
    bounds = sample_boundaries(records[:1000], n_buckets, key_bytes=KEY)
    part = range_partitioner(bounds)

    def bytes_run():
        buckets = [[] for _ in range(n_buckets)]
        for r in records:
            buckets[part(r, n_buckets)].append(r)
        return buckets

    batch = RecordBatch.from_bytes(blob, RECORD)

    def array_run():
        ids, hist = partition_batch(batch, part, n_buckets)
        pieces = scatter_by_ids(batch, ids, hist)
        jax.block_until_ready([p.data for p in pieces])
        return pieces

    def scatter_run():
        pieces = scatter_batch(batch, part, n_buckets)
        jax.block_until_ready([p.data for p in pieces])
        return pieces

    def _timed(fn):
        t0 = time.perf_counter()
        out = fn()
        return time.perf_counter() - t0, out

    runs = [_timed(bytes_run) for _ in range(repeats)]
    t_bytes, buckets = min(runs, key=lambda r: r[0])
    array_run()  # warm: jit compile + constant folding
    runs = [_timed(array_run) for _ in range(repeats)]
    t_array, pieces = min(runs, key=lambda r: r[0])
    scatter_run()  # warm
    runs = [_timed(scatter_run) for _ in range(repeats)]
    t_scat, spieces = min(runs, key=lambda r: r[0])

    # parity spot-check on the timed outputs: identical per-bucket counts
    assert [len(b) for b in buckets] == [p.num_records for p in pieces]
    assert [len(b) for b in buckets] == [p.num_records for p in spieces]

    return {
        "records": n_records,
        "n_buckets": n_buckets,
        "key_bytes": KEY,
        "bytes_seconds": round(t_bytes, 3),
        "array_seconds": round(t_array, 3),
        "scatter_seconds": round(t_scat, 3),
        "bytes_rec_per_s": round(n_records / t_bytes),
        "array_rec_per_s": round(n_records / t_array),
        "scatter_rec_per_s": round(n_records / t_scat),
        "speedup": round(t_bytes / t_array, 1),
        "scatter_speedup": round(t_bytes / t_scat, 1),
    }


def run_tracing(n_records: int = 50_000, *, best_of: int = 7,
                out_dir: str | None = None) -> dict:
    """The tracing plane's two promises, measured: enabled-mode overhead
    on the array TeraSort stays small (``overhead_ratio``, CI-gated at
    <5% over the untraced baseline via ``check_regression.py``), and the
    traced run exports a Chrome/Perfetto timeline
    (``TRACE_terasort.json`` when ``out_dir`` is given — the artifact
    ``scripts/check_trace.py`` validates in CI).

    Both arms use the engine-level timing policy (``timing_sync=True``,
    one warm run, best-of-N minimum on the whole-job wall time) so the
    ratio compares steady-state runs, not compile noise — and the timed
    runs interleave the two arms so clock drift or background load
    lands on both equally instead of skewing the ratio."""
    data = _gen_records(n_records)
    bounds = _sample_bounds(data)

    def setup(tracer):
        master, client = _make_cloud()
        client.upload("tera", data, replication=3)
        eng = SphereEngine(master, client, timing_sync=True, tracer=tracer)
        job = _terasort_job(bounds, "array")
        eng.run(job)   # warm: trace UDFs + shuffle kernels once
        return eng, job

    eng_off, job_off = setup(None)
    tracer = Tracer()
    eng_on, job_on = setup(tracer)
    gc.collect()
    best_off = best_on = None
    rep_off = rep_on = None
    for _ in range(max(best_of, 1)):
        t0 = time.perf_counter()
        _, rep_off = eng_off.run(job_off)
        dt = time.perf_counter() - t0
        best_off = dt if best_off is None else min(best_off, dt)
        t0 = time.perf_counter()
        _, rep_on = eng_on.run(job_on)
        dt = time.perf_counter() - t0
        best_on = dt if best_on is None else min(best_on, dt)
    out = {
        "records": n_records,
        "untraced_job_seconds": round(best_off, 4),
        "traced_job_seconds": round(best_on, 4),
        "overhead_ratio": round(best_on / max(best_off, 1e-9), 3),
        # tracing must ride the existing harvest: same sync count on/off
        "untraced_host_syncs": rep_off.host_syncs,
        "traced_host_syncs": rep_on.host_syncs,
        "spans": tracer.count(),
    }
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "TRACE_terasort.json")
        doc = tracer.export_chrome(path)
        out["trace_path"] = path
        out["trace_events"] = len(doc["traceEvents"])
    return out


_DEVICE_BENCH = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.spmd import distributed_sort, barrier_sort
from repro.launch.mesh import make_flat_mesh
mesh = make_flat_mesh()
N = {n}
keys = jax.random.randint(jax.random.PRNGKey(0), (N,), 0, 1 << 30,
                          dtype=jnp.uint32)
out, valid = jax.jit(lambda k: distributed_sort(k, mesh))(keys)
per = np.asarray(out).reshape(mesh.devices.size, -1)
got = np.concatenate([p[p != 0xFFFFFFFF] for p in per])
assert np.array_equal(got, np.sort(np.asarray(keys)))
outb = jax.jit(lambda k: barrier_sort(k, mesh))(keys)
assert np.array_equal(np.asarray(outb).reshape(-1), np.sort(np.asarray(keys)))
n = mesh.devices.size
print(f"{{N*4}},{{N*4*n}}")
"""


def run_device_level(n_keys: int = 1 << 18) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _DEVICE_BENCH.format(n=n_keys)],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    b_s, b_h = out.stdout.strip().split("\n")[-1].split(",")
    return {"bytes_all_to_all": int(b_s), "bytes_barrier": int(b_h),
            "traffic_ratio": round(int(b_h) / int(b_s), 1),
            "correct": True}


def main(smoke: bool = False, out_dir: str = ".") -> dict:
    host = run_host_level(5_000 if smoke else 50_000)
    print("level,metric,value")
    for label in ("sphere", "hadoop_style", "sphere_array"):
        for k, v in host[label].items():
            print(f"host:{label},{k},{v}")
    print(f"host,speedup,{host['speedup']}  (paper band: 2-3x)")
    scales = run_engine_scales([5_000, 20_000] if smoke
                               else [5_000, 50_000, 200_000, 1_000_000])
    for row in scales:
        print(f"host_scales:{row['records']},bytes_rec_per_s,"
              f"{row['bytes_rec_per_s']}")
        print(f"host_scales:{row['records']},array_rec_per_s,"
              f"{row['array_rec_per_s']} ({row['array_over_bytes']}x bytes)")
    part = run_partition_bench(100_000 if smoke else 1_000_000,
                               repeats=2 if smoke else 5)
    for k, v in part.items():
        print(f"partition,{k},{v}")
    dev = run_device_level(1 << 14 if smoke else 1 << 18)
    for k, v in dev.items():
        print(f"device,{k},{v}")
    trc = run_tracing(20_000 if smoke else 50_000, out_dir=out_dir)
    for k, v in trc.items():
        print(f"tracing,{k},{v}")
    return {"host": host, "host_scales": scales, "partition": part,
            "device": dev, "tracing": trc}


if __name__ == "__main__":
    try:
        from benchmarks.bench_out import write_bench
    except ImportError:
        from bench_out import write_bench
    smoke = "--smoke" in sys.argv
    write_bench("table3_terasort", main(smoke=smoke), smoke=smoke)
