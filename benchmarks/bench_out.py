"""Shared writer for the ``BENCH_*.json`` benchmark artifacts.

Every benchmark (the :mod:`benchmarks.run` aggregator and each table
script run standalone) writes its machine-readable result through
:func:`write_bench`, so the JSON shape is defined once and every
artifact carries the same provenance stamp: the git SHA it was measured
at and the JAX backend it ran on.  ``check_regression.py`` reads the
``result`` subtree; provenance rides alongside it, so a regression
report can always say *which commit* produced the baseline it is
comparing against.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Optional


def git_sha() -> Optional[str]:
    """HEAD commit of the repo this file lives in (None outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def provenance() -> dict:
    """The stamp every benchmark artifact carries."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:  # noqa: BLE001 — provenance must never fail a bench
        backend = None
    return {
        "git_sha": git_sha(),
        "jax_backend": backend,
        "written_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_bench(section: str, result, *, smoke: bool, ok: bool = True,
                out_dir: str = ".") -> str:
    """Write ``BENCH_<section>.json`` under ``out_dir`` and return the
    path.  ``result`` is the section's structured output (an error
    summary when ``ok`` is False) — consumers address into it as
    ``result.<key>...``, so the envelope never nests it deeper."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump({"section": section, "smoke": smoke, "ok": ok,
                   "provenance": provenance(), "result": result},
                  f, indent=2, default=str)
    return path
