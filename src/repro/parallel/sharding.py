"""Sharding policy: logical rules mapping parameter paths -> PartitionSpec.

The production mesh is ``("data", "model")`` within a pod and
``("pod", "data", "model")`` across pods. Policy (paper-faithful wide-area
design — see DESIGN.md §4):

  * parameters / optimizer state: FSDP over ``data`` x TP/EP over ``model``,
    **replicated over ``pod``** — the cross-pod ("wide-area") hop carries only
    the once-per-step gradient reduction, never bulk weights;
  * activations: batch over ``(pod, data)``, heads/ffn over ``model``;
  * KV caches: batch over ``(pod, data)``; heads over ``model`` when the head
    count divides, else the sequence dim (flash-decoding style), else
    replicated.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution + optimization knobs (the hillclimb surface)."""

    mesh: Optional[Mesh] = None
    multi_pod: bool = False
    # --- optimization knobs (baseline values are paper-faithful) -----------
    mode: str = "pjit"                 # "pjit" | "podwise" (manual pod axis)
    remat: str = "full"                # "none" | "full" | "dots"
    moe_dispatch: str = "einsum"       # "einsum" (GShard one-hot) | "gather"
    compress_pod: str = "none"         # "none" | "bf16" | "int8_ef"
    attn_impl: str = "scan"            # "scan" | "rect" | "triangular" | "pallas"
    q_chunk: int = 2048
    kv_chunk: int = 2048
    donate: bool = True
    scan_layers: bool = True
    # --- beyond-paper optimizations (each a §Perf iteration) ---------------
    layout: str = "tp"                 # "tp" (FSDPxTP) | "fsdp" (ZeRO-3:
                                       # batch over data AND model; no TP
                                       # activation all-reduces — needs
                                       # global_batch % (data*model) == 0)
    fused_head: bool = False           # chunked CE fused with the LM head
    head_chunk: int = 512              # token chunk for the fused head
    embed_mode: str = "gather"         # "gather" | "vocab_parallel"
    accum_steps: int = 1               # gradient-accumulation microbatches
    lru_chunk: int = 0                 # RG-LRU: chunk the associative scan
    cache_write: str = "masked"        # "masked" (shardable everywhere) |
                                       # "scatter" (DUS: 1x instead of 3x
                                       # cache traffic; needs unsharded seq)
    # --- measurement (roofline) mode ----------------------------------------
    unroll_scans: bool = False         # python-loop the inner scans so
                                       # cost_analysis counts every trip

    @property
    def data_axes(self) -> Tuple[str, ...]:
        base = ("pod", "data") if self.multi_pod else ("data",)
        if self.layout == "fsdp":
            base = base + ("model",)
        return base

    @property
    def axis_sizes(self):
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def model_size(self) -> int:
        return self.axis_sizes.get("model", 1)

    @property
    def data_size(self) -> int:
        s = self.axis_sizes.get("data", 1)
        if self.multi_pod:
            s *= self.axis_sizes.get("pod", 1)
        return s

    def with_(self, **kw) -> "ParallelConfig":
        return replace(self, **kw)


NO_PARALLEL = ParallelConfig(mesh=None)


def batch_spec(pcfg: ParallelConfig, *trailing) -> P:
    """Batch dim over the data axes; trailing entries appended verbatim.

    Under the fsdp layout the model axis belongs to the batch dim, so any
    trailing "model" (TP) annotation is dropped."""
    if pcfg.mesh is None:
        return P()
    if pcfg.layout == "fsdp":
        trailing = tuple(None if t == "model" else t for t in trailing)
    return P(pcfg.data_axes if len(pcfg.data_axes) > 1 else pcfg.data_axes[0],
             *trailing)


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def heads_spec(pcfg: ParallelConfig, n_heads: int, *, batch_dims=1, trailing=1):
    """Spec for [batch, (seq), heads, d_head]-shaped activations."""
    if pcfg.mesh is None:
        return None
    axes = [pcfg.data_axes if len(pcfg.data_axes) > 1 else pcfg.data_axes[0]]
    axes += [None] * (batch_dims - 1)
    use_tp = pcfg.layout == "tp" and _divisible(n_heads, pcfg.model_size)
    axes += ["model" if use_tp else None]
    axes += [None] * trailing
    return P(*axes)


def kv_cache_spec(pcfg: ParallelConfig, n_kv: int, seq: int) -> P:
    """Spec for a [B, S, K, D] KV cache (leading group dim handled by caller).

    Heads over ``model`` when divisible, else sequence (flash-decoding
    partial-softmax), else replicated over model.
    """
    if pcfg.mesh is None:
        return P()
    b = pcfg.data_axes if len(pcfg.data_axes) > 1 else pcfg.data_axes[0]
    if _divisible(n_kv, pcfg.model_size):
        return P(b, None, "model", None)
    if _divisible(seq, pcfg.model_size):
        return P(b, "model", None, None)
    return P(b, None, None, None)


def validate_spec(spec: P, shape, sizes: dict) -> P:
    """Drop spec axes that do not divide the corresponding dim."""
    dims = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            dims.append(None if i >= len(shape) else ax)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for n in names:
            total *= sizes.get(n, 1)
        dims.append(ax if shape[i] % total == 0 else None)
    return P(*dims)


def constrain(x: jax.Array, pcfg: ParallelConfig, spec: Optional[P]):
    """with_sharding_constraint that degrades gracefully: no-op without a
    mesh, and any axis that does not divide its dim is dropped."""
    if pcfg.mesh is None or spec is None:
        return x
    spec = validate_spec(spec, x.shape, pcfg.axis_sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(pcfg.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter path -> PartitionSpec rules
# ---------------------------------------------------------------------------
# Paths are '/'-joined key paths into the param tree. Leading "blocks/u<i>/"
# (and "encoder/blocks/u<i>/") segments carry a stacked group dim, handled by
# prefixing the matched spec with None.
#
# Order matters: first match wins.

_RULES: Tuple[Tuple[str, P], ...] = (
    # embeddings / head: vocab over model, d_model over data (FSDP)
    (r"embed/w$", P("model", "data")),
    (r"lm_head/w$", P("data", "model")),
    # attention projections
    (r"attn/wq$", P("data", "model")),
    (r"attn/wk$", P("data", "model")),
    (r"attn/wv$", P("data", "model")),
    (r"attn/wo$", P("model", "data")),
    (r"attn/b[qkv]$", P("model")),
    (r"attn/(q_norm|k_norm)$", P(None)),
    # dense FFN
    (r"mlp/w(i|g)$", P("data", "model")),
    (r"mlp/wo$", P("model", "data")),
    # MoE: experts over model (EP), FSDP over data
    (r"moe/router$", P("data", None)),
    (r"moe/w(i|g)$", P("model", "data", None)),
    (r"moe/wo$", P("model", None, "data")),
    # RG-LRU block
    (r"rglru/in_[xg]$", P("data", "model")),
    (r"rglru/out$", P("model", "data")),
    (r"rglru/conv_w$", P(None, "model")),
    (r"rglru/(gate_a|gate_x)/w$", P(None, None, "model")),
    (r"rglru/a_param$", P("model")),
    # mLSTM block
    (r"mlstm/up$", P("data", "model")),
    (r"mlstm/down$", P("model", "data")),
    (r"mlstm/conv_w$", P(None, "model")),
    (r"mlstm/(q|k|v)/w$", P("model", None, None)),
    (r"mlstm/(igate|fgate)/w$", P("model", None)),
    (r"mlstm/(igate|fgate)/b$", P(None)),
    (r"mlstm/out_norm$", P("model")),
    # sLSTM block
    (r"slstm/w_(i|f|z|o)$", P("data", "model")),
    (r"slstm/r_(i|f|z|o)$", P(None, None, "model")),
    (r"slstm/b_(i|f|z|o)$", P("model")),
    # frontend projectors
    (r"frontend/.*w.$", P("data", "model")),
    # norms, biases, anything 1-D: replicated
    (r".*", P()),
)


def _spec_for_path(path: str, leading_group_dim: bool) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            if leading_group_dim and len(spec) > 0:
                return P(None, *spec)
            if leading_group_dim:
                return P(None)
            return spec
    raise AssertionError("unreachable")


def spec_matches(path: str, spec_len: int) -> P:
    """Public helper for tests."""
    return _spec_for_path(path, False)


def param_specs_for(shape_tree, pcfg: ParallelConfig):
    """Tree of PartitionSpecs parallel to the param tree.

    Leaves under ``blocks/`` (scan-stacked) get a leading None for the group
    dim. Specs are validated for divisibility against the mesh — any axis
    whose size does not divide falls back to None (replicated) on that dim,
    so every arch lowers on every mesh (e.g. 10-head recurrentgemma on
    model=16).
    """
    from repro.utils.pytree import tree_map_with_path

    sizes = pcfg.axis_sizes

    def leaf(path: str, leaf_spec):
        grouped = "blocks/" in path
        spec = _spec_for_path(path, grouped)
        if pcfg.mesh is None:
            return P()
        # validate divisibility per dim
        dims = []
        for i, ax in enumerate(spec):
            if ax is None:
                dims.append(None)
                continue
            names = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for n in names:
                total *= sizes.get(n, 1)
            if leaf_spec.shape[i] % total == 0:
                dims.append(ax)
            else:
                dims.append(None)
        return P(*dims)

    return tree_map_with_path(leaf, shape_tree)


def shardings_for(shape_tree, pcfg: ParallelConfig):
    """NamedSharding tree (or None when mesh-less)."""
    if pcfg.mesh is None:
        return None
    specs = param_specs_for(shape_tree, pcfg)
    return jax.tree.map(lambda s: NamedSharding(pcfg.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
