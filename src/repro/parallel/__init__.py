from repro.parallel.sharding import (  # noqa: F401
    ParallelConfig,
    batch_spec,
    constrain,
    param_specs_for,
    kv_cache_spec,
)
