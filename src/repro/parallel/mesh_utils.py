"""Mesh helpers shared by launchers and tests."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def single_device_mesh(axes=("data", "model")) -> Mesh:
    """A trivial mesh over however many devices exist (tests / CPU)."""
    n = jax.device_count()
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def validate_mesh(mesh: Mesh, expect_devices: int | None = None) -> None:
    n = int(np.prod(mesh.devices.shape))
    if expect_devices is not None and n != expect_devices:
        raise ValueError(f"mesh has {n} devices, expected {expect_devices}")
