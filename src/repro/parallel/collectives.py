"""Cross-pod ("wide-area") collective schedule — the UDT analogue.

The paper's transport insight: the long-haul hop is the scarce resource;
give it a dedicated protocol and keep bulk traffic local. Mapped to a
multi-pod TPU job (DESIGN.md §2):

  * parameters/optimizer state are sharded *within* a pod and replicated
    *across* pods, so the only cross-pod traffic is one gradient reduction
    per step;
  * that reduction runs hierarchically (in-pod reduce-scatter happens
    automatically through FSDP sharding; the cross-pod hop is explicit here);
  * the cross-pod hop can be compressed: bf16 cast, or int8 with error
    feedback (the residual of quantisation is carried to the next step, so
    compression is unbiased in the long run).

These functions run inside a ``shard_map`` that is *manual* over the ``pod``
axis and *auto* over ``data``/``model`` (``ParallelConfig.mode ==
"podwise"``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax


def cross_pod_mean(grads, *, axis: str = "pod", compress: str = "none",
                   ef_state=None):
    """Mean-reduce a grad pytree over ``axis`` with optional compression.

    Returns (reduced_grads, new_ef_state). ``ef_state`` is required (a
    pytree of fp32 residuals, zeros initially) when ``compress=='int8_ef'``.
    """
    npods = lax.psum(1, axis)

    if compress == "none":
        g = jax.tree.map(lambda x: lax.pmean(x, axis), grads)
        return g, ef_state

    if compress == "bf16":
        def red(x):
            return lax.pmean(x.astype(jnp.bfloat16), axis).astype(x.dtype)
        return jax.tree.map(red, grads), ef_state

    if compress == "int8_ef":
        def red(x, ef):
            xf = x.astype(jnp.float32) + ef
            # shared scale so quantised values are summable across pods
            amax = lax.pmax(jnp.max(jnp.abs(xf)), axis)
            scale = jnp.maximum(amax, 1e-30) / 127.0
            q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
            new_ef = xf - q.astype(jnp.float32) * scale
            # all-gather int8 (the compressed wide-area payload), sum locally
            gathered = lax.all_gather(q, axis)  # [npods, ...] int8
            total = gathered.astype(jnp.int32).sum(0).astype(jnp.float32)
            mean = total * scale / npods
            return mean.astype(x.dtype), new_ef
        flat_g, td = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef_state)
        out = [red(g, e) for g, e in zip(flat_g, flat_e)]
        gs = jax.tree.unflatten(td, [o[0] for o in out])
        es = jax.tree.unflatten(td, [o[1] for o in out])
        return gs, es

    raise ValueError(compress)


def pod_efficiency_ratio(step_time_multi: float, step_time_single: float):
    """The paper's LLPR analogue: multi-pod step time vs single-pod."""
    return step_time_single / max(step_time_multi, 1e-12)
