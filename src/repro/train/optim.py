"""AdamW (from scratch) with fp32 master weights, + LR schedules.

Optimizer state shards exactly like its parameter (ZeRO-style: the sharded
``m``/``v``/``master`` trees inherit the param PartitionSpecs, which are FSDP
over ``data`` x TP over ``model`` and replicated over ``pod``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.common import sds


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # int8_ef cross-pod compression keeps a residual tree in the state
    error_feedback: bool = False


def warmup_cosine(lr: float, warmup: int, total: int) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def state_shapes(param_tree, ocfg: AdamWConfig) -> Dict:
    """ShapeDtypeStruct tree for the optimizer state."""
    def f32(s):
        return sds(s.shape, jnp.float32)
    out = {
        "step": sds((), jnp.int32),
        "m": jax.tree.map(f32, param_tree),
        "v": jax.tree.map(f32, param_tree),
        "master": jax.tree.map(f32, param_tree),
    }
    if ocfg.error_feedback:
        out["ef"] = jax.tree.map(f32, param_tree)
    return out


def init_state(params, ocfg: AdamWConfig):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    out = {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }
    if ocfg.error_feedback:
        out["ef"] = jax.tree.map(jnp.copy, zeros)
    return out


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _decay_mask(path: str) -> bool:
    """Weight decay only on matrices (skip norms/biases/1-D gates)."""
    leaf = path.rsplit("/", 1)[-1]
    return not (leaf in ("scale",) or leaf.startswith("b")
                or leaf.endswith("_norm") or leaf == "a_param")


def apply_updates(params, grads, state, ocfg: AdamWConfig,
                  lr_fn: Callable):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    from repro.utils.pytree import tree_flatten_with_paths

    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if ocfg.grad_clip else jnp.asarray(1.0, jnp.float32)
    lr = lr_fn(state["step"])
    b1, b2 = ocfg.b1, ocfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    paths = [p for p, _ in tree_flatten_with_paths(params)]
    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(state["master"])

    new_p, new_m, new_v, new_w = [], [], [], []
    for path, p, g, m, v, w in zip(paths, flat_p, flat_g, flat_m, flat_v,
                                   flat_w):
        gf = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + ocfg.eps)
        if _decay_mask(path):
            upd = upd + ocfg.weight_decay * w
        w2 = w - lr * upd
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
        new_p.append(w2.astype(p.dtype))

    new_state = dict(state)
    new_state["step"] = step
    new_state["m"] = jax.tree.unflatten(td, new_m)
    new_state["v"] = jax.tree.unflatten(td, new_v)
    new_state["master"] = jax.tree.unflatten(td, new_w)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return jax.tree.unflatten(td, new_p), new_state, metrics
