"""Checkpoints stored *through Sector*: replicated, content-hashed, atomic.

Layout per step:
    ckpt/<tag>/step_<N>.bin            -- packed leaf payload (zlib)
    ckpt/<tag>/step_<N>.manifest.json  -- written LAST = atomic commit point

The manifest carries per-leaf (path, shape, dtype, offset, nbytes) plus a
sha256 of the payload; restore picks the newest step whose manifest exists
AND whose payload hash verifies, so a failure mid-upload can never yield a
half-written restore point. Replication (>=2 sites) comes for free from the
Sector placement policy — a whole-site loss keeps every checkpoint readable
(tested).

bf16 leaves are serialised as exact float32 (bf16<->f32 round-trips
losslessly); everything else is stored raw.
"""
from __future__ import annotations

import hashlib
import io
import json
import re
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sector.client import SectorClient
from repro.utils.pytree import tree_flatten_with_paths


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    dt = jnp.dtype(x.dtype)
    if dt == jnp.bfloat16:
        return np.asarray(jax.device_get(x.astype(jnp.float32))), "bfloat16"
    return np.asarray(jax.device_get(x)), str(dt)


def _from_numpy(arr: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(arr, jnp.bfloat16)
    return jnp.asarray(arr, dtype)


def serialize(tree) -> Tuple[bytes, dict]:
    flat = tree_flatten_with_paths(tree)
    buf = io.BytesIO()
    leaves = []
    for path, leaf in flat:
        arr, dtype = _to_numpy(leaf)
        off = buf.tell()
        buf.write(np.ascontiguousarray(arr).tobytes())
        leaves.append({"path": path, "shape": list(arr.shape),
                       "store_dtype": str(arr.dtype), "dtype": dtype,
                       "offset": off, "nbytes": buf.tell() - off})
    payload = zlib.compress(buf.getvalue(), level=1)
    manifest = {"leaves": leaves,
                "payload_sha256": hashlib.sha256(payload).hexdigest(),
                "payload_bytes": len(payload)}
    return payload, manifest


def deserialize(payload: bytes, manifest: dict, like_tree) -> Any:
    if hashlib.sha256(payload).hexdigest() != manifest["payload_sha256"]:
        raise IOError("checkpoint payload hash mismatch")
    raw = zlib.decompress(payload)
    by_path = {leaf["path"]: leaf for leaf in manifest["leaves"]}
    flat = tree_flatten_with_paths(like_tree)
    leaves = []
    for path, like in flat:
        meta = by_path[path]
        arr = np.frombuffer(
            raw, meta["store_dtype"],
            count=int(np.prod(meta["shape"])) if meta["shape"] else 1,
            offset=meta["offset"]).reshape(meta["shape"])
        leaves.append(_from_numpy(arr, meta["dtype"]))
    treedef = jax.tree.structure(like_tree)
    return jax.tree.unflatten(treedef, leaves)


class SectorCheckpointer:
    def __init__(self, client: SectorClient, tag: str,
                 replication: int = 2, keep: int = 3):
        self.client = client
        self.tag = tag
        self.replication = replication
        self.keep = keep

    def _bin(self, step: int) -> str:
        return f"ckpt/{self.tag}/step_{step:08d}.bin"

    def _man(self, step: int) -> str:
        return f"ckpt/{self.tag}/step_{step:08d}.manifest.json"

    def save(self, step: int, state: dict) -> None:
        """state: {'params':..., 'opt':..., 'extra': dict}."""
        payload, manifest = serialize(
            {"params": state["params"], "opt": state["opt"]})
        manifest["extra"] = state.get("extra", {})
        manifest["step"] = step
        self.client.upload(self._bin(step), payload,
                           replication=self.replication)
        self.client.upload(
            self._man(step), json.dumps(manifest).encode(),
            replication=self.replication)   # manifest last = commit
        self._gc()

    def steps(self) -> list:
        pat = re.compile(
            rf"ckpt/{re.escape(self.tag)}/step_(\d+)\.manifest\.json$")
        out = []
        for name in self.client.master.files:
            m = pat.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore_latest(self, like: dict) -> Optional[dict]:
        """like: {'params': shapes-or-arrays, 'opt': ...}. Tries newest
        first; skips corrupt/incomplete checkpoints."""
        for step in reversed(self.steps()):
            try:
                manifest = json.loads(
                    self.client.download(self._man(step)).decode())
                payload = self.client.download(self._bin(step))
                tree = deserialize(payload, manifest,
                                   {"params": like["params"],
                                    "opt": like["opt"]})
                return {"step": step, "params": tree["params"],
                        "opt": tree["opt"],
                        "extra": manifest.get("extra", {})}
            except (IOError, KeyError, FileNotFoundError) as e:
                continue
        return None

    def _gc(self) -> None:
        steps = self.steps()
        for step in steps[:-self.keep]:
            for name in (self._bin(step), self._man(step)):
                fm = self.client.master.files.pop(name, None)
                if fm is None:
                    continue
                for cid in fm.chunk_ids:
                    ck = self.client.master.chunks.pop(cid, None)
                    if ck is None:
                        continue
                    for sid in ck.locations:
                        srv = self.client.master.servers.get(sid)
                        if srv is not None and srv.alive:
                            srv.delete_chunk(cid)
