"""Train / serve step builders + the sharding trees the launcher jits with.

``make_train_step`` returns (step_fn, sharding trees). Two modes:

  * ``pjit``    — one global jit; XLA inserts every collective (baseline).
  * ``podwise`` — the step body runs in a ``shard_map`` that is *manual*
    over the ``pod`` axis and *auto* over ``data``/``model``: each pod
    computes its gradient with intra-pod FSDP/TP collectives, then the
    **only cross-pod traffic** is the explicit (optionally compressed)
    gradient reduction — the paper's wide-area transport discipline.

A training step is literally a two-stage Sphere job (DESIGN.md §2):
stage 1 = local fwd/bwd UDF over the pod's chunk of the batch,
shuffle = the cross-pod gradient reduction, stage 2 = optimizer UDF.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model
from repro.parallel import collectives
from repro.parallel.sharding import (ParallelConfig, batch_spec,
                                     kv_cache_spec, param_specs_for)
from repro.train import optim
from repro.utils.jax_compat import shard_map_partial
from repro.utils.pytree import tree_map_with_path


# ---------------------------------------------------------------------------
# Sharding spec trees
# ---------------------------------------------------------------------------

def batch_specs_for(batch_tree, pcfg: ParallelConfig):
    """Every batch leaf shards its leading (global-batch) dim — unless the
    batch does not divide the data axes (e.g. long_500k's batch=1)."""
    from repro.parallel.sharding import validate_spec

    def leaf(s):
        spec = batch_spec(pcfg, *([None] * (len(s.shape) - 1)))
        return validate_spec(spec, s.shape, pcfg.axis_sizes)

    return jax.tree.map(leaf, batch_tree)


def opt_state_specs_for(param_tree, pcfg: ParallelConfig,
                        ocfg: optim.AdamWConfig):
    pspecs = param_specs_for(param_tree, pcfg)
    out = {"step": P(), "m": pspecs, "v": pspecs, "master": pspecs}
    if ocfg.error_feedback:
        out["ef"] = jax.tree.map(
            lambda s: P("pod", *s) if pcfg.multi_pod else s, pspecs,
            is_leaf=lambda x: isinstance(x, P))
    return out


def cache_specs_for(cache_tree, pcfg: ParallelConfig):
    """PartitionSpecs for a decode cache / recurrent state tree.

    Leaves are [G, B, ...]: group dim replicated, batch over (pod, data),
    then for KV caches heads over ``model`` when divisible else the sequence
    dim (flash-decoding); recurrent states shard their first model-divisible
    feature dim.
    """
    if pcfg.mesh is None:
        return jax.tree.map(lambda s: P(), cache_tree)
    from repro.parallel.sharding import validate_spec
    b = pcfg.data_axes if len(pcfg.data_axes) > 1 else pcfg.data_axes[0]
    msz = pcfg.model_size

    def leaf(path: str, s):
        name = path.split("/")[-1]
        shape = s.shape
        if name in ("k", "v", "xk", "xv"):
            g, bb, S, K, D = shape
            if K % msz == 0:
                spec = P(None, b, None, "model", None)
            elif S % msz == 0:
                spec = P(None, b, "model", None, None)
            else:
                spec = P(None, b, None, None, None)
        elif name == "kpos":
            S = shape[2]
            spec = P(None, b, "model") if S % msz == 0 else P(None, b, None)
        else:
            # recurrent state: [G, B, ...feature dims]
            dims = [None, b]
            placed = False
            for d in shape[2:]:
                if not placed and d % msz == 0 and d >= msz:
                    dims.append("model")
                    placed = True
                else:
                    dims.append(None)
            spec = P(*dims)
        return validate_spec(spec, shape, pcfg.axis_sizes)

    return tree_map_with_path(leaf, cache_tree)


def to_shardings(spec_tree, mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _value_and_grad_accum(params, batch, *, cfg, pcfg):
    """fwd/bwd with optional gradient accumulation over microbatches.

    With ``accum_steps > 1`` the global batch is split along dim 0 and
    scanned, accumulating fp32 grads — activation memory divides by
    ``accum_steps`` at the cost of re-running the (already FSDP-gathered)
    weights per microbatch."""
    n = pcfg.accum_steps
    if n <= 1:
        return jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, cfg=cfg, pcfg=pcfg),
            has_aux=True)(params)

    def split(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    gfn = jax.value_and_grad(
        lambda p, b: model.loss_fn(p, b, cfg=cfg, pcfg=pcfg),
        has_aux=True)

    def body(acc, mb):
        (loss, metrics), grads = gfn(params, mb)
        acc_g, acc_l, acc_m = acc
        acc_g = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n, acc_g, grads)
        acc_m = jax.tree.map(lambda a, m: a + m / n, acc_m, metrics)
        return (acc_g, acc_l + loss / n, acc_m), None

    zero_g = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zero_m = {k: jnp.zeros((), jnp.float32)
              for k in ("nll", "z_loss", "accuracy", "tokens", "aux_loss")}
    if pcfg.unroll_scans:
        acc = (zero_g, jnp.zeros((), jnp.float32), zero_m)
        for i in range(n):
            acc, _ = body(acc, jax.tree.map(lambda x: x[i], micro))
    else:
        acc, _ = jax.lax.scan(
            body, (zero_g, jnp.zeros((), jnp.float32), zero_m), micro)
    grads, loss, metrics = acc
    return (loss, metrics), grads


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    ocfg: optim.AdamWConfig, lr_fn: Callable):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    if pcfg.mode == "pjit" or not pcfg.multi_pod:
        def step(params, opt_state, batch):
            (loss, metrics), grads = _value_and_grad_accum(
                params, batch, cfg=cfg, pcfg=pcfg)
            new_params, new_opt, om = optim.apply_updates(
                params, grads, opt_state, ocfg, lr_fn)
            return new_params, new_opt, {**metrics, **om, "loss": loss}
        return step

    if pcfg.mode != "podwise":
        raise ValueError(pcfg.mode)

    inner_pcfg = pcfg.with_(multi_pod=False)  # inside: pod axis is manual

    def pod_body(params, opt_state, batch):
        (loss, metrics), grads = _value_and_grad_accum(
            params, batch, cfg=cfg, pcfg=inner_pcfg)
        ef = opt_state.get("ef")
        grads, new_ef = collectives.cross_pod_mean(
            grads, axis="pod", compress=pcfg.compress_pod, ef_state=ef)
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), metrics)
        new_params, new_opt, om = optim.apply_updates(
            params, grads, opt_state, ocfg, lr_fn)
        if new_ef is not None:
            new_opt["ef"] = new_ef
        return new_params, new_opt, {**metrics, **om, "loss": loss}

    def step(params, opt_state, batch):
        pshape = model.param_shapes(cfg)
        rep = jax.tree.map(lambda s: P(), pshape)
        opt_in = {"step": P(), "m": rep, "v": rep, "master": rep}
        if "ef" in opt_state:
            opt_in["ef"] = jax.tree.map(lambda s: P("pod"), pshape)
        batch_in = jax.tree.map(lambda x: P("pod"), batch)
        out_specs = (rep, dict(opt_in), jax.tree.map(lambda _: P(),
                     {"nll": 0, "z_loss": 0, "accuracy": 0, "tokens": 0,
                      "aux_loss": 0, "grad_norm": 0, "lr": 0, "loss": 0}))
        fn = shard_map_partial(pod_body, mesh=pcfg.mesh,
                               in_specs=(rep, opt_in, batch_in),
                               out_specs=out_specs,
                               manual_axes={"pod"})  # manual over pod only
        return fn(params, opt_state, batch)

    return step


def train_state_specs(cfg: ModelConfig, pcfg: ParallelConfig,
                      ocfg: optim.AdamWConfig, batch_tree):
    pshapes = model.param_shapes(cfg)
    return (param_specs_for(pshapes, pcfg),
            opt_state_specs_for(pshapes, pcfg, ocfg),
            batch_specs_for(batch_tree, pcfg))


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, pcfg: ParallelConfig):
    """Greedy decode step: (params, cache, token [B,1], pos [B]) ->
    (next_token [B,1], new_cache)."""

    def serve_step(params, cache, token, pos):
        logits, new_cache = model.decode_step(params, cache, token, pos,
                                              cfg=cfg, pcfg=pcfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig,
                      max_len: int = 0):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cfg=cfg, pcfg=pcfg,
                             max_len=max_len)
    return prefill_step
