from repro.train import optim  # noqa: F401
from repro.train.checkpoint import SectorCheckpointer  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
