"""Elastic scaling: survive device/host loss by remeshing + restoring.

The 1000+-node posture (DESIGN.md §8): when a host dies mid-run,
  1. the failure is detected (heartbeat timeout on the Sector side; a raised
     device error on the JAX side),
  2. the controller rebuilds a mesh without the lost host's devices — the
     mesh shape shrinks along the ``data`` (or ``pod``) axis, never
     ``model`` (TP degree is a property of the checkpointed layout),
  3. the latest committed Sector checkpoint (params + optimizer + data
     cursor) is restored onto the new mesh — placement is re-derived from
     the PartitionSpecs, which are mesh-shape-agnostic,
  4. training resumes; the consistent-hash ring keeps chunk reassignment to
     ~1/n.

On this CPU harness the "failure" is injected (a callback raising
``HostFailure`` at a chosen step) and meshes are host-device meshes, but the
remesh/restore path is the production code path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import jax

from repro.train.trainer import Trainer


class HostFailure(RuntimeError):
    pass


@dataclass
class ElasticController:
    trainer: Trainer
    make_mesh: Callable[[int], object]  # n_devices -> Mesh
    max_restarts: int = 3

    def run_with_failures(self, steps: int,
                          fail_at: Optional[List[int]] = None) -> dict:
        """Run ``steps`` steps; inject HostFailure at the given step indices
        (simulating a lost host), remesh with one fewer 'device group', and
        resume from the last committed checkpoint."""
        fail_at = sorted(fail_at or [])
        restarts = 0
        lost_groups = 0
        done = self.trainer.step_idx
        target = done + steps
        while done < target:
            next_fail = fail_at[0] if fail_at else None
            try:
                run_until = min(target,
                                next_fail if next_fail is not None
                                else target)
                n = run_until - done
                if n > 0:
                    self.trainer.run(n)
                done = self.trainer.step_idx
                if next_fail is not None and done >= next_fail:
                    fail_at.pop(0)
                    raise HostFailure(f"injected at step {done}")
            except HostFailure:
                restarts += 1
                lost_groups += 1
                if restarts > self.max_restarts:
                    raise
                # --- remesh: drop one group of devices, rebuild, restore ---
                n_dev = max(1, jax.device_count() - lost_groups)
                new_mesh = self.make_mesh(n_dev)
                self.trainer.pcfg = self.trainer.pcfg.with_(mesh=new_mesh)
                self.trainer._build()  # re-jit + restore from checkpoint
                done = self.trainer.step_idx
        return {"restarts": restarts, "final_step": done,
                "history": self.trainer.history}
