"""The training loop: Sector data -> Sphere-staged step -> Sector checkpoints."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataPipeline
from repro.models import model
from repro.parallel.sharding import ParallelConfig, param_specs_for
from repro.train import optim
from repro.train.checkpoint import SectorCheckpointer
from repro.train.step import (make_train_step,
                              opt_state_specs_for, to_shardings)


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig,
                 tcfg: TrainerConfig, pipeline: DataPipeline,
                 checkpointer: Optional[SectorCheckpointer] = None):
        self.cfg = cfg
        self.pcfg = pcfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.ckpt = checkpointer
        self.ocfg = optim.AdamWConfig(
            lr=tcfg.lr,
            error_feedback=(pcfg.compress_pod == "int8_ef"))
        self.lr_fn = optim.warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)
        self.history: List[Dict] = []
        self.step_idx = 0
        self._build()

    def _build(self) -> None:
        cfg, pcfg = self.cfg, self.pcfg
        params = model.init_params(cfg, jax.random.PRNGKey(self.tcfg.seed))
        opt = optim.init_state(params, self.ocfg)
        restored = None
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(
                {"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            self.step_idx = restored["step"]
            if "cursor" in restored.get("extra", {}):
                self.pipeline.load_state_dict(restored["extra"]["cursor"])
        if pcfg.mesh is not None:
            pshapes = model.param_shapes(cfg)
            psh = to_shardings(param_specs_for(pshapes, pcfg), pcfg.mesh)
            osh = to_shardings(
                opt_state_specs_for(pshapes, pcfg, self.ocfg), pcfg.mesh)
            params = jax.device_put(params, psh)
            opt = jax.device_put(opt, osh)
        self.params, self.opt = params, opt
        step_fn = make_train_step(cfg, pcfg, self.ocfg, self.lr_fn)
        self._step = jax.jit(step_fn,
                             donate_argnums=(0, 1) if pcfg.donate else ())

    def run(self, steps: Optional[int] = None) -> List[Dict]:
        n = steps or self.tcfg.steps
        it = iter(self.pipeline)
        t0 = time.time()
        for _ in range(n):
            batch = next(it)
            self.params, self.opt, metrics = self._step(
                self.params, self.opt, batch)
            self.step_idx += 1
            if self.step_idx % self.tcfg.log_every == 0 or \
                    self.step_idx == n:
                rec = {k: float(v) for k, v in metrics.items()}
                rec["step"] = self.step_idx
                rec["wall_s"] = time.time() - t0
                self.history.append(rec)
            if self.ckpt is not None and \
                    self.step_idx % self.tcfg.ckpt_every == 0:
                self.save_checkpoint()
        return self.history

    def save_checkpoint(self) -> None:
        self.ckpt.save(self.step_idx, {
            "params": self.params, "opt": self.opt,
            "extra": {"cursor": self.pipeline.state_dict()},
        })
