"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full softmax


def sample(logits: jax.Array, rng: jax.Array,
           scfg: SamplerConfig) -> jax.Array:
    """logits: [B, V] -> tokens [B] int32."""
    if scfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / scfg.temperature
    if scfg.top_k:
        kth = jax.lax.top_k(lf, scfg.top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)
