from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.sampler import SamplerConfig, sample  # noqa: F401
