"""Serving engine with continuous batching over a fixed slot pool.

Decode runs as one jitted step over ``max_batch`` slots; requests stream in
and out of slots without recompilation (continuous batching). Prefill is a
second jitted program (batch=1) whose cache is spliced into the pool at the
slot index. Finished slots (EOS or token budget) are recycled immediately.

The KV pool is the serving twin of Sector's "data waits for the task": the
cache shards stay resident on their devices; requests are routed to slots,
never the other way around.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model
from repro.parallel.sharding import NO_PARALLEL, ParallelConfig
from repro.serve.sampler import SamplerConfig, sample


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 32
    enc_frames: Optional[np.ndarray] = None
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 pcfg: ParallelConfig = NO_PARALLEL,
                 max_batch: int = 4, max_len: int = 256,
                 eos_id: int = -1,
                 scfg: SamplerConfig = SamplerConfig()):
        self.cfg = cfg
        self.params = params
        self.pcfg = pcfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.scfg = scfg
        cross = max_len if cfg.is_encoder_decoder else 0
        self.cache = model.init_cache(cfg, max_batch, max_len,
                                      cross_len=cross)
        self.pos = np.zeros(max_batch, np.int32)
        self.tok = np.zeros(max_batch, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.rng = jax.random.PRNGKey(0)
        self._rid = 0

        self._decode = jax.jit(
            lambda p, c, t, q: model.decode_step(p, c, t, q, cfg=cfg,
                                                 pcfg=pcfg))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg=cfg, pcfg=pcfg,
                                       max_len=max_len))
        self._insert = jax.jit(self._insert_impl)

    @staticmethod
    def _insert_impl(pool, new, slot):
        def put(a, b):
            # a: [G, B, ...]; b: [G, 1, ...]
            idx = (0, slot) + (0,) * (a.ndim - 2)
            return jax.lax.dynamic_update_slice(a, b.astype(a.dtype), idx)
        return jax.tree.map(put, pool, new)

    # ------------------------------------------------------------- requests
    def submit(self, prompt: List[int], max_new: int = 32,
               enc_frames: Optional[np.ndarray] = None) -> Request:
        req = Request(self._rid, list(prompt), max_new, enc_frames)
        self._rid += 1
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            batch = {"inputs": jnp.asarray([req.prompt], jnp.int32)}
            if self.cfg.is_encoder_decoder:
                frames = req.enc_frames
                if frames is None:
                    frames = np.zeros((1, self.max_len, self.cfg.d_model),
                                      np.float32)
                batch["enc_frames"] = jnp.asarray(frames, jnp.bfloat16)
            last_logits, cache1 = self._prefill(self.params, batch)
            self.cache = self._insert(self.cache, cache1,
                                      jnp.asarray(slot, jnp.int32))
            self.rng, k = jax.random.split(self.rng)
            tok = int(sample(last_logits, k, self.scfg)[0])
            req.out.append(tok)
            self.slot_req[slot] = req
            self.pos[slot] = len(req.prompt)
            self.tok[slot] = tok

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """One batched decode step. Returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tok = jnp.asarray(self.tok[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        self.rng, k = jax.random.split(self.rng)
        nxt = np.asarray(sample(logits, k, self.scfg))
        for slot in active:
            req = self.slot_req[slot]
            t = int(nxt[slot])
            req.out.append(t)
            self.pos[slot] += 1
            self.tok[slot] = t
            if t == self.eos_id or len(req.out) >= req.max_new or \
                    self.pos[slot] >= self.max_len - 1:
                req.done = True
                self.slot_req[slot] = None  # recycle immediately
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
