"""Pytree helpers shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_flatten_with_paths(tree):
    """Returns [(path_str, leaf), ...]."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(path), leaf) for path, leaf in flat]


def tree_map_with_path(fn, tree):
    """Map fn(path_str, leaf) over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_str(path), leaf), tree
    )
