from repro.utils.pytree import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_map_with_path,
    tree_zeros_like,
)

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_paths",
    "tree_map_with_path",
    "tree_zeros_like",
]
