"""Version-compat shims so the repo runs on jax 0.4.x and >= 0.6.

The newer shard_map API spells partial-manual mode ``axis_names={...},
check_vma=False``; jax 0.4.x spells the same thing ``auto=<complement>,
check_rep=False``.  ``shard_map_partial`` translates.
"""
from __future__ import annotations

from typing import FrozenSet, Iterable, Optional

try:
    from jax import shard_map as _shard_map  # jax >= 0.6
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _new_api() -> bool:
    import inspect
    try:
        return "axis_names" in inspect.signature(_shard_map).parameters
    except (TypeError, ValueError):  # pragma: no cover
        return False


# The 0.4.x ``auto=`` spelling works for simple partial-manual regions but
# XLA can hit fatal sharding checks on psum-over-subgroup patterns (the
# podwise train step); callers that need those patterns should gate on this.
PARTIAL_MANUAL_ROBUST = _new_api()


def shard_map_partial(f, *, mesh, in_specs, out_specs,
                      manual_axes: Optional[Iterable[str]] = None):
    """shard_map, optionally manual over only ``manual_axes``."""
    if manual_axes is None:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    manual: FrozenSet[str] = frozenset(manual_axes)
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False,
                          axis_names=manual)
    except TypeError:  # jax 0.4.x: auto = the axes that stay automatic
        auto = frozenset(mesh.axis_names) - manual
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False, auto=auto)
