"""Sphere-on-SPMD: the paper's stage/shuffle model on the TPU mesh.

A Sphere stage is an embarrassingly-parallel UDF over the chunks resident on
each node; on the device mesh that is exactly a ``shard_map`` body over the
``data`` axis. The Sphere shuffle is ``lax.all_to_all``. The training step
is a two-stage Sphere job (fwd/bwd UDF -> gradient shuffle -> optimizer
UDF); this module exposes the generic combinators plus the distributed sort
(TeraSort, Table 3) built from them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

SENTINEL = jnp.uint32(0xFFFFFFFF)


def sphere_map(udf: Callable, mesh: Mesh, axis: str = "data"):
    """Lift a per-shard UDF into a distributed Sphere stage."""
    def stage(x):
        fn = _shard_map(udf, mesh=mesh,
                        in_specs=P(axis), out_specs=P(axis))
        return fn(x)
    return stage


def sphere_shuffle(x: jax.Array, bucket_of_shard: Callable, mesh: Mesh,
                   axis: str = "data"):
    """all_to_all exchange: element (i, j) of the per-shard [n, cap] send
    buffer goes to shard i."""
    def body(buf):
        return lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    fn = _shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(x)


# ---------------------------------------------------------------------------
# Distributed sort (TeraSort) — sample, bucketize, all_to_all, local sort
# ---------------------------------------------------------------------------

def distributed_sort(keys: jax.Array, mesh: Mesh, axis: str = "data",
                     oversample: int = 4):
    """Sort uint32 keys sharded over ``axis``.

    Returns (sorted_padded, valid): per-shard ascending keys padded with
    SENTINEL; ``valid`` counts real keys per shard. Global order =
    concatenation of shards in axis order (asserted in tests).
    """
    n = mesh.shape[axis]

    def body(local):
        local = local.reshape(-1)
        m = local.shape[0]
        cap = 2 * m  # bucket capacity (skew headroom)

        # --- stage 1 (sample UDF): boundary estimation ---------------------
        samp_n = min(n * oversample, m)
        stride = max(m // samp_n, 1)
        samples = jnp.sort(local)[::stride][:samp_n]
        all_samples = lax.all_gather(samples, axis, tiled=True)
        ssorted = jnp.sort(all_samples)
        step = ssorted.shape[0] // n
        bounds = ssorted[step::step][: n - 1]  # [n-1]

        # --- shuffle: bucketize + fixed-capacity all_to_all -----------------
        bucket = jnp.searchsorted(bounds, local, side="right")  # [m]
        order = jnp.argsort(bucket)
        sk = local[order]
        sb = bucket[order]
        # position within bucket via cumulative count
        onehot = jax.nn.one_hot(sb, n, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, sb[:, None], axis=1)[:, 0]
        send = jnp.full((n, cap), SENTINEL, jnp.uint32)
        ok = pos < cap
        send = send.at[jnp.where(ok, sb, 0), jnp.where(ok, pos, 0)].set(
            jnp.where(ok, sk, SENTINEL), mode="drop")
        recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=True)  # [n, cap] from each peer

        # --- stage 2 (sort UDF): local sort of owned bucket ------------------
        flat = recv.reshape(-1)
        out = jnp.sort(flat)
        valid = jnp.sum((flat != SENTINEL).astype(jnp.int32))
        return out, valid[None]

    fn = _shard_map(body, mesh=mesh, in_specs=P(axis),
                    out_specs=(P(axis), P(axis)))
    return fn(keys)


def barrier_sort(keys: jax.Array, mesh: Mesh, axis: str = "data"):
    """Hadoop-style comparison point: gather everything to every node, sort,
    keep your slice — the no-locality, all-data-moves baseline."""
    n = mesh.shape[axis]

    def body(local):
        local = local.reshape(-1)
        allk = lax.all_gather(local, axis, tiled=True)
        ssorted = jnp.sort(allk)
        m = ssorted.shape[0] // n
        idx = lax.axis_index(axis)
        return lax.dynamic_slice_in_dim(ssorted, idx * m, m)

    fn = _shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(keys)
