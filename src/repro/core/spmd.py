"""Sphere-on-SPMD: the paper's stage/shuffle model on the TPU mesh.

A Sphere stage is an embarrassingly-parallel UDF over the chunks resident on
each node; on the device mesh that is exactly a ``shard_map`` body over the
``data`` axis. The Sphere shuffle is ``lax.all_to_all``. The training step
is a two-stage Sphere job (fwd/bwd UDF -> gradient shuffle -> optimizer
UDF); this module exposes the generic combinators plus the distributed sort
(TeraSort, Table 3) built from them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

SENTINEL = jnp.uint32(0xFFFFFFFF)


def sphere_map(udf: Callable, mesh: Mesh, axis: str = "data"):
    """Lift a per-shard UDF into a distributed Sphere stage.

    Variadic: every argument (and the result) is sharded over ``axis``
    along its leading dimension — e.g. the engine's fused stage apply
    passes (stacked data, per-slot valid counts)."""
    def stage(*xs):
        fn = _shard_map(udf, mesh=mesh,
                        in_specs=tuple(P(axis) for _ in xs),
                        out_specs=P(axis))
        return fn(*xs)
    return stage


def sphere_shuffle(x: jax.Array, bucket_of_shard: Callable, mesh: Mesh,
                   axis: str = "data"):
    """all_to_all exchange: element (i, j) of the per-shard [n, cap] send
    buffer goes to shard i."""
    def body(buf):
        return lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    fn = _shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(x)


def fused_scatter_round(data: jax.Array, n_valids: jax.Array, bounds,
                        *, key_spec, n_buckets: int, n_workers: int,
                        mesh: Mesh, axis: str = "data",
                        interpret: bool | None = None):
    """The engine's fused shuffle round lowered through ``shard_map``:
    per-shard key extraction + ``bucket_partition`` kernel, the exchange
    as ``lax.all_to_all``, and on-device regrouping onto destination
    workers — the multi-device twin of the host-driven
    ``scatter_round_dispatch`` harvest, sharing its record ordering
    contract exactly.

    ``data`` is uint8 [S, rows, width] — the engine's stacked round,
    slots ordered worker-major and sharded contiguously over ``axis``
    (S must divide by the mesh size D) — and ``n_valids`` its int32 [S]
    valid-count vector.  ``n_workers`` must divide by D; worker ``w``
    is resident on device ``w // (n_workers // D)`` and owns buckets
    ``{b : b % n_workers == w}``.

    Returns ``(parts, counts, hist_sb)``:

    * ``parts`` uint8 [n_workers, cap, width] (sharded over ``axis``) —
      worker ``w``'s regrouped partition in slot ``w``: its buckets in
      ascending order, records within a bucket in (slot-major, then
      input) order.  ``cap`` is the static all_to_all capacity
      (D * local rows); tails are junk.
    * ``counts`` int32 [n_workers] — valid prefixes of ``parts``.
    * ``hist_sb`` int32 [S, n_buckets] — the per-slot histogram, the ONE
      metadata array the executor syncs for movement accounting.

    Per-shard work stays a single fused program: the send buffer is
    packed with the one-stable-argsort + section-offset idiom of
    :func:`distributed_sort`, with an int32 bucket-id sidecar (−1 =
    empty) exchanged alongside the rows so the receiver can regroup
    without a second metadata round-trip.
    """
    from repro.core.shuffle import _extract_keys, _kernel_partition

    D = mesh.shape[axis]
    if n_workers % D or data.shape[0] % D:
        raise ValueError(f"fused_scatter_round needs S ({data.shape[0]}) "
                         f"and n_workers ({n_workers}) divisible by the "
                         f"mesh size ({D})")
    wpd = n_workers // D
    rows, width = data.shape[1], data.shape[2]
    bounds_np = bounds

    def body(local, nv):
        s_l = local.shape[0]
        m = s_l * rows
        flat = local.reshape(m, width)
        keys = _extract_keys(flat, key_spec)
        ids, _ = _kernel_partition(keys, bounds_np, n_buckets,
                                   interpret=interpret)
        pos = lax.iota(jnp.int32, m)
        slot = pos // rows
        valid = (pos % rows) < nv[slot]
        hist_sb = jnp.zeros((s_l, n_buckets), jnp.int32) \
            .at[slot, ids].add(valid.astype(jnp.int32))
        # --- sender: rows sorted by (dest device, bucket), stable, then
        # scattered into per-destination sections of the send buffer
        e = (ids % n_workers) // wpd                        # dest device
        skey = jnp.where(valid, e * (n_buckets + 1) + ids,
                         D * (n_buckets + 1))               # invalid last
        order = jnp.argsort(skey)                           # stable
        se, sb, sv = e[order], ids[order], valid[order]
        srows = flat[order]
        sec_count = jnp.sum(
            jnp.where(valid[:, None],
                      jax.nn.one_hot(e, D, dtype=jnp.int32), 0), axis=0)
        sec_start = jnp.cumsum(sec_count) - sec_count
        pos_in = lax.iota(jnp.int32, m) - sec_start[se]
        se_ = jnp.where(sv, se, D)                          # D = dropped
        send = jnp.zeros((D, m, width), jnp.uint8) \
            .at[se_, pos_in].set(srows, mode="drop")
        meta = jnp.full((D, m), -1, jnp.int32) \
            .at[se_, pos_in].set(sb, mode="drop")
        recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=True)
        rmeta = lax.all_to_all(meta, axis, split_axis=0, concat_axis=0,
                               tiled=True)
        # --- receiver: one stable sort by (local worker, bucket) lands
        # every incoming row in its worker's bucket-ordered partition;
        # source sections arrive device-major, so ties keep slot-major
        # input order — the host harvest's ordering contract
        n2 = D * m
        rb = rmeta.reshape(n2)
        rr = recv.reshape(n2, width)
        dev = lax.axis_index(axis)
        rkey = jnp.where(rb >= 0,
                         ((rb % n_workers) - dev * wpd) * (n_buckets + 1)
                         + rb,
                         wpd * (n_buckets + 1))
        rorder = jnp.argsort(rkey)                          # stable
        sr = rr[rorder]
        srb = rb[rorder]
        srv = srb >= 0
        sli = jnp.where(srv, (srb % n_workers) - dev * wpd, wpd)
        sli_c = jnp.clip(sli, 0, wpd - 1)
        wcount = jnp.sum(
            jnp.where(srv[:, None],
                      jax.nn.one_hot(sli_c, wpd, dtype=jnp.int32), 0),
            axis=0)
        wstart = jnp.cumsum(wcount) - wcount
        posw = lax.iota(jnp.int32, n2) - wstart[sli_c]
        out = jnp.zeros((wpd, n2, width), jnp.uint8) \
            .at[sli, posw].set(sr, mode="drop")             # wpd = dropped
        return out, wcount, hist_sb

    # check_rep=False: shard_map has no replication rule for pallas_call
    # (the bucket_partition kernel); every output is explicitly sharded
    # over ``axis`` anyway, so replication tracking buys nothing here.
    fn = _shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                    out_specs=(P(axis), P(axis), P(axis)),
                    check_rep=False)
    return fn(data, n_valids)


# ---------------------------------------------------------------------------
# Distributed sort (TeraSort) — sample, bucketize, all_to_all, local sort
# ---------------------------------------------------------------------------

def distributed_sort(keys: jax.Array, mesh: Mesh, axis: str = "data",
                     oversample: int = 4):
    """Sort uint32 keys sharded over ``axis``.

    Returns (sorted_padded, valid): per-shard ascending keys padded with
    SENTINEL; ``valid`` counts real keys per shard. Global order =
    concatenation of shards in axis order (asserted in tests).
    """
    n = mesh.shape[axis]

    def body(local):
        local = local.reshape(-1)
        m = local.shape[0]
        cap = 2 * m  # bucket capacity (skew headroom)

        # --- stage 1 (sample UDF): boundary estimation ---------------------
        samp_n = min(n * oversample, m)
        stride = max(m // samp_n, 1)
        samples = jnp.sort(local)[::stride][:samp_n]
        all_samples = lax.all_gather(samples, axis, tiled=True)
        ssorted = jnp.sort(all_samples)
        step = ssorted.shape[0] // n
        bounds = ssorted[step::step][: n - 1]  # [n-1]

        # --- shuffle: bucketize + fixed-capacity all_to_all -----------------
        bucket = jnp.searchsorted(bounds, local, side="right")  # [m]
        order = jnp.argsort(bucket)
        sk = local[order]
        sb = bucket[order]
        # position within bucket via cumulative count
        onehot = jax.nn.one_hot(sb, n, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, sb[:, None], axis=1)[:, 0]
        send = jnp.full((n, cap), SENTINEL, jnp.uint32)
        ok = pos < cap
        send = send.at[jnp.where(ok, sb, 0), jnp.where(ok, pos, 0)].set(
            jnp.where(ok, sk, SENTINEL), mode="drop")
        recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=True)  # [n, cap] from each peer

        # --- stage 2 (sort UDF): local sort of owned bucket ------------------
        flat = recv.reshape(-1)
        out = jnp.sort(flat)
        valid = jnp.sum((flat != SENTINEL).astype(jnp.int32))
        return out, valid[None]

    fn = _shard_map(body, mesh=mesh, in_specs=P(axis),
                    out_specs=(P(axis), P(axis)))
    return fn(keys)


def barrier_sort(keys: jax.Array, mesh: Mesh, axis: str = "data"):
    """Hadoop-style comparison point: gather everything to every node, sort,
    keep your slice — the no-locality, all-data-moves baseline."""
    n = mesh.shape[axis]

    def body(local):
        local = local.reshape(-1)
        allk = lax.all_gather(local, axis, tiled=True)
        ssorted = jnp.sort(allk)
        m = ssorted.shape[0] // n
        idx = lax.axis_index(axis)
        return lax.dynamic_slice_in_dim(ssorted, idx * m, m)

    fn = _shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(keys)
