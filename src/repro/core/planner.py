"""Sphere control plane: pure locality/speculation planner (paper §4).

``SpherePlanner`` is the scheduling half of the engine's planner/executor
split.  It decides *where* every task runs and *how long* the stage takes
in simulated time — locality first, then least-(estimated)-loaded, with
speculative re-execution of observed stragglers on replicas — without
touching any data.  Its only effect is the returned :class:`StagePlan`,
so scheduling behaviour is unit-testable with no Sector cloud at all:
callers inject ``move_time(nbytes, src_worker, dst_worker)`` and per-
worker ``speeds``; identical inputs always produce identical plans.

Scheduling uses ESTIMATED speeds (uniform — the scheduler does not know a
node is slow until it runs); execution reveals actual speeds, and
speculation re-runs the surprises on replicas.  This mirrors the paper's
load balancing: replicas exist precisely so slow nodes can be routed
around after the fact.

The data-plane half (fetching chunks, running UDFs, bucketizing records)
lives in :mod:`repro.core.executor`; :class:`repro.core.engine.SphereEngine`
glues the two together.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

PROCESS_RATE = 400e6  # bytes/s of UDF processing on a speed-1.0 worker

# simulated seconds to move nbytes between two workers' sites
MoveTime = Callable[[int, str, str], float]


@dataclass
class SphereReport:
    sim_seconds: float = 0.0
    bytes_moved: int = 0
    bytes_local: int = 0
    tasks: int = 0
    speculated: int = 0
    speculation_wins: int = 0
    retried: int = 0
    locality_fraction: float = 1.0
    stage_seconds: List[float] = field(default_factory=list)
    # REAL wall-clock spent computing bucket assignments + scattering
    # records in shuffles (everything else above is simulated time) —
    # the bytes-vs-array backend comparison the benchmarks report.
    partition_seconds: float = 0.0
    partitioned_records: int = 0
    # array backend: number of distinct shapes each pad-stable stage UDF
    # was traced with (1 = the jit-once guarantee held for that stage)
    udf_traces: Dict[str, int] = field(default_factory=dict)
    # streams/sessions: stage-0 tasks that got FRESH replica placement
    # this run vs. tasks replayed from a cached per-file plan — the
    # delta-planning guarantee ("only new chunks are planned") is
    # asserted on these two counters.
    planned_tasks: int = 0
    reused_tasks: int = 0
    # overlap accounting for the dispatch-then-sync shuffle: shuffle
    # rounds executed, and how often the data plane blocked the host on
    # the device during them.  The array backend harvests every worker
    # batch's histogram behind ONE barrier, so a kernel-path shuffle
    # round costs exactly one host sync (host_syncs == shuffle_rounds —
    # not workers x rounds); reduce/degenerate rounds resolve with zero
    # syncs, and the bytes backend never syncs a device at all.
    shuffle_rounds: int = 0
    host_syncs: int = 0
    # array backend: compiled device dispatches issued by the data plane's
    # hot loop (stage UDF applies in run_stage + scatter/harvest work in
    # bucketize).  The fused-round invariant is asserted on this counter:
    # with ``fused_rounds`` a kernel-path shuffle round costs O(1)
    # dispatches (one stacked UDF call, a bounded shard fan of scatter
    # calls, one regrouping gather) regardless of task or worker count,
    # where the per-task/per-worker loop costs O(tasks + workers).
    device_dispatches: int = 0


@dataclass(frozen=True)
class TaskSpec:
    """One schedulable unit of work: a chunk (stage 0) or a worker's
    partition (later stages), with the replica holders the scheduler may
    place it on for free."""
    key: str
    nbytes: int
    locs: Tuple[str, ...]


@dataclass(frozen=True)
class TaskPlan:
    key: str
    nbytes: int
    locs: Tuple[str, ...]
    worker: str        # originally scheduled worker
    executor: str      # final executing worker (differs when a
                       # speculative copy on a replica won the race)
    finish: float      # simulated completion time within the stage


@dataclass(frozen=True)
class StagePlan:
    tasks: Tuple[TaskPlan, ...]
    seconds: float          # stage makespan (max task finish)
    bytes_local: int
    bytes_moved: int
    speculated: int
    speculation_wins: int


class IncrementalPlan:
    """A stage-0 plan grown per task *group* (one group per Sector file).

    Streams extend the plan when a file enters the window — only the new
    group is locality-scheduled — and ``retire`` a group when its file
    leaves, without touching the surviving groups.  That makes per-window
    planning cost proportional to the *delta*, not the window, and makes
    retirement exact (a group's plan never depended on its neighbours).

    Each group is planned independently from a clean per-job state, so
    the merged view treats groups as running in parallel: the merged
    makespan is the max of group makespans.  Cross-group contention for
    a worker is not modelled — the same optimism ``plan_shuffle`` applies
    to parallel flows — which is the price of extend-don't-rebuild.
    """

    def __init__(self):
        self.groups: Dict[str, StagePlan] = {}  # insertion-ordered

    def __contains__(self, key: str) -> bool:
        return key in self.groups

    def __len__(self) -> int:
        return len(self.groups)

    def add(self, key: str, plan: StagePlan) -> None:
        if key in self.groups:
            raise ValueError(f"group {key!r} already planned")
        self.groups[key] = plan

    def retire(self, key: str) -> Optional[StagePlan]:
        """Drop one group (its file left the window).  Surviving groups
        are untouched.  Returns the retired plan, if any."""
        return self.groups.pop(key, None)

    def merged(self) -> StagePlan:
        """The whole window's stage-0 plan: group tasks concatenated in
        arrival order, counters summed, makespan = max over groups."""
        groups = self.groups.values()
        return StagePlan(
            tuple(t for g in groups for t in g.tasks),
            max((g.seconds for g in groups), default=0.0),
            sum(g.bytes_local for g in groups),
            sum(g.bytes_moved for g in groups),
            sum(g.speculated for g in groups),
            sum(g.speculation_wins for g in groups))


class SpherePlanner:
    def __init__(self, *, speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8,
                 move_time: Optional[MoveTime] = None):
        self.speeds = dict(speeds or {})
        self.speculate_factor = speculate_factor
        self._move_time = move_time or (lambda nbytes, src, dst: 0.0)
        # per-JOB speculation state: worker -> count of tasks observed
        # straggling on it so far in the current job.  Later stages of the
        # same job avoid speculating *onto* these workers when another
        # replica is available; a session running a chain of jobs through
        # one planner resets this at every job boundary so one job's slow
        # node never biases the next job's placement.
        self.job_stragglers: Dict[str, int] = {}

    def reset_job_state(self) -> None:
        """Forget per-job speculation/straggler observations (called by
        the engine/session at each job boundary)."""
        self.job_stragglers.clear()

    def extend_plan(self, inc: IncrementalPlan, key: str,
                    tasks: Sequence[TaskSpec], workers: Sequence[str]
                    ) -> Tuple[StagePlan, Dict[str, int]]:
        """Plan ONE new group and add it to ``inc`` — the extend half of
        extend-don't-rebuild.  The group is planned from a clean per-job
        straggler state (group plans must not depend on arrival order),
        and the planner's current job state is saved and restored, so
        extending mid-job never perturbs the running job.  Returns the
        group plan plus the straggler observations planning it produced,
        for the caller to replay at each later job boundary."""
        saved = self.job_stragglers
        self.job_stragglers = {}
        try:
            plan = self.plan_stage(tasks, workers)
            contrib = dict(self.job_stragglers)
        finally:
            self.job_stragglers = saved
        inc.add(key, plan)
        return plan, contrib

    def _speed(self, worker: str) -> float:
        return self.speeds.get(worker, 1.0)

    def _proc_time(self, worker: str, nbytes: int) -> float:
        return nbytes / (PROCESS_RATE * self._speed(worker))

    # ------------------------------------------------------------- stage
    def plan_stage(self, tasks: Sequence[TaskSpec], workers: Sequence[str]
                   ) -> StagePlan:
        """Place every task, then speculate on observed stragglers."""
        est_ready = {w: 0.0 for w in workers}
        act_ready = {w: 0.0 for w in workers}
        bytes_local = bytes_moved = 0

        # --- schedule: locality first, then least-(estimated)-loaded ----
        scheduled: List[Tuple[TaskSpec, str, float]] = []
        for t in sorted(tasks, key=lambda t: -t.nbytes):
            live = [w for w in t.locs if w in est_ready]
            candidates = live or list(workers)
            w = min(candidates,
                    key=lambda x: est_ready[x] + t.nbytes / PROCESS_RATE)
            move = 0.0
            if w in live:
                bytes_local += t.nbytes
            else:
                src = live[0] if live else workers[0]
                move = self._move_time(t.nbytes, src, w)
                bytes_moved += t.nbytes
            est_ready[w] += move + t.nbytes / PROCESS_RATE
            fin = act_ready[w] + move + self._proc_time(w, t.nbytes)
            act_ready[w] = fin
            scheduled.append((t, w, fin))

        # --- speculative re-execution of (observed) stragglers -----------
        fins = sorted(f for _, _, f in scheduled)
        median = fins[len(fins) // 2] if fins else 0.0
        speculated = wins = 0
        plans: List[TaskPlan] = []
        for t, w, fin in scheduled:
            best_w, best_fin = w, fin
            if fin > self.speculate_factor * median:
                self.job_stragglers[w] = self.job_stragglers.get(w, 0) + 1
                alts = [x for x in t.locs if x != w and x in act_ready]
                # known stragglers are poor speculation targets: try clean
                # replicas first, fall back to the full list otherwise
                clean = [x for x in alts if x not in self.job_stragglers]
                for alt in clean or alts:
                    alt_fin = act_ready[alt] + self._proc_time(alt, t.nbytes)
                    speculated += 1
                    if alt_fin < best_fin:
                        best_w, best_fin = alt, alt_fin
                        act_ready[alt] = alt_fin
                        wins += 1
                        break
            plans.append(TaskPlan(t.key, t.nbytes, t.locs, w, best_w,
                                  best_fin))
        seconds = max((p.finish for p in plans), default=0.0)
        return StagePlan(tuple(plans), seconds, bytes_local, bytes_moved,
                         speculated, wins)

    # ----------------------------------------------------------- shuffle
    def plan_shuffle(self, flows: Sequence[Tuple[str, str, int]]
                     ) -> Tuple[float, int, int]:
        """Time + movement for a shuffle given its actual record flows.

        ``flows`` holds one ``(src_worker, dst_worker, nbytes)`` entry per
        bucket fragment — the bytes of each bucket that originated on each
        worker, as observed by the executor.  Fragments staying on their
        origin worker are local (no movement, no time); the rest transfer
        in parallel over distinct links, so the shuffle completes when the
        slowest flow lands.  Returns (seconds, bytes_moved, bytes_local).
        """
        seconds = 0.0
        moved = local = 0
        for src, dst, nbytes in flows:
            if not nbytes:
                continue
            if src == dst:
                local += nbytes
            else:
                seconds = max(seconds,
                              self._move_time(nbytes, src, dst))
                moved += nbytes
        return seconds, moved, local
