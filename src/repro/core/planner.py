"""Sphere control plane: pure locality/speculation planner (paper §4).

``SpherePlanner`` is the scheduling half of the engine's planner/executor
split.  It decides *where* every task runs and *how long* the stage takes
in simulated time — locality first, then least-(estimated)-loaded, with
speculative re-execution of observed stragglers on replicas — without
touching any data.  Its only effect is the returned :class:`StagePlan`,
so scheduling behaviour is unit-testable with no Sector cloud at all:
callers inject ``move_time(nbytes, src_worker, dst_worker)`` and per-
worker ``speeds``; identical inputs always produce identical plans.

Scheduling uses ESTIMATED speeds (uniform — the scheduler does not know a
node is slow until it runs); execution reveals actual speeds, and
speculation re-runs the surprises on replicas.  This mirrors the paper's
load balancing: replicas exist precisely so slow nodes can be routed
around after the fact.

Wide-area contention (the paper's whole premise is scheduling over shared
10 Gbps waves, §5/Table 1) enters through two opt-in knobs:

* ``link_of(src_worker, dst_worker)`` maps a transfer to the *physical
  path* it rides (``None`` = uncontended; the engine wires this to
  :meth:`repro.sector.topology.Topology.link_key`).  When set, every
  cross-worker move reserves time on a per-link
  :class:`~repro.sector.topology.LinkSchedule`: transfers sharing a wave
  queue behind each other instead of being priced as if each had a
  private link, in ``plan_stage`` candidate scoring, in
  ``plan_shuffle``'s flow merge, and in
  :meth:`IncrementalPlan.merged`'s transfer-group ready-time merge.
* ``offload=True`` widens stage placement from replica-holders-only to
  every worker, with the cross-site fetch priced into the candidate
  score — the WAN scenario where remote capacity is worth renting *if*
  the link can carry the bytes in time.

Both default off, in which case planning is bit-identical to the
contention-blind behaviour (every pre-existing test and benchmark sees
the same plans).  :meth:`SpherePlanner.price_plan` re-prices any fixed
assignment under *this* planner's link model — how the WAN benchmark
charges a contention-blind plan its true, queued cost.

The data-plane half (fetching chunks, running UDFs, bucketizing records)
lives in :mod:`repro.core.executor`; :class:`repro.core.engine.SphereEngine`
glues the two together.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Callable, Dict, Hashable, List, Optional, Sequence,
                    Tuple)

from repro.core.trace import NULL_TRACER
from repro.sector.topology import LinkSchedule

PROCESS_RATE = 400e6  # bytes/s of UDF processing on a speed-1.0 worker

# simulated seconds to move nbytes between two workers' sites
MoveTime = Callable[[int, str, str], float]
# physical path a worker-to-worker transfer rides (None = uncontended)
LinkOf = Callable[[str, str], Optional[Hashable]]

# SphereReport fields mirrored 1:1 into a bound MetricsRegistry as
# ``sphere.<field>`` counters (the numeric accumulate-only fields);
# locality_fraction mirrors as a gauge, stage_seconds as a histogram
# (via observe_stage) and udf_traces as per-stage gauges (via
# note_udf_traces).
_MIRRORED_COUNTERS = frozenset({
    "sim_seconds", "bytes_moved", "bytes_local", "tasks", "speculated",
    "speculation_wins", "retried", "partition_seconds",
    "partitioned_records", "planned_tasks", "reused_tasks",
    "shuffle_rounds", "host_syncs", "device_dispatches",
    "link_wait_seconds"})


@dataclass
class SphereReport:
    sim_seconds: float = 0.0
    bytes_moved: int = 0
    bytes_local: int = 0
    tasks: int = 0
    speculated: int = 0
    speculation_wins: int = 0
    retried: int = 0
    locality_fraction: float = 1.0
    stage_seconds: List[float] = field(default_factory=list)
    # REAL wall-clock spent computing bucket assignments + scattering
    # records in shuffles (everything else above is simulated time) —
    # the bytes-vs-array backend comparison the benchmarks report.
    partition_seconds: float = 0.0
    partitioned_records: int = 0
    # array backend: number of distinct shapes each pad-stable stage UDF
    # was traced with (1 = the jit-once guarantee held for that stage)
    udf_traces: Dict[str, int] = field(default_factory=dict)
    # streams/sessions: stage-0 tasks that got FRESH replica placement
    # this run vs. tasks replayed from a cached per-file plan — the
    # delta-planning guarantee ("only new chunks are planned") is
    # asserted on these two counters.
    planned_tasks: int = 0
    reused_tasks: int = 0
    # overlap accounting for the dispatch-then-sync shuffle: shuffle
    # rounds executed, and how often the data plane blocked the host on
    # the device during them.  The array backend harvests every worker
    # batch's histogram behind ONE barrier, so a kernel-path shuffle
    # round costs exactly one host sync (host_syncs == shuffle_rounds —
    # not workers x rounds); reduce/degenerate rounds resolve with zero
    # syncs, and the bytes backend never syncs a device at all.
    shuffle_rounds: int = 0
    host_syncs: int = 0
    # array backend: compiled device dispatches issued by the data plane's
    # hot loop (stage UDF applies in run_stage + scatter/harvest work in
    # bucketize).  The fused-round invariant is asserted on this counter:
    # with ``fused_rounds`` a kernel-path shuffle round costs O(1)
    # dispatches (one stacked UDF call, a bounded shard fan of scatter
    # calls, one regrouping gather) regardless of task or worker count,
    # where the per-task/per-worker loop costs O(tasks + workers).
    device_dispatches: int = 0
    # contention-aware planning: simulated seconds transfers spent
    # QUEUED behind other transfers on shared wide-area links (0.0 when
    # the planner runs contention-blind or every move rode a private
    # path).  The gap between a contention-blind estimate and reality.
    link_wait_seconds: float = 0.0

    # ------------------------------------------------------ metrics mirror
    def bind_metrics(self, registry, **labels) -> "SphereReport":
        """Mirror this report into ``registry``: from now on every
        counter-field mutation forwards its delta to the matching
        ``sphere.<field>`` series, so registry reads and report fields
        are two views of one write path (the report's current values
        are seeded first — binding mid-run loses nothing).  Labels
        identify this report's series; the engine adds a unique ``run``
        label per binding so chained reports never collide."""
        object.__setattr__(self, "_metrics", registry)
        object.__setattr__(self, "_metric_labels", dict(labels))
        for name in _MIRRORED_COUNTERS:
            v = getattr(self, name)
            if v:
                registry.counter(f"sphere.{name}", **labels).inc(v)
        registry.gauge("sphere.locality_fraction",
                       **labels).set(self.locality_fraction)
        for s in self.stage_seconds:
            registry.histogram("sphere.stage_seconds", **labels).observe(s)
        for stage, n in self.udf_traces.items():
            registry.gauge("sphere.udf_traces", stage=stage,
                           **labels).set(n)
        return self

    @property
    def metric_labels(self) -> Dict[str, str]:
        """Labels this report's mirrored series carry ({} if unbound)."""
        return dict(getattr(self, "_metric_labels", {}))

    def __setattr__(self, name: str, value) -> None:
        m = self.__dict__.get("_metrics")
        if m is not None:
            if name in _MIRRORED_COUNTERS:
                delta = value - self.__dict__.get(name, 0)
                if delta:
                    m.counter(f"sphere.{name}",
                              **self._metric_labels).inc(delta)
            elif name == "locality_fraction":
                m.gauge("sphere.locality_fraction",
                        **self._metric_labels).set(value)
        object.__setattr__(self, name, value)

    def observe_stage(self, seconds: float) -> None:
        """Record one stage's simulated makespan (the ONE write path for
        ``stage_seconds`` — list append + histogram observation)."""
        self.stage_seconds.append(seconds)
        m = self.__dict__.get("_metrics")
        if m is not None:
            m.histogram("sphere.stage_seconds",
                        **self._metric_labels).observe(seconds)

    def note_udf_traces(self, stage: str, traces: int) -> None:
        """Record a stage UDF's distinct traced shapes (max-aggregated
        per stage name: a retracing stage must not be masked by a later
        same-named stage that traced once)."""
        self.udf_traces[stage] = max(self.udf_traces.get(stage, 0), traces)
        m = self.__dict__.get("_metrics")
        if m is not None:
            m.gauge("sphere.udf_traces", stage=stage,
                    **self._metric_labels).set(self.udf_traces[stage])


@dataclass(frozen=True)
class TaskSpec:
    """One schedulable unit of work: a chunk (stage 0) or a worker's
    partition (later stages), with the replica holders the scheduler may
    place it on for free."""
    key: str
    nbytes: int
    locs: Tuple[str, ...]


@dataclass(frozen=True)
class TaskPlan:
    key: str
    nbytes: int
    locs: Tuple[str, ...]
    worker: str        # originally scheduled worker
    executor: str      # final executing worker (differs when a
                       # speculative copy on a replica won the race)
    finish: float      # simulated completion time within the stage


@dataclass(frozen=True)
class StagePlan:
    """Immutable result of planning one stage.

    ``link_seconds``/``link_wait`` are populated only by a
    contention-aware planner (``link_of`` set): ``link_seconds`` is the
    per-physical-link busy time this plan's transfers occupy, as sorted
    ``(link_key, seconds)`` pairs — what :meth:`IncrementalPlan.merged`
    sums across groups to find a shared bottleneck — and ``link_wait``
    is the total time transfers sat queued behind other transfers.
    Contention-blind plans carry the defaults, so equality between two
    blind plans is unchanged from before the fields existed.

    ``transfers`` records each cross-worker move's reservation on its
    physical link — ``(link_key, task_key, begin, end)`` in simulated
    seconds — exactly as :meth:`LinkSchedule.reserve` granted it.  The
    tracer turns these into per-link timeline spans; moves riding a
    ``None`` (uncontended) path are not recorded.
    """
    tasks: Tuple[TaskPlan, ...]
    seconds: float          # stage makespan (max task finish)
    bytes_local: int
    bytes_moved: int
    speculated: int
    speculation_wins: int
    link_seconds: Tuple[Tuple[Hashable, float], ...] = ()
    link_wait: float = 0.0
    transfers: Tuple[Tuple[Hashable, str, float, float], ...] = ()


def _sorted_link_items(busy: Dict[Hashable, float]
                       ) -> Tuple[Tuple[Hashable, float], ...]:
    """Deterministic ordering for link-busy pairs (keys may be any
    hashable, so sort on repr)."""
    return tuple(sorted(busy.items(), key=lambda kv: repr(kv[0])))


class IncrementalPlan:
    """A stage-0 plan grown per task *group* (one group per Sector file).

    Streams extend the plan when a file enters the window — only the new
    group is locality-scheduled — and ``retire`` a group when its file
    leaves, without touching the surviving groups.  That makes per-window
    planning cost proportional to the *delta*, not the window, and makes
    retirement exact (a group's plan never depended on its neighbours).

    Each group is planned independently from a clean per-job state, so
    the merged view treats groups as running in parallel on *workers*:
    the merged makespan starts from the max of group makespans.  What
    groups can NOT do in parallel is occupy the same wide-area link:
    when the planner is contention-aware each group plan carries its
    per-link busy time, and ``merged`` raises the makespan to the
    busiest shared link's total across groups (transfer-group ready-time
    merging — two groups each needing 1 s of the same wave take 2 s, two
    groups on disjoint waves still take max).  With a contention-blind
    planner every group's ``link_seconds`` is empty and the merge
    reduces to the old max-of-makespans exactly.  Cross-group contention
    for a *worker* remains unmodelled — the price of
    extend-don't-rebuild.
    """

    def __init__(self):
        self.groups: Dict[str, StagePlan] = {}  # insertion-ordered

    def __contains__(self, key: str) -> bool:
        return key in self.groups

    def __len__(self) -> int:
        return len(self.groups)

    def add(self, key: str, plan: StagePlan) -> None:
        if key in self.groups:
            raise ValueError(f"group {key!r} already planned")
        self.groups[key] = plan

    def retire(self, key: str) -> Optional[StagePlan]:
        """Drop one group (its file left the window).  Surviving groups
        are untouched.  Returns the retired plan, if any."""
        return self.groups.pop(key, None)

    def merged(self) -> StagePlan:
        """The whole window's stage-0 plan: group tasks concatenated in
        arrival order, counters summed, makespan = max over group
        makespans, raised to the busiest shared link's summed busy time
        (see class docstring)."""
        groups = list(self.groups.values())
        busy: Dict[Hashable, float] = {}
        for g in groups:
            for key, secs in g.link_seconds:
                busy[key] = busy.get(key, 0.0) + secs
        makespan = max((g.seconds for g in groups), default=0.0)
        queued = max(busy.values(), default=0.0)
        return StagePlan(
            tuple(t for g in groups for t in g.tasks),
            max(makespan, queued),
            sum(g.bytes_local for g in groups),
            sum(g.bytes_moved for g in groups),
            sum(g.speculated for g in groups),
            sum(g.speculation_wins for g in groups),
            _sorted_link_items(busy),
            sum(g.link_wait for g in groups),
            # per-group reservation times (each group planned from a
            # clean link schedule, so spans from different groups may
            # overlap on a shared track — see OBSERVABILITY.md)
            tuple(tr for g in groups for tr in g.transfers))


class SpherePlanner:
    """See the module docstring for the scheduling model.

    Constructor contract:

    * ``speeds`` — worker -> relative speed (1.0 default); ACTUAL speeds
      revealed at execution, never used for placement estimates.
    * ``speculate_factor`` — a task finishing later than this multiple of
      the stage median gets a speculative copy on a replica.
    * ``move_time(nbytes, src_worker, dst_worker)`` — simulated seconds
      for one transfer ALONE on its path (the transport model); queueing
      on shared paths is this planner's job, not ``move_time``'s.
    * ``link_of(src_worker, dst_worker)`` — physical-path identity for
      capacity accounting; ``None``-returning pairs (and a ``None``
      callable, the default) are priced uncontended.
    * ``offload`` — let stages place tasks on non-replica workers when
      the priced fetch still wins; default ``False`` keeps the paper's
      locality-first placement (moves only when no replica is live).

    With ``link_of=None`` and ``offload=False`` every method produces
    bit-identical plans to the pre-contention planner.
    """

    def __init__(self, *, speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8,
                 move_time: Optional[MoveTime] = None,
                 link_of: Optional[LinkOf] = None,
                 offload: bool = False, tracer=None):
        self.speeds = dict(speeds or {})
        self.speculate_factor = speculate_factor
        self._move_time = move_time or (lambda nbytes, src, dst: 0.0)
        self._link_of = link_of
        self.offload = offload
        self.tracer = tracer or NULL_TRACER
        # per-JOB speculation state: worker -> count of tasks observed
        # straggling on it so far in the current job.  Later stages of the
        # same job avoid speculating *onto* these workers when another
        # replica is available; a session running a chain of jobs through
        # one planner resets this at every job boundary so one job's slow
        # node never biases the next job's placement.
        self.job_stragglers: Dict[str, int] = {}

    def reset_job_state(self) -> None:
        """Forget per-job speculation/straggler observations (called by
        the engine/session at each job boundary)."""
        self.job_stragglers.clear()

    def extend_plan(self, inc: IncrementalPlan, key: str,
                    tasks: Sequence[TaskSpec], workers: Sequence[str]
                    ) -> Tuple[StagePlan, Dict[str, int]]:
        """Plan ONE new group and add it to ``inc`` — the extend half of
        extend-don't-rebuild.  The group is planned from a clean per-job
        straggler state (group plans must not depend on arrival order),
        and the planner's current job state is saved and restored, so
        extending mid-job never perturbs the running job.  Link
        occupancy likewise starts clean per group; the CROSS-group link
        bill is settled later by :meth:`IncrementalPlan.merged`, which
        is what keeps a group's plan independent of its neighbours (the
        retirement-exactness guarantee) while still charging shared
        bottlenecks.  Returns the group plan plus the straggler
        observations planning it produced, for the caller to replay at
        each later job boundary."""
        saved = self.job_stragglers
        self.job_stragglers = {}
        try:
            plan = self.plan_stage(tasks, workers)
            contrib = dict(self.job_stragglers)
        finally:
            self.job_stragglers = saved
        inc.add(key, plan)
        return plan, contrib

    def _speed(self, worker: str) -> float:
        return self.speeds.get(worker, 1.0)

    def _proc_time(self, worker: str, nbytes: int) -> float:
        return nbytes / (PROCESS_RATE * self._speed(worker))

    def _key_of(self, src: str, dst: str) -> Optional[Hashable]:
        return self._link_of(src, dst) if self._link_of is not None else None

    # ------------------------------------------------------------- stage
    def plan_stage(self, tasks: Sequence[TaskSpec], workers: Sequence[str]
                   ) -> StagePlan:
        """Place every task, then speculate on observed stragglers.

        Contention-blind + locality-only (the default knobs) takes the
        legacy path; either knob routes through the link-aware scheduler.
        """
        with self.tracer.span("planner:plan-stage", track="planner") as sp:
            if self._link_of is None and not self.offload:
                plan = self._plan_stage_blind(tasks, workers)
            else:
                plan = self._plan_stage_aware(tasks, workers)
            if self.tracer.enabled:
                sp.set_attrs(tasks=len(plan.tasks),
                             sim_seconds=plan.seconds,
                             bytes_local=plan.bytes_local,
                             bytes_moved=plan.bytes_moved,
                             speculated=plan.speculated,
                             links_reserved=len(plan.transfers),
                             link_wait=plan.link_wait)
        return plan

    def _plan_stage_blind(self, tasks: Sequence[TaskSpec],
                          workers: Sequence[str]) -> StagePlan:
        """Pre-contention scheduler, preserved bit-for-bit: each move is
        priced alone on its path and charged to the destination worker's
        queue; placement never leaves the replica set while any replica
        is live."""
        est_ready = {w: 0.0 for w in workers}
        act_ready = {w: 0.0 for w in workers}
        bytes_local = bytes_moved = 0

        # --- schedule: locality first, then least-(estimated)-loaded ----
        scheduled: List[Tuple[TaskSpec, str, float]] = []
        for t in sorted(tasks, key=lambda t: -t.nbytes):
            live = [w for w in t.locs if w in est_ready]
            candidates = live or list(workers)
            w = min(candidates,
                    key=lambda x: est_ready[x] + t.nbytes / PROCESS_RATE)
            move = 0.0
            if w in live:
                bytes_local += t.nbytes
            else:
                src = live[0] if live else workers[0]
                move = self._move_time(t.nbytes, src, w)
                bytes_moved += t.nbytes
            est_ready[w] += move + t.nbytes / PROCESS_RATE
            fin = act_ready[w] + move + self._proc_time(w, t.nbytes)
            act_ready[w] = fin
            scheduled.append((t, w, fin))

        plans, seconds, speculated, wins = self._speculate(scheduled,
                                                           act_ready)
        return StagePlan(tuple(plans), seconds, bytes_local, bytes_moved,
                         speculated, wins)

    def _plan_stage_aware(self, tasks: Sequence[TaskSpec],
                          workers: Sequence[str]) -> StagePlan:
        """Link-aware scheduler: a cross-worker fetch reserves time on
        its physical path, so two fetches sharing a wave serialize and
        the SECOND one's candidate score already includes the wait.
        A transfer starts when BOTH its physical path and its
        destination worker are free (the destination receives serially —
        without that, stacking every task on one worker would look
        nearly free), and the destination's compute follows the
        transfer; source workers are not charged (transfers are pulls of
        resident data).  On a ``None`` path the link never queues, so
        the accounting reduces to the blind model's per-destination
        ``move + proc`` exactly.  With ``offload`` every
        worker is a candidate; otherwise only replica holders are (the
        legacy rule), but moves that DO happen still queue."""
        est_ready = {w: 0.0 for w in workers}
        act_ready = {w: 0.0 for w in workers}
        est_links = LinkSchedule()
        act_links = LinkSchedule()
        link_busy: Dict[Hashable, float] = {}
        link_wait = 0.0
        bytes_local = bytes_moved = 0
        transfers: List[Tuple[Hashable, str, float, float]] = []
        worker_list = list(workers)

        scheduled: List[Tuple[TaskSpec, str, float]] = []
        for t in sorted(tasks, key=lambda t: -t.nbytes):
            live = [w for w in t.locs if w in est_ready]
            if self.offload and worker_list:
                candidates = worker_list
            else:
                candidates = live or worker_list
            proc_est = t.nbytes / PROCESS_RATE
            src0 = live[0] if live else (worker_list[0] if worker_list
                                         else "")

            def est_fin(x: str) -> float:
                if x in live:
                    return est_ready[x] + proc_est
                move = self._move_time(t.nbytes, src0, x)
                _, t_end = est_links.peek(self._key_of(src0, x),
                                          est_ready[x], move)
                return t_end + proc_est

            w = min(candidates, key=est_fin)
            if w in live:
                bytes_local += t.nbytes
                est_ready[w] += proc_est
                fin = act_ready[w] + self._proc_time(w, t.nbytes)
            else:
                move = self._move_time(t.nbytes, src0, w)
                key = self._key_of(src0, w)
                bytes_moved += t.nbytes
                _, e_end = est_links.reserve(key, est_ready[w], move)
                est_ready[w] = e_end + proc_est
                a_begin, a_end = act_links.reserve(key, act_ready[w], move)
                link_wait += a_begin - act_ready[w]
                if key is not None:
                    link_busy[key] = link_busy.get(key, 0.0) + move
                    transfers.append((key, t.key, a_begin, a_end))
                fin = a_end + self._proc_time(w, t.nbytes)
            act_ready[w] = fin
            scheduled.append((t, w, fin))

        plans, seconds, speculated, wins = self._speculate(scheduled,
                                                           act_ready)
        return StagePlan(tuple(plans), seconds, bytes_local, bytes_moved,
                         speculated, wins, _sorted_link_items(link_busy),
                         link_wait, tuple(transfers))

    def _speculate(self, scheduled: List[Tuple[TaskSpec, str, float]],
                   act_ready: Dict[str, float]
                   ) -> Tuple[List[TaskPlan], float, int, int]:
        """Speculative re-execution of (observed) stragglers — shared by
        both schedulers.  Speculative copies run on replicas, so they
        move no bytes and touch no link."""
        fins = sorted(f for _, _, f in scheduled)
        median = fins[len(fins) // 2] if fins else 0.0
        speculated = wins = 0
        plans: List[TaskPlan] = []
        for t, w, fin in scheduled:
            best_w, best_fin = w, fin
            if fin > self.speculate_factor * median:
                self.job_stragglers[w] = self.job_stragglers.get(w, 0) + 1
                alts = [x for x in t.locs if x != w and x in act_ready]
                # known stragglers are poor speculation targets: try clean
                # replicas first, fall back to the full list otherwise
                clean = [x for x in alts if x not in self.job_stragglers]
                for alt in clean or alts:
                    alt_fin = act_ready[alt] + self._proc_time(alt, t.nbytes)
                    speculated += 1
                    if alt_fin < best_fin:
                        best_w, best_fin = alt, alt_fin
                        act_ready[alt] = alt_fin
                        wins += 1
                        break
            plans.append(TaskPlan(t.key, t.nbytes, t.locs, w, best_w,
                                  best_fin))
        seconds = max((p.finish for p in plans), default=0.0)
        return plans, seconds, speculated, wins

    # ----------------------------------------------------------- pricing
    def price_plan(self, plan: StagePlan, workers: Sequence[str]
                   ) -> StagePlan:
        """Re-price a FIXED assignment under this planner's link model.

        Keeps every task on ``plan``'s chosen executor and replays the
        stage through a fresh :class:`LinkSchedule` and fresh worker
        queues, in the same largest-first service order planning uses.
        This is how two planning policies are compared honestly: plan
        with each policy, then price both plans under the same
        (contention-aware) model — a contention-blind plan's optimistic
        ``seconds`` is replaced by what its transfers would really take
        queued on shared waves.  Speculation counters pass through
        unchanged (the assignment, including speculative winners, is
        what is being priced)."""
        worker_set = set(workers)
        ready: Dict[str, float] = {w: 0.0 for w in workers}
        links = LinkSchedule()
        link_busy: Dict[Hashable, float] = {}
        link_wait = 0.0
        bytes_local = bytes_moved = 0
        transfers: List[Tuple[Hashable, str, float, float]] = []
        repriced: List[TaskPlan] = []
        for p in sorted(plan.tasks, key=lambda p: -p.nbytes):
            w = p.executor
            ready.setdefault(w, 0.0)
            live = [x for x in p.locs if x in worker_set]
            if w in live:
                bytes_local += p.nbytes
                fin = ready[w] + self._proc_time(w, p.nbytes)
            else:
                src = live[0] if live else (workers[0] if workers else w)
                move = self._move_time(p.nbytes, src, w)
                key = self._key_of(src, w)
                begin, end = links.reserve(key, ready[w], move)
                link_wait += begin - ready[w]
                if key is not None:
                    link_busy[key] = link_busy.get(key, 0.0) + move
                    transfers.append((key, p.key, begin, end))
                bytes_moved += p.nbytes
                fin = end + self._proc_time(w, p.nbytes)
            ready[w] = fin
            repriced.append(TaskPlan(p.key, p.nbytes, p.locs, p.worker, w,
                                     fin))
        seconds = max((p.finish for p in repriced), default=0.0)
        priced = StagePlan(tuple(repriced), seconds, bytes_local, bytes_moved,
                           plan.speculated, plan.speculation_wins,
                           _sorted_link_items(link_busy), link_wait,
                           tuple(transfers))
        if self.tracer.enabled:
            self.tracer.instant("planner:price", track="planner",
                                attrs={"tasks": len(priced.tasks),
                                       "sim_seconds": priced.seconds,
                                       "link_wait": priced.link_wait,
                                       "links_reserved": len(transfers)})
        return priced

    # ----------------------------------------------------------- shuffle
    def plan_shuffle(self, flows: Sequence[Tuple[str, str, int]]
                     ) -> Tuple[float, int, int]:
        """Time + movement for a shuffle given its actual record flows.

        ``flows`` holds one ``(src_worker, dst_worker, nbytes)`` entry per
        bucket fragment — the bytes of each bucket that originated on each
        worker, as observed by the executor.  Fragments staying on their
        origin worker are local (no movement, no time).  Cross-worker
        flows riding DISTINCT physical paths transfer in parallel, so
        they cost the max of their move times; flows whose ``link_of``
        maps to the same path serialize, so each shared path costs the
        SUM of its flows' move times and the shuffle completes when the
        busiest path drains.  A contention-blind planner (no ``link_of``)
        treats every flow as a distinct path — the pre-contention
        behaviour, unchanged.  Returns (seconds, bytes_moved,
        bytes_local).
        """
        seconds = 0.0
        moved = local = 0
        busy: Dict[Hashable, float] = {}
        for src, dst, nbytes in flows:
            if not nbytes:
                continue
            if src == dst:
                local += nbytes
                continue
            moved += nbytes
            mt = self._move_time(nbytes, src, dst)
            key = self._key_of(src, dst)
            if key is None:
                seconds = max(seconds, mt)
            else:
                busy[key] = busy.get(key, 0.0) + mt
        if busy:
            seconds = max(seconds, max(busy.values()))
        return seconds, moved, local
