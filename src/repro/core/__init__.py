from repro.core.engine import (SphereEngine, SphereReport,  # noqa: F401
                               SphereSession)
from repro.core.executor import ArrayExecutor, BytesExecutor  # noqa: F401
from repro.core.job import SphereJob, SphereStage  # noqa: F401
from repro.core.planner import (IncrementalPlan,  # noqa: F401
                                SpherePlanner, StagePlan, TaskPlan, TaskSpec)
from repro.core.metrics import MetricsRegistry  # noqa: F401
from repro.core.stream import SphereStream, WindowPolicy  # noqa: F401
from repro.core.shuffle import (hash_partitioner,  # noqa: F401
                                range_partitioner, reduce_partitioner)
from repro.core.trace import NULL_TRACER, NullTracer, Tracer  # noqa: F401
