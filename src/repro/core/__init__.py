from repro.core.engine import (SphereEngine, SphereReport,  # noqa: F401
                               SphereSession)
from repro.core.executor import ArrayExecutor, BytesExecutor  # noqa: F401
from repro.core.job import SphereJob, SphereStage  # noqa: F401
from repro.core.planner import (SpherePlanner, StagePlan,  # noqa: F401
                                TaskPlan, TaskSpec)
from repro.core.shuffle import (hash_partitioner,  # noqa: F401
                                range_partitioner, reduce_partitioner)
