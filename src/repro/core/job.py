"""Sphere job model: arbitrary UDF stages over a stream of records.

The paper's programming model (§4): the dataset is a stream divided into
chunks already distributed by Sector; ``sphere.run(data, process)`` applies
``process`` to every record in parallel where the data lives; between stages
data is shuffled as required. Unlike MapReduce, *both* positions are
arbitrary UDFs — a stage is any record->records function, optionally
followed by a partitioner that reshuffles records across buckets.

Two record backends:

* ``backend="bytes"`` (reference): records are Python ``bytes``; a stage's
  ``udf`` maps a list of records to a list of records and the shuffle
  calls the partitioner once per record.
* ``backend="array"``: records are packed into :class:`RecordBatch`
  arrays; a stage's ``batch_udf`` is a (typically jitted) ``RecordBatch ->
  RecordBatch`` function and the shuffle runs the Pallas bucket-partition
  kernel + one argsort/gather per worker batch.  Requires a fixed
  ``record_size``.  A stage with only a bytes ``udf`` still works on the
  array backend through a decode/re-encode compatibility path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

from repro.core.records import RecordBatch

# A UDF maps a list of records (bytes each) to a list of records.
UDF = Callable[[Sequence[bytes]], List[bytes]]
# A batch UDF maps a RecordBatch to a RecordBatch (array backend).
BatchUDF = Callable[[RecordBatch], RecordBatch]
# A mask-aware UDF maps (padded RecordBatch, validity mask, params) to a
# RecordBatch whose row count depends only on the padded input shape —
# the contract for reduction-shaped stages (array backend).
MaskedUDF = Callable[[RecordBatch, Any, Any], RecordBatch]
# A partitioner maps one record to a bucket index in [0, n_buckets).
Partitioner = Callable[[bytes, int], int]

BACKENDS = ("bytes", "array")


@dataclass
class SphereStage:
    name: str
    udf: Optional[UDF] = None
    partitioner: Optional[Partitioner] = None  # None = no shuffle after
    n_buckets: int = 0                         # 0 = same as worker count
    batch_udf: Optional[BatchUDF] = None       # array-backend stage body
    # pad_value declares batch_udf *pad-stable*: the array executor may
    # pad input rows with this byte up to a fixed block shape, call
    # the UDF on the padded batch (so it is traced once per stage, not
    # once per task shape), and slice the first n rows back off.  The
    # UDF must preserve the row count and keep padding rows at the tail
    # — e.g. identity, row-local maps, or a stable sort with max-byte
    # (0xff) padding.  None = shape-polymorphic UDF, traced per shape.
    pad_value: Optional[int] = None
    # masked_udf declares the stage *mask-aware* (reduction-shaped): the
    # array executor pads the input batch with pad_value (default 0) to
    # the stage's fixed block shape and calls
    # ``masked_udf(batch, mask, params)`` where ``mask`` is a bool [rows]
    # validity vector (True = real record).  Unlike pad-stable batch
    # UDFs, the output row count may differ from the input — it must
    # depend only on the padded shape (e.g. a k-means assign stage that
    # folds any number of points into one partial record), and every
    # output row is real (no un-pad slice).  The executor jits the call
    # once per stage with (data, n_valid, params) as dynamic arguments,
    # so a chain of jobs re-running the stage with new ``params`` values
    # never retraces.  masked_udf and batch_udf are mutually exclusive.
    masked_udf: Optional[MaskedUDF] = None
    # per-run parameters, passed to masked_udf as a dynamic jit argument
    # (a pytree of arrays).  Mutate between session runs — e.g. the
    # current k-means centroids — without invalidating the traced UDF.
    # Bytes UDFs may read it via a closure over the stage object.
    params: Any = None

    def __post_init__(self):
        if self.masked_udf is not None and self.batch_udf is not None:
            raise ValueError(f"stage {self.name!r} declares both batch_udf "
                             f"and masked_udf; they are mutually exclusive")
        if self.masked_udf is not None and self.pad_value is None:
            self.pad_value = 0  # masked stages neutralise padding via mask

    def apply_bytes(self, records: Sequence[bytes]) -> List[bytes]:
        if self.udf is None:
            raise ValueError(f"stage {self.name!r} has no bytes udf "
                             f"(backend='bytes' needs one)")
        return self.udf(records)

    def apply_batch(self, batch: RecordBatch) -> RecordBatch:
        if self.batch_udf is not None:
            out = self.batch_udf(batch)
            if not isinstance(out, RecordBatch):
                raise TypeError(f"stage {self.name!r} batch_udf must return "
                                f"a RecordBatch, got {type(out).__name__}")
            return out
        # compatibility: run the bytes udf over the unpacked batch
        out_records = self.apply_bytes(batch.to_records())
        if not out_records:
            return RecordBatch.empty(batch.record_size)
        return RecordBatch.from_records(out_records)


@dataclass
class SphereJob:
    name: str
    input_file: str
    stages: List[SphereStage]
    record_size: int = 0   # fixed-size records; 0 = whole chunk is 1 record
    backend: str = "bytes"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"choose from {BACKENDS}")
        if self.backend == "array" and self.record_size <= 0:
            raise ValueError("backend='array' requires a fixed "
                             "record_size > 0")

    def split_records(self, blob: bytes) -> List[bytes]:
        if not self.record_size:
            return [blob]
        rs = self.record_size
        return [blob[i:i + rs] for i in range(0, len(blob) - rs + 1, rs)]

    def split_batch(self, blob: bytes) -> RecordBatch:
        return RecordBatch.from_bytes(blob, self.record_size)
