"""Sphere job model: arbitrary UDF stages over a stream of records.

The paper's programming model (§4): the dataset is a stream divided into
chunks already distributed by Sector; ``sphere.run(data, process)`` applies
``process`` to every record in parallel where the data lives; between stages
data is shuffled as required. Unlike MapReduce, *both* positions are
arbitrary UDFs — a stage is any record->records function, optionally
followed by a partitioner that reshuffles records across buckets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

# A UDF maps a list of records (bytes each) to a list of records.
UDF = Callable[[Sequence[bytes]], List[bytes]]
# A partitioner maps one record to a bucket index in [0, n_buckets).
Partitioner = Callable[[bytes, int], int]


@dataclass
class SphereStage:
    name: str
    udf: UDF
    partitioner: Optional[Partitioner] = None  # None = no shuffle after
    n_buckets: int = 0                         # 0 = same as worker count


@dataclass
class SphereJob:
    name: str
    input_file: str
    stages: List[SphereStage]
    record_size: int = 0   # fixed-size records; 0 = whole chunk is 1 record

    def split_records(self, blob: bytes) -> List[bytes]:
        if not self.record_size:
            return [blob]
        rs = self.record_size
        return [blob[i:i + rs] for i in range(0, len(blob) - rs + 1, rs)]
