"""Partitioners for the Sphere shuffle — bytes reference + array backend.

Each partitioner is a callable ``(record: bytes, n: int) -> int`` (the
bytes reference path, unchanged engine protocol) and additionally exposes
``bucket_ids(batch, n)`` which computes the same assignment for a whole
``RecordBatch`` in one shot via the Pallas ``bucket_partition`` kernel
(ids + histogram).  The kernel's rule is ``bucket = #{i : bounds[i] <
key}``; both partitioners phrase their bytes-side decision with exactly
that rule so the two paths agree record-for-record:

* ``HashPartitioner`` hashes the key prefix with FNV-1a 32-bit (scalar
  and vectorised twins in :mod:`repro.core.records`) and buckets the
  hash against ``uniform_hash_bounds``.
* ``RangePartitioner`` keeps the classic TeraSort binary search over
  sampled boundaries.  Its array path compares big-endian uint32 views
  of the first 4 key bytes, which matches the bytes comparison whenever
  boundaries are at most 4 bytes (use ``sample_boundaries(...,
  key_bytes=4)`` when targeting the array backend).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.records import (RecordBatch, fnv1a32, scatter_by_ids,
                                uniform_hash_bounds)
from repro.kernels.bucket_partition import bucket_partition


def _kernel_partition(keys: jax.Array, bounds_u32: np.ndarray, n: int,
                      *, block_n: int = 1 << 20,
                      interpret: bool | None = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """bucket_partition over uint32 keys with degenerate-shape handling.

    The Pallas kernel needs at least one boundary; n == 1 (or an empty
    boundary list) means every record lands in bucket 0.  When there are
    more boundaries than n - 1 the tail buckets are clamped onto n - 1,
    mirroring the ``min(lo, n - 1)`` in the bytes reference.
    """
    nrec = keys.shape[0]
    if nrec == 0 or n <= 1 or len(bounds_u32) == 0:
        ids = jnp.zeros((nrec,), jnp.int32)
        hist = jnp.zeros((max(n, 1),), jnp.int32).at[0].set(nrec)
        return ids, hist
    nb = len(bounds_u32) + 1
    ids, hist = bucket_partition(keys, jnp.asarray(bounds_u32), n_buckets=nb,
                                 block_n=min(block_n, nrec),
                                 interpret=interpret)
    if nb > n:  # clamp overflow buckets, fold their histogram tail
        ids = jnp.minimum(ids, n - 1)
        hist = hist[:n].at[n - 1].add(hist[n:].sum())
    return ids, hist


class HashPartitioner:
    """FNV-1a hash of the first ``key_bytes`` bytes -> uniform bucket."""

    def __init__(self, key_bytes: int = 8):
        self.key_bytes = key_bytes
        self._bounds: Dict[int, List[int]] = {}

    def _bounds_for(self, n: int) -> List[int]:
        if n not in self._bounds:
            self._bounds[n] = uniform_hash_bounds(n).tolist()
        return self._bounds[n]

    def __call__(self, record: bytes, n: int) -> int:
        h = fnv1a32(record[:self.key_bytes])
        return bisect_left(self._bounds_for(n), h)

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        keys = batch.hash_keys_u32(self.key_bytes)
        return _kernel_partition(keys, uniform_hash_bounds(n), n,
                                 block_n=block_n, interpret=interpret)


class RangePartitioner:
    """TeraSort-style: bucket by key position among sorted boundaries."""

    def __init__(self, boundaries: Sequence[bytes]):
        self.bnd = list(boundaries)

    def __call__(self, record: bytes, n: int) -> int:
        bnd = self.bnd
        key = record[:len(bnd[0])] if bnd else record
        lo, hi = 0, len(bnd)
        while lo < hi:
            mid = (lo + hi) // 2
            if key > bnd[mid]:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, n - 1)

    def bounds_u32(self) -> np.ndarray:
        """Boundaries as big-endian uint32 of their first 4 bytes."""
        return np.array([int.from_bytes(b[:4].ljust(4, b"\0"), "big")
                         for b in self.bnd], dtype=np.uint32)

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        # The kernel compares uint32 views of 4-byte key prefixes, which
        # only matches the bytes path when boundaries fit in 4 bytes
        # (sample_boundaries(..., key_bytes=4)).  Longer boundaries take
        # the per-record host loop so the assignment never silently
        # diverges from the reference.
        if self.bnd and len(self.bnd[0]) > 4:
            return _host_partition(batch, self, n)
        kb = min(len(self.bnd[0]), 4) if self.bnd else 4
        return _kernel_partition(batch.keys_u32(kb), self.bounds_u32(), n,
                                 block_n=block_n, interpret=interpret)


def hash_partitioner(key_bytes: int = 8) -> HashPartitioner:
    return HashPartitioner(key_bytes)


def range_partitioner(boundaries: Sequence[bytes]) -> RangePartitioner:
    return RangePartitioner(boundaries)


def _host_partition(batch: RecordBatch, partitioner, n: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-record host loop — the correctness fallback for partitioners
    the kernel cannot express."""
    ids_np = np.fromiter((partitioner(r, n) for r in batch.to_records()),
                         np.int32, count=batch.num_records)
    hist = np.bincount(ids_np, minlength=n).astype(np.int32)
    return jnp.asarray(ids_np), jnp.asarray(hist)


def partition_batch(batch: RecordBatch, partitioner, n: int, *,
                    block_n: int = 1 << 20, interpret: bool | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """(ids, hist) for a batch under any engine partitioner.

    Array-aware partitioners go through the Pallas kernel; arbitrary
    ``(record, n) -> int`` callables fall back to a per-record host loop
    so the array backend stays correct for custom partitioners.
    """
    if hasattr(partitioner, "bucket_ids"):
        return partitioner.bucket_ids(batch, n, block_n=block_n,
                                      interpret=interpret)
    return _host_partition(batch, partitioner, n)


def shuffle_batch(batch: RecordBatch, partitioner, n: int, *,
                  block_n: int = 1 << 20, interpret: bool | None = None
                  ) -> List[RecordBatch]:
    """Partition + scatter: one kernel call, one argsort, n gathers."""
    ids, hist = partition_batch(batch, partitioner, n, block_n=block_n,
                                interpret=interpret)
    return scatter_by_ids(batch, ids, hist)


def terasort_stages(bounds: Sequence[bytes], backend: str, n_buckets: int,
                    key_bytes: int = 10) -> list:
    """The canonical TeraSort stage pair (partition+shuffle, then sort)
    on either record backend — shared by benchmarks, examples and tests
    so the two paths always run the same job shape."""
    from repro.core.job import SphereStage
    part = range_partitioner(bounds)
    if backend == "array":
        return [
            SphereStage("partition", batch_udf=lambda b: b,
                        partitioner=part, n_buckets=n_buckets),
            SphereStage("sort",
                        batch_udf=lambda b: b.sort_by_key(key_bytes)),
        ]
    return [
        SphereStage("partition", lambda rs: list(rs),
                    partitioner=part, n_buckets=n_buckets),
        SphereStage("sort",
                    lambda rs: sorted(rs, key=lambda r: r[:key_bytes])),
    ]


def sample_boundaries(records: Sequence[bytes], n_buckets: int,
                      key_bytes: int = 10) -> List[bytes]:
    """Sample keys to build balanced range boundaries (TeraSort pre-pass).

    Use ``key_bytes=4`` (or fewer) when the job will run on the array
    backend: 4-byte boundaries make the kernel's uint32 comparison exact.
    """
    keys = sorted(r[:key_bytes] for r in records)
    if not keys or n_buckets <= 1:
        return []
    step = len(keys) / n_buckets
    return [keys[min(int(step * i) - 1, len(keys) - 1)]
            for i in range(1, n_buckets)]
