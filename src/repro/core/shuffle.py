"""Partitioners for the Sphere shuffle."""
from __future__ import annotations

import hashlib
from typing import Callable, List, Sequence


def hash_partitioner(key_bytes: int = 8) -> Callable[[bytes, int], int]:
    def part(record: bytes, n: int) -> int:
        h = hashlib.md5(record[:key_bytes]).digest()
        return int.from_bytes(h[:4], "big") % n
    return part


def range_partitioner(boundaries: Sequence[bytes]) -> Callable[[bytes, int], int]:
    """TeraSort-style: bucket by key position among sorted boundaries."""
    bnd = list(boundaries)

    def part(record: bytes, n: int) -> int:
        key = record[:len(bnd[0])] if bnd else record
        lo, hi = 0, len(bnd)
        while lo < hi:
            mid = (lo + hi) // 2
            if key > bnd[mid]:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, n - 1)
    return part


def sample_boundaries(records: Sequence[bytes], n_buckets: int,
                      key_bytes: int = 10) -> List[bytes]:
    """Sample keys to build balanced range boundaries (TeraSort pre-pass)."""
    keys = sorted(r[:key_bytes] for r in records)
    if not keys or n_buckets <= 1:
        return []
    step = len(keys) / n_buckets
    return [keys[min(int(step * i) - 1, len(keys) - 1)]
            for i in range(1, n_buckets)]
