"""Partitioners for the Sphere shuffle — bytes reference + array backend.

Each partitioner is a callable ``(record: bytes, n: int) -> int`` (the
bytes reference path, unchanged engine protocol) and additionally exposes

* ``kernel_inputs(batch, n)`` — the (keys, bounds) uint32 rows the Pallas
  kernels compare, or ``None`` when the batch must take the host loop;
* ``bucket_ids(batch, n)`` — ids + histogram via ``bucket_partition``
  (the analysis path: ids come back to the caller);
* :func:`scatter_dispatch` / :func:`scatter_batch` — the engine shuffle
  path: the ``bucket_scatter`` kernel lands records bucket-contiguously
  ON DEVICE (stable counting scatter), and the only host sync is the
  final [n] histogram that slices the contiguous result into per-bucket
  batches (the same counts the planner's movement pricing needs).
  ``scatter_dispatch`` enqueues that work without blocking and defers
  the histogram sync into :meth:`ScatterDispatch.harvest`, so a caller
  shuffling many batches (the engine's per-worker loop) dispatches them
  all and pays ONE barrier per shuffle round; ``scatter_batch`` is the
  dispatch-plus-immediate-harvest convenience.  Batches are padded to a
  power-of-two row count and ``n_valid`` is dynamic, so one kernel trace
  serves every batch size at a given padded shape — this is what keeps
  engine-level throughput at kernel speed instead of re-tracing per
  per-worker batch size.

The kernel's rule is ``bucket = #{i : bounds[i] < key}``; both
partitioners phrase their bytes-side decision with exactly that rule so
the two paths agree record-for-record:

* ``HashPartitioner`` hashes the key prefix with FNV-1a 32-bit (scalar
  and vectorised twins in :mod:`repro.core.records`) and buckets the
  hash against ``uniform_hash_bounds``.
* ``RangePartitioner`` keeps the classic TeraSort binary search over
  sampled boundaries.  Its array path compares rows of big-endian uint32
  words lexicographically (the kernel's multi-word compare), covering
  boundaries of any length — 10-byte TeraSort keys use 3 words.  When
  boundary lengths vary, a trailing length word reproduces Python's
  shorter-prefix-sorts-first bytes ordering exactly, so the kernel path
  never needs the per-record host fallback.
"""
from __future__ import annotations

from bisect import bisect_left
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.records import (RecordBatch, StackedBatch,  # noqa: F401
                                _pow2_rows, _quarter_rows, fnv1a32,
                                scatter_by_ids, uniform_hash_bounds)
from repro.kernels.bucket_partition import (bucket_dest, bucket_partition,
                                            bucket_scatter)


def _kernel_partition(keys: jax.Array, bounds_u32: np.ndarray, n: int,
                      *, block_n: int = 1 << 20,
                      interpret: bool | None = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """bucket_partition over uint32 keys with degenerate-shape handling.

    ``keys`` is [N] (single-word) or [N, k] (multi-word rows) with
    ``bounds_u32`` shaped to match.  The Pallas kernel needs at least one
    boundary; n == 1 (or an empty boundary list) means every record lands
    in bucket 0.  When there are more boundaries than n - 1 the tail
    buckets are clamped onto n - 1, mirroring the ``min(lo, n - 1)`` in
    the bytes reference.
    """
    nrec = keys.shape[0]
    if nrec == 0 or n <= 1 or len(bounds_u32) == 0:
        ids = jnp.zeros((nrec,), jnp.int32)
        hist = jnp.zeros((max(n, 1),), jnp.int32).at[0].set(nrec)
        return ids, hist
    nb = len(bounds_u32) + 1
    ids, hist = bucket_partition(keys, jnp.asarray(bounds_u32), n_buckets=nb,
                                 block_n=min(block_n, nrec),
                                 interpret=interpret)
    if nb > n:  # clamp overflow buckets, fold their histogram tail
        ids = jnp.minimum(ids, n - 1)
        hist = hist[:n].at[n - 1].add(hist[n:].sum())
    return ids, hist


class HashPartitioner:
    """FNV-1a hash of the first ``key_bytes`` bytes -> uniform bucket."""

    def __init__(self, key_bytes: int = 8):
        self.key_bytes = key_bytes
        self._bounds: Dict[int, List[int]] = {}

    def _bounds_for(self, n: int) -> List[int]:
        if n not in self._bounds:
            self._bounds[n] = uniform_hash_bounds(n).tolist()
        return self._bounds[n]

    def __call__(self, record: bytes, n: int) -> int:
        h = fnv1a32(record[:self.key_bytes])
        return bisect_left(self._bounds_for(n), h)

    def kernel_inputs(self, batch: RecordBatch, n: int
                      ) -> Tuple[jax.Array, np.ndarray]:
        """(keys, bounds) uint32 rows for the Pallas kernels."""
        return batch.hash_keys_u32(self.key_bytes), uniform_hash_bounds(n)

    def scatter_spec(self, batch: RecordBatch, n: int):
        """(static key spec, bounds) for the jitted device scatter, or
        None when every record belongs in bucket 0."""
        if n <= 1:
            return None
        return ("hash", self.key_bytes), uniform_hash_bounds(n)

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        keys, bounds = self.kernel_inputs(batch, n)
        return _kernel_partition(keys, bounds, n,
                                 block_n=block_n, interpret=interpret)


class RangePartitioner:
    """TeraSort-style: bucket by key position among sorted boundaries."""

    def __init__(self, boundaries: Sequence[bytes]):
        self.bnd = list(boundaries)

    def __call__(self, record: bytes, n: int) -> int:
        bnd = self.bnd
        key = record[:len(bnd[0])] if bnd else record
        lo, hi = 0, len(bnd)
        while lo < hi:
            mid = (lo + hi) // 2
            if key > bnd[mid]:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, n - 1)

    def bounds_words(self, n_words: int, lengths: bool) -> np.ndarray:
        """Boundaries as [n-1, k] big-endian uint32 word rows, zero-padded
        to ``n_words`` words, plus a trailing byte-length word when
        ``lengths`` is set (the variable-length tiebreak)."""
        rows = []
        for b in self.bnd:
            padded = b[:4 * n_words].ljust(4 * n_words, b"\0")
            row = [int.from_bytes(padded[4 * i:4 * i + 4], "big")
                   for i in range(n_words)]
            if lengths:
                row.append(len(b))
            rows.append(row)
        return np.array(rows, dtype=np.uint32)

    def kernel_inputs(self, batch: RecordBatch, n: int
                      ) -> Tuple[jax.Array, np.ndarray]:
        """(keys, bounds) uint32 rows for the Pallas kernels.

        Multi-word lexicographic compare: boundary bytes and key
        prefixes become rows of big-endian uint32 words, so boundaries
        of any length stay on the kernel path.  A record's comparison
        key is its first len(bnd[0]) bytes (clipped to the record), so
        when any boundary length differs from that key length the
        zero-padded words can tie where the byte strings differ — a
        trailing length word reproduces bytes ordering exactly.
        """
        if not self.bnd:
            return batch.keys_u32(4), np.empty(0)
        key_len = min(len(self.bnd[0]), batch.record_size)
        width = max(key_len, max(len(b) for b in self.bnd))
        n_words = max(1, -(-width // 4))
        need_len = any(len(b) != key_len for b in self.bnd)
        keys = batch.key_words(key_len, n_words=n_words,
                               length_word=key_len if need_len else None)
        return keys, self.bounds_words(n_words, lengths=need_len)

    def scatter_spec(self, batch: RecordBatch, n: int):
        """(static key spec, bounds) for the jitted device scatter —
        same word-row construction as :meth:`kernel_inputs`, but the key
        extraction itself runs *inside* the jitted scatter so the whole
        shuffle of a padded batch is one compiled call."""
        if not self.bnd or n <= 1:
            return None
        key_len = min(len(self.bnd[0]), batch.record_size)
        width = max(key_len, max(len(b) for b in self.bnd))
        n_words = max(1, -(-width // 4))
        need_len = any(len(b) != key_len for b in self.bnd)
        return (("range", key_len, n_words, key_len if need_len else None),
                self.bounds_words(n_words, lengths=need_len))

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        keys, bounds = self.kernel_inputs(batch, n)
        return _kernel_partition(keys, bounds, n,
                                 block_n=block_n, interpret=interpret)


class ReducePartitioner:
    """Every record to bucket 0 — the reduction shuffle (e.g. k-means
    partials folding on one worker).  The array path computes ids and
    histogram directly instead of dropping to the per-record host loop
    that arbitrary ``lambda r, n: 0`` callables would take, so reduce
    stages stay on the array fast path even for a single tiny batch of
    partials."""

    def __call__(self, record: bytes, n: int) -> int:
        return 0

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        nrec = batch.num_records
        ids = jnp.zeros((nrec,), jnp.int32)
        hist = jnp.zeros((max(n, 1),), jnp.int32).at[0].set(nrec)
        return ids, hist


def hash_partitioner(key_bytes: int = 8) -> HashPartitioner:
    return HashPartitioner(key_bytes)


def reduce_partitioner() -> ReducePartitioner:
    return ReducePartitioner()


def range_partitioner(boundaries: Sequence[bytes]) -> RangePartitioner:
    return RangePartitioner(boundaries)


def _host_partition(batch: RecordBatch, partitioner, n: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-record host loop — the correctness fallback for partitioners
    the kernel cannot express."""
    ids_np = np.fromiter((partitioner(r, n) for r in batch.to_records()),
                         np.int32, count=batch.num_records)
    hist = np.bincount(ids_np, minlength=n).astype(np.int32)
    return jnp.asarray(ids_np), jnp.asarray(hist)


def partition_batch(batch: RecordBatch, partitioner, n: int, *,
                    block_n: int = 1 << 20, interpret: bool | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """(ids, hist) for a batch under any engine partitioner.

    Array-aware partitioners go through the Pallas kernel; arbitrary
    ``(record, n) -> int`` callables fall back to a per-record host loop
    so the array backend stays correct for custom partitioners.
    """
    batch = batch.compact()  # analysis keys are host-visible: no junk rows
    if hasattr(partitioner, "bucket_ids"):
        return partitioner.bucket_ids(batch, n, block_n=block_n,
                                      interpret=interpret)
    return _host_partition(batch, partitioner, n)


def shuffle_batch(batch: RecordBatch, partitioner, n: int, *,
                  block_n: int = 1 << 20, interpret: bool | None = None
                  ) -> List[RecordBatch]:
    """Partition + host-driven scatter: one kernel call, one host
    argsort, n gathers.  The engine uses :func:`scatter_batch` (fully
    device-resident) instead; this path remains for custom callable
    partitioners and as the ids-visible reference."""
    ids, hist = partition_batch(batch, partitioner, n, block_n=block_n,
                                interpret=interpret)
    return scatter_by_ids(batch, ids, hist)


# _pow2_rows / _quarter_rows live in repro.core.records (shared with
# StackedBatch.pack) and are re-exported above for their historical home.


def _single_bucket_pieces(batch: RecordBatch, n: int) -> List[RecordBatch]:
    return [batch] + [RecordBatch.empty(batch.record_size)
                      for _ in range(max(n, 1) - 1)]


@partial(jax.jit,
         static_argnames=("n_buckets", "key_spec", "block_n", "interpret"))
def _scatter_padded(data, bounds, n_valid, *, n_buckets: int, key_spec,
                    block_n: int | None, interpret: bool):
    """One compiled call for the whole padded-batch shuffle: key
    extraction (``key_spec`` is static — ``("hash", key_bytes)`` or
    ``("range", key_len, n_words, length_word)``), the bucket_scatter
    kernel, and its scan/scatter epilogue.  Re-traces only per
    (padded shape, key spec, n_buckets) — never per record count,
    because ``n_valid`` is dynamic."""
    keys = _extract_keys(data, key_spec)
    return bucket_scatter(data, keys, bounds, n_valid, n_buckets=n_buckets,
                          block_n=block_n, interpret=interpret)


def _cpu_block_n(rows: int) -> int | None:
    """Grid size for the interpret (CPU) kernel, or None for a single
    block.  The in-kernel rank scan is O(rows log rows) *per block*, so
    gridding a large input into 64k blocks beats one giant block by
    ~25% (measured: four 64k blocks vs one 256k block) and by several
    x at the 1M single-batch shape; below ~1.5 blocks the
    pad-to-block-multiple junk rows would outweigh the saved scan
    levels."""
    return 65536 if rows > 98304 else None


def _extract_keys(data, key_spec):
    batch = RecordBatch(data)
    if key_spec[0] == "hash":
        return batch.hash_keys_u32(key_spec[1])
    _, key_len, n_words, length_word = key_spec
    return batch.key_words(key_len, n_words=n_words, length_word=length_word)


@partial(jax.jit,
         static_argnames=("n_buckets", "key_spec", "block_n", "interpret"))
def _scatter_dest_padded(data, bounds, n_valid, *, n_buckets: int, key_spec,
                         block_n: int | None, interpret: bool):
    """The data-free twin of :func:`_scatter_padded`: key extraction +
    kernel + scan epilogue, stopping at the destination vector instead
    of moving the rows.  Used on CPU, where XLA lowers the [rows]
    permutation-inverting scatter at ~40ns/element while numpy's fancy
    assignment inverts it host-side at memcpy speed — so the rows are
    moved by a plain device gather against the host-inverted
    permutation at harvest time (see :meth:`ScatterDispatch.harvest`).
    """
    keys = _extract_keys(data, key_spec)
    return bucket_dest(keys, bounds, n_valid, n_buckets=n_buckets,
                       block_n=block_n, interpret=interpret)


@partial(jax.jit,
         static_argnames=("n_buckets", "key_spec", "block_n", "interpret"))
def _scatter_dest_segments(pieces, bounds, n_valids, *, n_buckets: int,
                           key_spec, block_n: int | None, interpret: bool):
    """Segmented twin of :func:`_scatter_dest_padded` for a WHOLE round:
    ``pieces`` is a tuple of s [rows, width] resident pieces at one
    ladder shape, junk tails in place — and ``n_valids`` [s] their
    dynamic valid counts.  The stack happens INSIDE the trace: an eager
    ``jnp.stack`` over s arrays dispatches s reshapes plus a
    concatenate (~1ms of pure host overhead per piece on CPU — it was
    the single largest line of a profiled round), while here XLA sees
    one fused concatenate.  Rows flatten in piece order and each
    piece's junk tail is masked into the trash bucket, so the
    destination vector orders valid rows bucket-major then
    global-input-major across the whole stack — exactly the order a
    concat of the pieces would have produced, without ever
    materialising the concat eagerly.  Returns the flattened data
    alongside (dest, hist) so the harvest gathers straight off it.
    Re-traces only per (piece count, piece shape, key spec, n_buckets)
    — ``n_valids`` is dynamic.

    The flatten is a direct 2D ``jnp.concatenate``, NOT stack+reshape:
    XLA:CPU turns the [s, rows, width] stack of 2D operands plus the
    flattening reshape into a program ~3x slower than the plain
    concatenate (measured 61-79ms vs 20-25ms for 33 x [6144, 100]
    uint8 pieces), while the 2D concat compiles to one linear copy."""
    rows, width = pieces[0].shape
    s = len(pieces)
    data = jnp.concatenate(pieces, axis=0)
    keys = _extract_keys(data, key_spec)
    pos = jax.lax.iota(jnp.int32, s * rows)
    valid = (pos % rows) < n_valids[pos // rows]
    dest, hist = bucket_dest(keys, bounds, valid.astype(jnp.int32),
                             n_buckets=n_buckets, block_n=block_n,
                             interpret=interpret)
    return data, dest, hist


@dataclass
class ScatterDispatch:
    """The in-flight half of a dispatch-then-sync shuffle.

    :func:`scatter_dispatch` returns one of these per batch after
    enqueueing all device work (pad, key extraction, kernel, epilogue)
    WITHOUT blocking.  A caller shuffling many batches dispatches them
    all first — the device queue stays full — then fetches every
    dispatch's :attr:`sync_arrays` in one host barrier and calls
    :meth:`harvest` with the synced values.  ``harvest()`` with no
    argument syncs this dispatch's own metadata (the compatibility path
    :func:`scatter_batch` uses).

    A pending dispatch is in one of two shapes, per backend:

    * **compiled (TPU/GPU)** — ``out`` holds the bucket-contiguous rows
      (the kernel's device epilogue already moved them); harvest slices
      it by the synced histogram.
    * **host-invert (CPU)** — ``src`` holds the untouched padded block
      and ``dest`` the destination vector; harvest inverts the
      permutation host-side (numpy fancy assignment at memcpy speed,
      where XLA:CPU's scatter crawls at ~40ns/element) and gathers each
      bucket's rows off ``src`` directly — only valid rows ever move.

    Either way the barrier is ONE ``device_get`` per round of [n]-sized
    (plus, on CPU, [rows]-sized int32) metadata — record bytes stay on
    device.  Degenerate/fallback shapes resolve at dispatch time into
    ``pieces``: those harvest for free, and ``host_syncs`` records any
    sync the fallback already paid (1 for the per-record host loop, else
    0), so executor-level sync accounting stays truthful.
    """

    n: int                                        # bucket count
    pieces: Optional[List[RecordBatch]] = None    # resolved at dispatch
    out: Optional[jax.Array] = None               # compiled: scattered rows
    src: Optional[jax.Array] = None               # host-invert: padded block
    dest: Optional[jax.Array] = None              # host-invert: [rows] dest
    hist: Optional[jax.Array] = None              # pending [n] counts
    host_syncs: int = field(default=0)            # syncs paid at dispatch

    @property
    def pending(self) -> bool:
        """True when metadata must reach the host before slicing."""
        return self.pieces is None

    @property
    def sync_arrays(self):
        """The device values the round barrier must fetch: the [n]
        histogram, plus the destination vector on the host-invert path."""
        return (self.hist,) if self.dest is None else (self.hist, self.dest)

    def harvest(self, synced=None) -> List[RecordBatch]:
        """Per-bucket batches.  ``synced`` is the already-fetched
        :attr:`sync_arrays` tuple (numpy); omitted, the dispatch syncs
        its own."""
        if self.pieces is not None:
            return self.pieces
        if synced is None:
            synced = jax.device_get(self.sync_arrays)   # host sync
        hist = np.asarray(synced[0])
        offsets = np.concatenate([[0], np.cumsum(hist)])
        if self.out is not None:
            self.pieces = [RecordBatch(self.out[offsets[i]:offsets[i + 1]])
                           for i in range(self.n)]
        else:
            dest = np.asarray(synced[1])
            perm = np.empty(dest.shape[0], np.int32)
            perm[dest] = np.arange(dest.shape[0], dtype=np.int32)
            self.pieces = [
                RecordBatch(jnp.take(self.src,
                                     jnp.asarray(perm[offsets[i]:
                                                      offsets[i + 1]]),
                                     axis=0))
                for i in range(self.n)]
        return self.pieces


def scatter_dispatch(batch: RecordBatch, partitioner, n: int, *,
                     pad_block: int = 4096, block_n: int | None = None,
                     interpret: bool | None = None) -> ScatterDispatch:
    """Enqueue the device-resident shuffle of one batch; never blocks.

    The fast path places the batch in a power-of-two-ladder block
    (floored at ``pad_block``; a padding-resident batch at a usable
    shape is reused as-is, junk tail included) and runs ONE jitted call
    — key extraction, ``bucket_scatter`` kernel and scan/scatter
    epilogue — with the real row count as a *dynamic* argument: records
    land bucket-contiguously on device without the bucket ids ever
    reaching the host, and one trace serves every batch size at a given
    padded shape.  The ONE host sync each batch ever needs is the final
    [n] histogram, deferred into :meth:`ScatterDispatch.harvest` so a
    caller with many batches pays it once for all of them.

    Within a bucket records keep input order (the kernel's stability
    guarantee), matching the bytes backend's append order exactly.
    Degenerate shapes (empty batch, single bucket, no boundaries) take a
    zero-kernel shortcut; partitioners without ``scatter_spec``
    (arbitrary ``(record, n) -> int`` callables) fall back to the
    host-loop + host-argsort path so correctness never depends on the
    kernel being expressible.
    """
    nrec = batch.num_records
    if n <= 1:
        return ScatterDispatch(n, pieces=[batch])
    if nrec == 0:
        empty = [batch.take(jnp.zeros((0,), jnp.int32)) for _ in range(n)]
        return ScatterDispatch(n, pieces=empty)
    if isinstance(partitioner, ReducePartitioner):
        return ScatterDispatch(n, pieces=_single_bucket_pieces(batch, n))
    if not hasattr(partitioner, "scatter_spec"):
        ids, hist = _host_partition(batch, partitioner, n)
        return ScatterDispatch(n, pieces=scatter_by_ids(batch, ids, hist),
                               host_syncs=1)
    spec = partitioner.scatter_spec(batch, n)
    if spec is None:
        return ScatterDispatch(n, pieces=_single_bucket_pieces(batch, n))
    key_spec, bounds = spec
    if interpret is None:
        # compiled Pallas lowering on real accelerators (TPU Mosaic /
        # GPU Triton); interpret mode only on CPU
        interpret = jax.default_backend() not in ("tpu", "gpu")
    data = batch.block(_pow2_rows(nrec, min(pad_block, 1 << 20)))
    if interpret:
        # CPU: stop the jitted call at the destination vector and let
        # harvest invert it host-side — numpy's fancy assignment beats
        # XLA:CPU's [rows] int32 scatter ~15x, and the harvest gather
        # then touches only the valid rows
        if block_n is None:
            block_n = _cpu_block_n(data.shape[0])
        dest, hist = _scatter_dest_padded(data, jnp.asarray(bounds), nrec,
                                          n_buckets=n, key_spec=key_spec,
                                          block_n=block_n, interpret=True)
        return ScatterDispatch(n, src=data, dest=dest, hist=hist)
    out, hist = _scatter_padded(data, jnp.asarray(bounds), nrec,
                                n_buckets=n, key_spec=key_spec,
                                block_n=block_n, interpret=interpret)
    return ScatterDispatch(n, out=out, hist=hist)


def scatter_batch(batch: RecordBatch, partitioner, n: int, *,
                  pad_block: int = 4096, block_n: int | None = None,
                  interpret: bool | None = None) -> List[RecordBatch]:
    """Device-resident shuffle: batch in, n bucket-sliced batches out.

    Dispatch + immediate harvest (one host sync) — see
    :func:`scatter_dispatch` for the split the engine's shuffle loop
    uses to amortise that sync across every worker batch of a round.
    """
    return scatter_dispatch(batch, partitioner, n, pad_block=pad_block,
                            block_n=block_n, interpret=interpret).harvest()


def scatter_pieces_dispatch(pieces: Sequence[RecordBatch], partitioner,
                            n: int, *, pad_block: int = 4096,
                            block_n: int | None = None,
                            interpret: bool | None = None
                            ) -> ScatterDispatch:
    """Enqueue one worker's stage output — its list of resident pieces —
    as a single scatter; never blocks.

    The fast path is the SEGMENTED scatter: when every piece shares one
    resident ladder shape (the executor's fixed per-stage blocks make
    that the common case) and the partitioner is on the host-invert
    kernel path, the pieces enter the jitted call as a pytree and the
    stack, junk-tail masking and key extraction all trace into one
    fused program.  That removes the eager concat-to-ladder copy and
    its per-piece dispatch overhead (~1ms/op on a CPU host — profiled
    as the largest single line of a shuffle round), and the kernel runs
    on the pieces' resident rows instead of a re-padded ladder block.
    The destination vector still orders valid rows bucket-major then
    piece-then-input-major — byte-identical to what a concat would
    have produced.

    Everything else (single piece, ragged piece shapes, degenerate or
    host-loop partitioners, compiled backends whose device epilogue
    already moves the rows) concatenates and falls through to
    :func:`scatter_dispatch`, so the caller sees one ScatterDispatch
    either way.
    """
    if len(pieces) == 1:
        return scatter_dispatch(pieces[0], partitioner, n,
                                pad_block=pad_block, block_n=block_n,
                                interpret=interpret)
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    kernelish = (n > 1 and not isinstance(partitioner, ReducePartitioner)
                 and getattr(partitioner, "scatter_spec", None) is not None)
    nrec = sum(p.num_records for p in pieces)
    if kernelish and interpret and nrec:
        rows = pieces[0].padded_rows
        width = pieces[0].record_size
        if rows and all(p.padded_rows == rows and p.record_size == width
                        for p in pieces):
            spec = partitioner.scatter_spec(pieces[0], n)
            if spec is not None:
                key_spec, bounds = spec
                if block_n is None:
                    block_n = _cpu_block_n(len(pieces) * rows)
                n_valids = jnp.asarray([p.num_records for p in pieces],
                                       jnp.int32)
                src, dest, hist = _scatter_dest_segments(
                    tuple(p.data for p in pieces), jnp.asarray(bounds),
                    n_valids, n_buckets=n, key_spec=key_spec,
                    block_n=block_n, interpret=True)
                return ScatterDispatch(n, src=src, dest=dest, hist=hist)
    if kernelish and nrec:
        # concat+pad fusion for the non-segmented kernel path: build the
        # shape-ladder block the scatter would pad to anyway in ONE
        # copy, so scatter_dispatch's block() is a shape-match no-op
        batch = RecordBatch.concat_block(
            pieces, _pow2_rows(nrec, min(pad_block, 1 << 20)))
    else:
        batch = RecordBatch.concat(list(pieces))
    return scatter_dispatch(batch, partitioner, n, pad_block=pad_block,
                            block_n=block_n, interpret=interpret)


# --------------------------------------------------------------------------
# Fused worker-axis round: the whole shuffle of a stage — every slot's key
# extraction, kernel pass and destination bookkeeping — as O(1) dispatches
# over a StackedBatch, instead of one dispatch per worker.

#: Target rows per segmented-shard dispatch on the interpret (CPU)
#: lowering.  The interpret kernel's cost grows super-linearly with the
#: per-call row count at a fixed block_n (measured on the TeraSort 1M
#: shape, 200 slots x 5120 rows: one flat call 101ms, 8 shards of ~128k
#: rows 50ms — matching the old per-worker path — while a per-slot vmap
#: took 599ms), so the stacked round is cut into at most
#: ``_ROUND_MAX_SHARDS`` contiguous slot ranges of about this many rows.
_ROUND_SHARD_ROWS = 131072
_ROUND_MAX_SHARDS = 8


@partial(jax.jit,
         static_argnames=("size", "rows_eff", "n_buckets", "key_spec",
                          "block_n", "interpret"))
def _scatter_dest_shard(data, n_valids, bounds, lo, *, size: int,
                        rows_eff: int, n_buckets: int, key_spec,
                        block_n: int | None, interpret: bool):
    """Destination vector + histogram for one contiguous slot range of a
    stacked [s, rows, width] round — the stacked twin of
    :func:`_scatter_dest_segments`.  The shard is sliced INSIDE the jit
    (``lo`` is a dynamic start, ``size`` static), so the round re-traces
    only per shard size (at most two sizes: the even split and the
    remainder), never per shard position.  ``rows_eff`` trims each
    slot's pad-ladder tail to the round's own quarter-ladder (every
    junk row beyond it would ride through the mask, kernel scan and
    destination fetch — at a 5k-record round on 4096-row slots that's
    ~80% of the kernel's work); the slice is static inside the jit so
    XLA fuses it for free."""
    shard = jax.lax.dynamic_slice_in_dim(data, lo, size, axis=0)
    nv = jax.lax.dynamic_slice_in_dim(n_valids, lo, size, axis=0)
    shard = shard[:, :rows_eff]
    s, rows, width = shard.shape
    flat = shard.reshape(s * rows, width)
    keys = _extract_keys(flat, key_spec)
    pos = jax.lax.iota(jnp.int32, s * rows)
    valid = (pos % rows) < nv[pos // rows]
    return bucket_dest(keys, bounds, valid.astype(jnp.int32),
                       n_buckets=n_buckets, block_n=block_n,
                       interpret=interpret)


@partial(jax.jit,
         static_argnames=("n_buckets", "key_spec", "block_n", "interpret"))
def _scatter_stacked(data, bounds, n_valids, *, n_buckets: int, key_spec,
                     block_n: int | None, interpret: bool):
    """The compiled-backend stacked round: ``bucket_scatter`` (key
    extraction + kernel + on-device row movement) vmapped over the slot
    axis.  One call scatters EVERY slot's rows bucket-contiguously and
    returns the one [s, n_buckets] histogram the round syncs — rows
    never leave the device.  (On CPU the segmented-shard path above is
    used instead: interpret-mode vmap serialises the per-slot scans and
    is ~10x slower than shard-flattened calls at the 1M shape.)"""
    def one(slot, nv):
        keys = _extract_keys(slot, key_spec)
        return bucket_scatter(slot, keys, bounds, nv, n_buckets=n_buckets,
                              block_n=block_n, interpret=interpret)
    return jax.vmap(one)(data, n_valids)


@partial(jax.jit, static_argnames=("rows_eff",))
def _regroup_take(src, idx, *, rows_eff: int):
    """The round's regrouping gather: flatten the [s, rows, width]
    source and take the [W, block2] global row positions in one fused
    program (the reshape is a view inside the jit, never a copy).
    ``rows_eff`` is the same per-round row trim the scatter shards used
    — harvest positions are strided by it.  The gather itself always
    runs on a FLAT index (XLA:CPU's batched gather is ~2x slower than
    the equivalent 1-D take); the index reshape and the output's
    [wn, block2, width] restore are free inside the jit."""
    s, _, width = src.shape
    flat = jnp.take(src[:, :rows_eff].reshape(s * rows_eff, width),
                    idx.reshape(-1), axis=0)
    return flat.reshape(idx.shape[0], idx.shape[1], width)


@dataclass
class FusedRoundResult:
    """The regrouped output of one fused shuffle round.

    ``data`` is uint8 [n_workers, block2, width]: destination worker
    ``w``'s resident partition occupies slot ``w`` — its buckets
    ``{b : b % n_workers == w}`` concatenated in ascending bucket order,
    records within a bucket in (slot-major, then input) order — i.e.
    exactly the order the bytes backend's per-worker append loop
    produces.  ``counts`` is the host [n_workers] valid-row vector
    (``data`` tails are junk) and ``origins[b]`` maps origin worker name
    to the bytes bucket ``b`` drew from it — the planner's movement
    pricing input.

    Large rounds come back SHARDED instead of as one stack: ``groups``
    holds ``(w_start, stack)`` pairs covering consecutive worker ranges
    (and ``data`` is None).  XLA:CPU's gather falls off its fast path
    above ~``_ROUND_SHARD_ROWS`` rows per call (a single 1M-row take is
    ~2x slower than the same rows split across a few separate calls),
    so the harvest caps rows per regrouping call exactly like the
    scatter caps rows per shard — the call count stays bounded by
    ``_ROUND_MAX_SHARDS``, never O(workers).  ``data is None`` with no
    ``groups`` means the round carried no records.
    """

    data: Optional[jax.Array]
    counts: np.ndarray
    origins: List[Dict[str, int]]
    dispatches: int = 0
    groups: Optional[List[Tuple[int, jax.Array]]] = None

    @property
    def record_size(self) -> int:
        if self.data is not None:
            return self.data.shape[2]
        if self.groups:
            return self.groups[0][1].shape[2]
        return 0


@dataclass
class StackedRoundDispatch:
    """The in-flight half of a FUSED shuffle round (cf. the per-batch
    :class:`ScatterDispatch`).

    :func:`scatter_round_dispatch` enqueues the whole round's device
    work — O(1) compiled calls regardless of worker or task count —
    and defers the single metadata sync into :meth:`harvest`.  Two
    lowerings share this container:

    * **segmented (CPU)** — at most ``_ROUND_MAX_SHARDS`` shard calls of
      :func:`_scatter_dest_shard`; ``metas`` holds each shard's
      (dest, hist) and harvest inverts the permutations host-side
      (numpy fancy assignment at memcpy speed).
    * **vmapped (TPU/GPU)** — ONE :func:`_scatter_stacked` call whose
      device epilogue already moved the rows; ``metas`` holds the
      [s, n] per-slot histogram and harvest only computes offsets.

    Either way :attr:`sync_arrays` is fetched in one ``device_get`` per
    round and :meth:`harvest` finishes with ONE gather that lands every
    destination worker's regrouped partition in a single stacked array —
    the device-side segment permutation that replaces the per-worker
    ``RecordBatch.concat`` loop.
    """

    n: int                           # bucket count
    worker_names: List[str]          # destination ring (bucket b -> b % W)
    slot_workers: np.ndarray         # [s] origin ring index per slot
    rows: int                        # padded rows per slot
    width: int
    pad_block: int
    src: jax.Array                   # [s, rows, width] round source
    mode: str                        # "segmented" | "vmapped"
    shards: List[Tuple[int, int]]    # segmented: (lo, size) slot ranges
    metas: List[Tuple[jax.Array, ...]]
    dispatches: int = 0
    host_syncs: int = 0

    @property
    def sync_arrays(self):
        """Device metadata the round barrier fetches — per-shard
        (dest, hist) on the segmented path, the [s, n] histogram on the
        vmapped path.  Record bytes never cross."""
        return tuple(a for m in self.metas for a in m)

    def harvest(self, synced=None) -> FusedRoundResult:
        """Regroup the round onto destination workers.  ``synced`` is
        the already-fetched :attr:`sync_arrays` tuple; omitted, the
        dispatch syncs its own (counted in :attr:`host_syncs`)."""
        if synced is None:
            synced = jax.device_get(self.sync_arrays)
            self.host_syncs += 1
        W, B, rows = len(self.worker_names), self.n, self.rows
        seg_pos: List[List[np.ndarray]] = [[] for _ in range(B)]
        origin_counts = np.zeros((B, W), np.int64)
        if self.mode == "segmented":
            i = 0
            for lo, size in self.shards:
                dest = np.asarray(synced[i])
                hist = np.asarray(synced[i + 1])
                i += 2
                perm = np.empty(dest.shape[0], np.int32)
                perm[dest] = np.arange(dest.shape[0], dtype=np.int32)
                off = np.concatenate(([0], np.cumsum(hist[:B])))
                n_valid = int(off[B])
                if not n_valid:
                    continue
                # dest order is bucket-contiguous, so perm[:n_valid] is
                # every bucket's ascending input rows back to back;
                # int32 throughout — global positions top out at s*rows
                gpos_all = perm[:n_valid] + np.int32(lo * rows)
                # each bucket's run is ascending, so slot boundaries
                # fall out of a searchsorted against the shard's slot
                # edges — origin pricing without touching every row
                # (the per-row bucket/worker decode was ~9ms of a ~20ms
                # 1M harvest)
                edges = (lo + np.arange(1, size)) * rows
                shard_workers = self.slot_workers[lo:lo + size]
                for b in range(B):
                    if off[b + 1] > off[b]:
                        seg = gpos_all[off[b]:off[b + 1]]
                        seg_pos[b].append(seg)
                        per_slot = np.diff(np.concatenate(
                            ([0], np.searchsorted(seg, edges),
                             [seg.size])))
                        np.add.at(origin_counts[b], shard_workers,
                                  per_slot)
        else:
            hist_sb = np.asarray(synced[0])[:, :B].astype(np.int64)
            off_sb = np.cumsum(hist_sb, axis=1) - hist_sb  # exclusive
            for b in range(B):
                for s in range(hist_sb.shape[0]):
                    c = int(hist_sb[s, b])
                    if c:
                        start = s * rows + int(off_sb[s, b])
                        seg_pos[b].append(
                            np.arange(start, start + c, dtype=np.int64))
                        origin_counts[b, self.slot_workers[s]] += c
        origins = [
            {self.worker_names[w]: int(origin_counts[b, w]) * self.width
             for w in np.nonzero(origin_counts[b])[0]}
            for b in range(B)]
        counts = np.zeros(W, np.int64)
        hist_total = origin_counts.sum(axis=1)
        for b in range(B):
            counts[b % W] += hist_total[b]
        nmax = int(counts.max()) if W else 0
        if nmax == 0:
            return FusedRoundResult(None, counts, origins, 0)
        # the regrouped stack gets its own quarter-ladder row count (same
        # trim rationale as scatter_round_dispatch's rows_eff: the
        # stage's pad_block floor would make a 1k-record partition carry
        # a 4096-row gather output)
        block2 = _quarter_rows(nmax, min(self.pad_block, 256))

        def idx_rows(ws) -> np.ndarray:
            """Global gather positions for workers ``ws`` (consecutive):
            each worker's buckets ascending, shard order within a
            bucket, input order within a shard — the bytes backend's
            append order.  Junk tail slots point at row 0; their content
            is never read (counts marks the valid prefixes)."""
            sub = np.zeros((len(ws), block2), np.int32)
            for j, w in enumerate(ws):
                fill = 0
                for b in range(w, B, W):
                    for gpos in seg_pos[b]:
                        sub[j, fill:fill + gpos.size] = gpos
                        fill += gpos.size
            return sub

        # The regrouping gather(s).  The [s, rows] -> [s*rows] flatten
        # happens INSIDE the gather jit where XLA fuses it away — an
        # eager reshape on XLA:CPU is a full copy of the round (~60ms at
        # the 1M shape).  Rows per call are capped like the scatter
        # shards: XLA:CPU's gather loses its fast path above
        # ~_ROUND_SHARD_ROWS rows per call, so big rounds split into at
        # most _ROUND_MAX_SHARDS worker-contiguous group takes —
        # bounded, never O(workers) — and each group's take is
        # dispatched as soon as its index rows are built, so the host
        # index build for group g+1 hides behind group g's gather.
        n_groups = int(min(_ROUND_MAX_SHARDS, W,
                           max(1, (W * block2) // _ROUND_SHARD_ROWS)))
        if n_groups <= 1:
            data = _regroup_take(self.src, jnp.asarray(idx_rows(range(W))),
                                 rows_eff=self.rows)
            return FusedRoundResult(data, counts, origins, 1)
        groups: List[Tuple[int, jax.Array]] = []
        w0 = 0
        for part in np.array_split(np.arange(W), n_groups):
            ws = [w0 + j for j in range(int(part.size))]
            groups.append(
                (w0, _regroup_take(self.src, jnp.asarray(idx_rows(ws)),
                                   rows_eff=self.rows)))
            w0 += int(part.size)
        return FusedRoundResult(None, counts, origins, n_groups,
                                groups=groups)


def scatter_round_dispatch(stacked: StackedBatch, partitioner, n: int, *,
                           worker_names: Sequence[str],
                           slot_workers=None, pad_block: int = 4096,
                           block_n: int | None = None,
                           interpret: bool | None = None,
                           lowering: str | None = None
                           ) -> Optional[StackedRoundDispatch]:
    """Enqueue a WHOLE round's shuffle over a stacked slot axis; never
    blocks.  Returns ``None`` when the round cannot stay on the fused
    kernel path (single bucket, reduce shuffle, host-loop partitioner,
    empty stack) — the caller falls back to the per-worker dispatch loop.

    ``slot_workers[i]`` names (by index into ``worker_names``) the worker
    whose stage output slot ``i`` holds, for movement accounting; slots
    must be ordered worker-major (ascending ``worker_names`` order, plan
    order within a worker) so the regrouped record order matches the
    bytes backend's append order record-for-record.  ``lowering``
    forces ``"segmented"`` / ``"vmapped"`` (default: segmented on the
    interpret/CPU backend, vmapped on compiled backends)."""
    s, rows, width = stacked.data.shape
    if n <= 1 or s == 0 or rows == 0 \
            or isinstance(partitioner, ReducePartitioner) \
            or getattr(partitioner, "scatter_spec", None) is None:
        return None
    # partitioners are immutable after construction, so the per-round
    # (key spec, device bounds) pair is cached on the instance — the
    # spec build + bounds device_put are ~0.3ms of host work per round,
    # which is real money on a ~2ms small round
    cached = getattr(partitioner, "_round_spec_cache", None)
    if cached is not None and cached[0] == (n, width):
        _, key_spec, bounds_dev = cached
    else:
        spec = partitioner.scatter_spec(RecordBatch.empty(width), n)
        if spec is None:
            return None
        key_spec, bounds = spec
        bounds_dev = jnp.asarray(bounds)
        try:
            partitioner._round_spec_cache = ((n, width), key_spec,
                                             bounds_dev)
        except AttributeError:
            pass                       # __slots__ partitioner: skip cache
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    if lowering is None:
        lowering = "segmented" if interpret else "vmapped"
    W = len(worker_names)
    if slot_workers is None:
        slot_workers = np.arange(s, dtype=np.int64) % max(W, 1)
    else:
        slot_workers = np.asarray(slot_workers, dtype=np.int64)
    nv_dev = jnp.asarray(stacked.n_valid, jnp.int32)
    metas: List[Tuple[jax.Array, ...]] = []
    shards: List[Tuple[int, int]] = []
    if lowering == "vmapped":
        src, hist_sb = _scatter_stacked(stacked.data, bounds_dev, nv_dev,
                                        n_buckets=n, key_spec=key_spec,
                                        block_n=block_n, interpret=interpret)
        metas.append((hist_sb,))
        dispatches = 1              # the stacked scatter
    else:
        src = stacked.data          # flattened inside the harvest gather
        dispatches = 0
        # trim each slot to the round's own quarter-ladder row count:
        # pad-ladder slots carry the STAGE's block shape (e.g. 4096-row
        # floors), but the round only needs rows up to its max n_valid —
        # the trim is a static in-jit slice and cuts the kernel's junk
        # work ~4x on small rounds
        nv_max = int(np.max(stacked.n_valid)) if s else 0
        rows = min(rows, _quarter_rows(nv_max, 256))
        n_shards = min(s, max(1, min(_ROUND_MAX_SHARDS,
                                     -(-s * rows // _ROUND_SHARD_ROWS))))
        base_sz = -(-s // n_shards)
        lo = 0
        while lo < s:
            size = min(base_sz, s - lo)
            shard_bn = _cpu_block_n(size * rows) if block_n is None \
                else block_n
            dest, hist = _scatter_dest_shard(
                stacked.data, nv_dev, bounds_dev, lo, size=size,
                rows_eff=rows, n_buckets=n, key_spec=key_spec,
                block_n=shard_bn, interpret=interpret)
            metas.append((dest, hist))
            shards.append((lo, size))
            dispatches += 1
            lo += size
    return StackedRoundDispatch(
        n=n, worker_names=list(worker_names), slot_workers=slot_workers,
        rows=rows, width=width, pad_block=pad_block, src=src,
        mode=lowering, shards=shards, metas=metas, dispatches=dispatches)


def terasort_stages(bounds: Sequence[bytes], backend: str, n_buckets: int,
                    key_bytes: int = 10) -> list:
    """The canonical TeraSort stage pair (partition+shuffle, then sort)
    on either record backend — shared by benchmarks, examples and tests
    so the two paths always run the same job shape."""
    from repro.core.job import SphereStage
    part = range_partitioner(bounds)
    if backend == "array":
        # pad_value=0xff declares both batch UDFs pad-stable, so the
        # executor pads to a fixed block shape and traces each once:
        # identity trivially keeps padding rows at the tail, and the
        # stable sort sends all-0xff padding keys to the end (ties with a
        # real all-0xff key keep the real record first — input order).
        return [
            SphereStage("partition", batch_udf=lambda b: b,
                        partitioner=part, n_buckets=n_buckets,
                        pad_value=0xFF),
            SphereStage("sort",
                        batch_udf=lambda b: b.sort_by_key(key_bytes),
                        pad_value=0xFF),
        ]
    return [
        SphereStage("partition", lambda rs: list(rs),
                    partitioner=part, n_buckets=n_buckets),
        SphereStage("sort",
                    lambda rs: sorted(rs, key=lambda r: r[:key_bytes])),
    ]


def sample_boundaries(records: Sequence[bytes], n_buckets: int,
                      key_bytes: int = 10) -> List[bytes]:
    """Sample keys to build balanced range boundaries (TeraSort pre-pass).

    Boundaries of any length stay on the kernel path (multi-word
    compare), so full 10-byte TeraSort keys are fine on the array
    backend.  When ``n_buckets > len(records)`` some boundaries repeat
    (the tail buckets stay empty); the index is clamped at both ends so
    the result is always sorted.
    """
    keys = sorted(r[:key_bytes] for r in records)
    if not keys or n_buckets <= 1:
        return []
    step = len(keys) / n_buckets
    return [keys[min(max(int(step * i) - 1, 0), len(keys) - 1)]
            for i in range(1, n_buckets)]
