"""Partitioners for the Sphere shuffle — bytes reference + array backend.

Each partitioner is a callable ``(record: bytes, n: int) -> int`` (the
bytes reference path, unchanged engine protocol) and additionally exposes

* ``kernel_inputs(batch, n)`` — the (keys, bounds) uint32 rows the Pallas
  kernels compare, or ``None`` when the batch must take the host loop;
* ``bucket_ids(batch, n)`` — ids + histogram via ``bucket_partition``
  (the analysis path: ids come back to the caller);
* :func:`scatter_batch` — the engine shuffle path: the ``bucket_scatter``
  kernel lands records bucket-contiguously ON DEVICE (stable counting
  scatter), and the only host sync is the final [n] histogram that
  slices the contiguous result into per-bucket batches (the same counts
  the planner's movement pricing needs).  Batches are padded to a
  power-of-two row count and ``n_valid`` is dynamic, so one kernel trace
  serves every batch size at a given padded shape — this is what keeps
  engine-level throughput at kernel speed instead of re-tracing per
  per-worker batch size.

The kernel's rule is ``bucket = #{i : bounds[i] < key}``; both
partitioners phrase their bytes-side decision with exactly that rule so
the two paths agree record-for-record:

* ``HashPartitioner`` hashes the key prefix with FNV-1a 32-bit (scalar
  and vectorised twins in :mod:`repro.core.records`) and buckets the
  hash against ``uniform_hash_bounds``.
* ``RangePartitioner`` keeps the classic TeraSort binary search over
  sampled boundaries.  Its array path compares rows of big-endian uint32
  words lexicographically (the kernel's multi-word compare), covering
  boundaries of any length — 10-byte TeraSort keys use 3 words.  When
  boundary lengths vary, a trailing length word reproduces Python's
  shorter-prefix-sorts-first bytes ordering exactly, so the kernel path
  never needs the per-record host fallback.
"""
from __future__ import annotations

from bisect import bisect_left
from functools import partial
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.records import (RecordBatch, fnv1a32, scatter_by_ids,
                                uniform_hash_bounds)
from repro.kernels.bucket_partition import bucket_partition, bucket_scatter


def _kernel_partition(keys: jax.Array, bounds_u32: np.ndarray, n: int,
                      *, block_n: int = 1 << 20,
                      interpret: bool | None = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """bucket_partition over uint32 keys with degenerate-shape handling.

    ``keys`` is [N] (single-word) or [N, k] (multi-word rows) with
    ``bounds_u32`` shaped to match.  The Pallas kernel needs at least one
    boundary; n == 1 (or an empty boundary list) means every record lands
    in bucket 0.  When there are more boundaries than n - 1 the tail
    buckets are clamped onto n - 1, mirroring the ``min(lo, n - 1)`` in
    the bytes reference.
    """
    nrec = keys.shape[0]
    if nrec == 0 or n <= 1 or len(bounds_u32) == 0:
        ids = jnp.zeros((nrec,), jnp.int32)
        hist = jnp.zeros((max(n, 1),), jnp.int32).at[0].set(nrec)
        return ids, hist
    nb = len(bounds_u32) + 1
    ids, hist = bucket_partition(keys, jnp.asarray(bounds_u32), n_buckets=nb,
                                 block_n=min(block_n, nrec),
                                 interpret=interpret)
    if nb > n:  # clamp overflow buckets, fold their histogram tail
        ids = jnp.minimum(ids, n - 1)
        hist = hist[:n].at[n - 1].add(hist[n:].sum())
    return ids, hist


class HashPartitioner:
    """FNV-1a hash of the first ``key_bytes`` bytes -> uniform bucket."""

    def __init__(self, key_bytes: int = 8):
        self.key_bytes = key_bytes
        self._bounds: Dict[int, List[int]] = {}

    def _bounds_for(self, n: int) -> List[int]:
        if n not in self._bounds:
            self._bounds[n] = uniform_hash_bounds(n).tolist()
        return self._bounds[n]

    def __call__(self, record: bytes, n: int) -> int:
        h = fnv1a32(record[:self.key_bytes])
        return bisect_left(self._bounds_for(n), h)

    def kernel_inputs(self, batch: RecordBatch, n: int
                      ) -> Tuple[jax.Array, np.ndarray]:
        """(keys, bounds) uint32 rows for the Pallas kernels."""
        return batch.hash_keys_u32(self.key_bytes), uniform_hash_bounds(n)

    def scatter_spec(self, batch: RecordBatch, n: int):
        """(static key spec, bounds) for the jitted device scatter, or
        None when every record belongs in bucket 0."""
        if n <= 1:
            return None
        return ("hash", self.key_bytes), uniform_hash_bounds(n)

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        keys, bounds = self.kernel_inputs(batch, n)
        return _kernel_partition(keys, bounds, n,
                                 block_n=block_n, interpret=interpret)


class RangePartitioner:
    """TeraSort-style: bucket by key position among sorted boundaries."""

    def __init__(self, boundaries: Sequence[bytes]):
        self.bnd = list(boundaries)

    def __call__(self, record: bytes, n: int) -> int:
        bnd = self.bnd
        key = record[:len(bnd[0])] if bnd else record
        lo, hi = 0, len(bnd)
        while lo < hi:
            mid = (lo + hi) // 2
            if key > bnd[mid]:
                lo = mid + 1
            else:
                hi = mid
        return min(lo, n - 1)

    def bounds_words(self, n_words: int, lengths: bool) -> np.ndarray:
        """Boundaries as [n-1, k] big-endian uint32 word rows, zero-padded
        to ``n_words`` words, plus a trailing byte-length word when
        ``lengths`` is set (the variable-length tiebreak)."""
        rows = []
        for b in self.bnd:
            padded = b[:4 * n_words].ljust(4 * n_words, b"\0")
            row = [int.from_bytes(padded[4 * i:4 * i + 4], "big")
                   for i in range(n_words)]
            if lengths:
                row.append(len(b))
            rows.append(row)
        return np.array(rows, dtype=np.uint32)

    def kernel_inputs(self, batch: RecordBatch, n: int
                      ) -> Tuple[jax.Array, np.ndarray]:
        """(keys, bounds) uint32 rows for the Pallas kernels.

        Multi-word lexicographic compare: boundary bytes and key
        prefixes become rows of big-endian uint32 words, so boundaries
        of any length stay on the kernel path.  A record's comparison
        key is its first len(bnd[0]) bytes (clipped to the record), so
        when any boundary length differs from that key length the
        zero-padded words can tie where the byte strings differ — a
        trailing length word reproduces bytes ordering exactly.
        """
        if not self.bnd:
            return batch.keys_u32(4), np.empty(0)
        key_len = min(len(self.bnd[0]), batch.record_size)
        width = max(key_len, max(len(b) for b in self.bnd))
        n_words = max(1, -(-width // 4))
        need_len = any(len(b) != key_len for b in self.bnd)
        keys = batch.key_words(key_len, n_words=n_words,
                               length_word=key_len if need_len else None)
        return keys, self.bounds_words(n_words, lengths=need_len)

    def scatter_spec(self, batch: RecordBatch, n: int):
        """(static key spec, bounds) for the jitted device scatter —
        same word-row construction as :meth:`kernel_inputs`, but the key
        extraction itself runs *inside* the jitted scatter so the whole
        shuffle of a padded batch is one compiled call."""
        if not self.bnd or n <= 1:
            return None
        key_len = min(len(self.bnd[0]), batch.record_size)
        width = max(key_len, max(len(b) for b in self.bnd))
        n_words = max(1, -(-width // 4))
        need_len = any(len(b) != key_len for b in self.bnd)
        return (("range", key_len, n_words, key_len if need_len else None),
                self.bounds_words(n_words, lengths=need_len))

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        keys, bounds = self.kernel_inputs(batch, n)
        return _kernel_partition(keys, bounds, n,
                                 block_n=block_n, interpret=interpret)


class ReducePartitioner:
    """Every record to bucket 0 — the reduction shuffle (e.g. k-means
    partials folding on one worker).  The array path computes ids and
    histogram directly instead of dropping to the per-record host loop
    that arbitrary ``lambda r, n: 0`` callables would take, so reduce
    stages stay on the array fast path even for a single tiny batch of
    partials."""

    def __call__(self, record: bytes, n: int) -> int:
        return 0

    def bucket_ids(self, batch: RecordBatch, n: int, *,
                   block_n: int = 1 << 20, interpret: bool | None = None
                   ) -> Tuple[jax.Array, jax.Array]:
        nrec = batch.num_records
        ids = jnp.zeros((nrec,), jnp.int32)
        hist = jnp.zeros((max(n, 1),), jnp.int32).at[0].set(nrec)
        return ids, hist


def hash_partitioner(key_bytes: int = 8) -> HashPartitioner:
    return HashPartitioner(key_bytes)


def reduce_partitioner() -> ReducePartitioner:
    return ReducePartitioner()


def range_partitioner(boundaries: Sequence[bytes]) -> RangePartitioner:
    return RangePartitioner(boundaries)


def _host_partition(batch: RecordBatch, partitioner, n: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-record host loop — the correctness fallback for partitioners
    the kernel cannot express."""
    ids_np = np.fromiter((partitioner(r, n) for r in batch.to_records()),
                         np.int32, count=batch.num_records)
    hist = np.bincount(ids_np, minlength=n).astype(np.int32)
    return jnp.asarray(ids_np), jnp.asarray(hist)


def partition_batch(batch: RecordBatch, partitioner, n: int, *,
                    block_n: int = 1 << 20, interpret: bool | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """(ids, hist) for a batch under any engine partitioner.

    Array-aware partitioners go through the Pallas kernel; arbitrary
    ``(record, n) -> int`` callables fall back to a per-record host loop
    so the array backend stays correct for custom partitioners.
    """
    if hasattr(partitioner, "bucket_ids"):
        return partitioner.bucket_ids(batch, n, block_n=block_n,
                                      interpret=interpret)
    return _host_partition(batch, partitioner, n)


def shuffle_batch(batch: RecordBatch, partitioner, n: int, *,
                  block_n: int = 1 << 20, interpret: bool | None = None
                  ) -> List[RecordBatch]:
    """Partition + host-driven scatter: one kernel call, one host
    argsort, n gathers.  The engine uses :func:`scatter_batch` (fully
    device-resident) instead; this path remains for custom callable
    partitioners and as the ids-visible reference."""
    ids, hist = partition_batch(batch, partitioner, n, block_n=block_n,
                                interpret=interpret)
    return scatter_by_ids(batch, ids, hist)


def _pow2_rows(n: int, floor: int) -> int:
    """Smallest padded row count >= n from the {2^k, 1.5 * 2^k} ladder,
    floored at ``floor`` — the fixed shapes batches pad to so kernel
    traces are shared across batch sizes.  The half-octave step caps
    padding waste at ~33% (a pure power-of-two ladder can waste ~100%)
    while keeping the number of distinct traced shapes per octave at 2."""
    target = max(floor, 2)
    while target < n:
        if target + target // 2 >= n:
            return target + target // 2
        target *= 2
    return target


def _single_bucket_pieces(batch: RecordBatch, n: int) -> List[RecordBatch]:
    return [batch] + [RecordBatch.empty(batch.record_size)
                      for _ in range(max(n, 1) - 1)]


@partial(jax.jit,
         static_argnames=("n_buckets", "key_spec", "block_n", "interpret"))
def _scatter_padded(data, bounds, n_valid, *, n_buckets: int, key_spec,
                    block_n: int | None, interpret: bool):
    """One compiled call for the whole padded-batch shuffle: key
    extraction (``key_spec`` is static — ``("hash", key_bytes)`` or
    ``("range", key_len, n_words, length_word)``), the bucket_scatter
    kernel, and its scan/scatter epilogue.  Re-traces only per
    (padded shape, key spec, n_buckets) — never per record count,
    because ``n_valid`` is dynamic."""
    batch = RecordBatch(data)
    if key_spec[0] == "hash":
        keys = batch.hash_keys_u32(key_spec[1])
    else:
        _, key_len, n_words, length_word = key_spec
        keys = batch.key_words(key_len, n_words=n_words,
                               length_word=length_word)
    return bucket_scatter(data, keys, bounds, n_valid, n_buckets=n_buckets,
                          block_n=block_n, interpret=interpret)


def scatter_batch(batch: RecordBatch, partitioner, n: int, *,
                  pad_block: int = 4096, block_n: int | None = None,
                  interpret: bool | None = None) -> List[RecordBatch]:
    """Device-resident shuffle: batch in, n bucket-sliced batches out.

    The fast path pads the batch to a power-of-two row count (floored at
    ``pad_block``) and runs ONE jitted call — key extraction,
    ``bucket_scatter`` kernel and scan/scatter epilogue — with the real
    row count as a *dynamic* argument: records land bucket-contiguously
    on device without the bucket ids ever reaching the host, and one
    trace serves every batch size at a given padded shape.  The ONE host
    sync is the final [n] histogram, which both slices the contiguous
    result into per-bucket batches and gives the planner its per-bucket
    movement sizes.

    Within a bucket records keep input order (the kernel's stability
    guarantee), matching the bytes backend's append order exactly.
    Degenerate shapes (empty batch, single bucket, no boundaries) take a
    zero-kernel shortcut; partitioners without ``scatter_spec``
    (arbitrary ``(record, n) -> int`` callables) fall back to the
    host-loop + host-argsort path so correctness never depends on the
    kernel being expressible.
    """
    nrec = batch.num_records
    if n <= 1:
        return [batch]
    if nrec == 0:
        return [batch.take(jnp.zeros((0,), jnp.int32)) for _ in range(n)]
    if isinstance(partitioner, ReducePartitioner):
        return _single_bucket_pieces(batch, n)
    if not hasattr(partitioner, "scatter_spec"):
        ids, hist = _host_partition(batch, partitioner, n)
        return scatter_by_ids(batch, ids, hist)
    spec = partitioner.scatter_spec(batch, n)
    if spec is None:
        return _single_bucket_pieces(batch, n)
    key_spec, bounds = spec
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    padded = batch.pad_to(_pow2_rows(nrec, min(pad_block, 1 << 20)))
    out, hist = _scatter_padded(padded.data, jnp.asarray(bounds), nrec,
                                n_buckets=n, key_spec=key_spec,
                                block_n=block_n, interpret=interpret)
    offsets = np.concatenate([[0], np.cumsum(np.asarray(hist))])  # host sync
    return [RecordBatch(out[offsets[i]:offsets[i + 1]]) for i in range(n)]


def terasort_stages(bounds: Sequence[bytes], backend: str, n_buckets: int,
                    key_bytes: int = 10) -> list:
    """The canonical TeraSort stage pair (partition+shuffle, then sort)
    on either record backend — shared by benchmarks, examples and tests
    so the two paths always run the same job shape."""
    from repro.core.job import SphereStage
    part = range_partitioner(bounds)
    if backend == "array":
        # pad_value=0xff declares both batch UDFs pad-stable, so the
        # executor pads to a fixed block shape and traces each once:
        # identity trivially keeps padding rows at the tail, and the
        # stable sort sends all-0xff padding keys to the end (ties with a
        # real all-0xff key keep the real record first — input order).
        return [
            SphereStage("partition", batch_udf=lambda b: b,
                        partitioner=part, n_buckets=n_buckets,
                        pad_value=0xFF),
            SphereStage("sort",
                        batch_udf=lambda b: b.sort_by_key(key_bytes),
                        pad_value=0xFF),
        ]
    return [
        SphereStage("partition", lambda rs: list(rs),
                    partitioner=part, n_buckets=n_buckets),
        SphereStage("sort",
                    lambda rs: sorted(rs, key=lambda r: r[:key_bytes])),
    ]


def sample_boundaries(records: Sequence[bytes], n_buckets: int,
                      key_bytes: int = 10) -> List[bytes]:
    """Sample keys to build balanced range boundaries (TeraSort pre-pass).

    Boundaries of any length stay on the kernel path (multi-word
    compare), so full 10-byte TeraSort keys are fine on the array
    backend.  When ``n_buckets > len(records)`` some boundaries repeat
    (the tail buckets stay empty); the index is clamped at both ends so
    the result is always sorted.
    """
    keys = sorted(r[:key_bytes] for r in records)
    if not keys or n_buckets <= 1:
        return []
    step = len(keys) / n_buckets
    return [keys[min(max(int(step * i) - 1, 0), len(keys) - 1)]
            for i in range(1, n_buckets)]
