"""Sphere data plane: per-backend executors (planner/executor split).

An executor owns everything that touches record data — fetching chunks
from Sector (with bounded retries), running stage UDFs on the worker the
planner chose, bucketizing stage output for the shuffle, and materialising
the final per-bucket blobs.  The planner (:mod:`repro.core.planner`)
never sees a record; the executor never makes a placement decision.

* :class:`BytesExecutor` — the per-record Python reference.  A worker's
  partition is a list of ``bytes`` records.

* :class:`ArrayExecutor` — the device-resident backend.  A worker's
  partition is ONE :class:`RecordBatch` that stays on device across
  stages: UDF apply -> bucket_partition kernel -> argsort/gather ->
  device concat on the destination worker, with host bytes touched only
  when reading Sector chunks (stage 0) and materialising final outputs.
  Stage UDFs that declare ``pad_value`` are applied through a jit-once
  wrapper: inputs are padded to a fixed block shape (the next power of
  two at or above ``pad_block`` rows) so tasks share one traced shape
  instead of recompiling per task shape.

Both executors report identical shuffle flows (per-bucket origin bytes),
so the planner charges movement from each bucket's *actual* origin
workers and simulated time agrees across backends for the same job.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.job import SphereJob, SphereStage
from repro.core.planner import SphereReport, StagePlan
from repro.core.records import RecordBatch, scatter_by_ids
from repro.core.shuffle import partition_batch
from repro.sector.server import ServerDown

# per-bucket origin accounting: origins[i][worker] = bytes of bucket i
# that were produced on that worker
Origins = List[Dict[str, int]]


class _ExecutorBase:
    def __init__(self, client, workers: Sequence[str], max_retries: int = 3):
        self.client = client
        self.workers = list(workers)
        self.max_retries = max_retries

    def _fetch_chunk(self, key: str, rep: SphereReport) -> Optional[bytes]:
        """Read a stage-0 chunk, retrying over surviving replicas."""
        for _ in range(self.max_retries):
            try:
                return self.client.read_chunk(key)
            except (IOError, ServerDown):
                rep.retried += 1
                self.client.run_repair()
        return None


class BytesExecutor(_ExecutorBase):
    """Reference data plane: partitions are lists of Python bytes."""

    def empty_parts(self) -> Dict[str, List[bytes]]:
        return {w: [] for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: sum(len(r) for r in parts[w]) for w in self.workers}

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool
                  ) -> Dict[str, List[bytes]]:
        out: Dict[str, List[bytes]] = {w: [] for w in self.workers}
        for t in plan.tasks:
            if first_stage:
                blob = self._fetch_chunk(t.key, rep)
                if blob is None:
                    continue
                records = job.split_records(blob)
            else:
                records = parts.get(t.key)
                if not records:
                    continue
            out[t.executor].extend(stage.apply_bytes(records))
        return out

    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[bytes]], Origins]:
        """Reference shuffle: one partitioner call per Python record."""
        buckets: List[List[bytes]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        t0 = time.perf_counter()
        for w in self.workers:
            for r in out[w]:
                b = stage.partitioner(r, n)
                buckets[b].append(r)
                origins[b][w] = origins[b].get(w, 0) + len(r)
                rep.partitioned_records += 1
        rep.partition_seconds += time.perf_counter() - t0
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        for w in self.workers:
            parts[w] = []
        for i, bucket in enumerate(buckets):
            parts[self.workers[i % len(self.workers)]].extend(bucket)

    def set_parts(self, parts, out) -> None:
        for w in self.workers:
            parts[w] = out[w]

    def outputs(self, parts) -> List[bytes]:
        return [b"".join(parts[w]) for w in self.workers if parts[w]]


class _TracedUDF:
    """jit wrapper around a batch UDF that counts trace events — the
    trace-time side effect fires once per distinct input shape, so
    ``traces == 1`` certifies the stage compiled exactly once."""

    def __init__(self, name: str, udf):
        self.name = name
        self.udf = udf
        self.traces = 0
        self._jit = jax.jit(self._call)

    def _call(self, data: jax.Array) -> jax.Array:
        self.traces += 1
        out = self.udf(RecordBatch(data))
        if not isinstance(out, RecordBatch):
            raise TypeError(f"stage {self.name!r} batch_udf must return "
                            f"a RecordBatch, got {type(out).__name__}")
        return out.data

    def __call__(self, data: jax.Array) -> jax.Array:
        return self._jit(data)


class ArrayExecutor(_ExecutorBase):
    """Device-resident data plane: one RecordBatch per worker partition."""

    def __init__(self, client, workers: Sequence[str], max_retries: int = 3,
                 pad_block: int = 4096):
        super().__init__(client, workers, max_retries)
        self.pad_block = pad_block
        self._traced: Dict[int, _TracedUDF] = {}

    def empty_parts(self) -> Dict[str, Optional[RecordBatch]]:
        return {w: None for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: (parts[w].nbytes if parts[w] is not None else 0)
                for w in self.workers}

    # --------------------------------------------------------- UDF apply
    def _apply_padded(self, stage: SphereStage, batch: RecordBatch,
                      target: int, rep: SphereReport) -> RecordBatch:
        # keyed by stage identity, not name: same-named stages must not
        # share a traced UDF (the name is only the report label)
        traced = self._traced.get(id(stage))
        if traced is None:
            traced = self._traced[id(stage)] = _TracedUDF(
                stage.name, stage.batch_udf)
        n = batch.num_records
        data = batch.data
        if target != n:
            data = jnp.pad(data, ((0, target - n), (0, 0)),
                           constant_values=stage.pad_value)
        out = traced(data)
        # max-aggregate per report label: a retracing stage must not be
        # masked by a later same-named stage that traced once
        rep.udf_traces[stage.name] = max(rep.udf_traces.get(stage.name, 0),
                                         traced.traces)
        if out.shape[0] != target:
            raise ValueError(
                f"stage {stage.name!r} declares pad_value but its batch_udf "
                f"changed the row count ({target} -> {out.shape[0]}); "
                f"pad-stable UDFs must map padding rows to tail padding")
        return RecordBatch(out[:n])

    def _stage_block_shape(self, job: SphereJob, plan: StagePlan, parts,
                           first_stage: bool) -> int:
        """Fixed block shape for a pad-stable stage: power-of-two ceiling
        of the stage's largest task, floored at pad_block.  Row counts
        come from the plan's task sizes / resident partitions, so no
        batch has to be fetched (or held) to compute it."""
        max_rows = 0
        for t in plan.tasks:
            if first_stage:
                rows = t.nbytes // job.record_size
            else:
                batch = parts.get(t.key)
                rows = batch.num_records if batch is not None else 0
            max_rows = max(max_rows, rows)
        if not max_rows:
            return 0
        target = self.pad_block
        while target < max_rows:
            target *= 2
        return target

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool
                  ) -> Dict[str, List[RecordBatch]]:
        pad_stable = (stage.batch_udf is not None
                      and stage.pad_value is not None)
        # the one fixed shape every task of this stage pads to, so the
        # UDF traces exactly once per stage
        target = (self._stage_block_shape(job, plan, parts, first_stage)
                  if pad_stable else 0)
        out: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        for t in plan.tasks:
            if first_stage:
                blob = self._fetch_chunk(t.key, rep)
                if blob is None:
                    continue
                batch = job.split_batch(blob)
            else:
                batch = parts.get(t.key)
                if batch is None or not batch.num_records:
                    continue
            if pad_stable and target:
                out[t.executor].append(
                    self._apply_padded(stage, batch, target, rep))
            else:
                # legacy/compat path: bytes-udf decode, per-shape tracing
                out[t.executor].append(stage.apply_batch(batch))
        return out

    # ----------------------------------------------------------- shuffle
    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[RecordBatch]], Origins]:
        """Array shuffle: per worker, one Pallas bucket-partition kernel
        call (ids + histogram) and one argsort/segment gather.  Records
        never leave the device; only the tiny ids/hist arrays come back
        to the host to drive the gather."""
        buckets: List[List[RecordBatch]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        t0 = time.perf_counter()
        for w in self.workers:
            if not out[w]:
                continue
            batch = RecordBatch.concat(out[w])
            ids, hist = partition_batch(batch, stage.partitioner, n)
            for i, piece in enumerate(scatter_by_ids(batch, ids, hist)):
                if piece.num_records:
                    buckets[i].append(piece)
                    origins[i][w] = piece.nbytes
            rep.partitioned_records += batch.num_records
        rep.partition_seconds += time.perf_counter() - t0
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        # bucket i lives on worker i % len(workers); a destination holding
        # several buckets keeps them in bucket order (matching the bytes
        # path's append order), merged into one device-resident batch
        incoming: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        for i, pieces in enumerate(buckets):
            incoming[self.workers[i % len(self.workers)]].extend(pieces)
        for w in self.workers:
            parts[w] = (RecordBatch.concat(incoming[w])
                        if incoming[w] else None)

    def set_parts(self, parts, out) -> None:
        for w in self.workers:
            parts[w] = RecordBatch.concat(out[w]) if out[w] else None

    def outputs(self, parts) -> List[bytes]:
        # the ONLY host materialisation of record data after stage 0
        return [parts[w].to_bytes() for w in self.workers
                if parts[w] is not None and parts[w].num_records]


def make_executor(job: SphereJob, client, workers: Sequence[str], *,
                  max_retries: int = 3, pad_block: int = 4096):
    if job.backend == "array":
        return ArrayExecutor(client, workers, max_retries=max_retries,
                             pad_block=pad_block)
    return BytesExecutor(client, workers, max_retries=max_retries)
