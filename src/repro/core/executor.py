"""Sphere data plane: per-backend executors (planner/executor split).

An executor owns everything that touches record data — fetching chunks
from Sector (with bounded retries), running stage UDFs on the worker the
planner chose, bucketizing stage output for the shuffle, and materialising
the final per-bucket blobs.  The planner (:mod:`repro.core.planner`)
never sees a record; the executor never makes a placement decision.

* :class:`BytesExecutor` — the per-record Python reference.  A worker's
  partition is a list of ``bytes`` records.

* :class:`ArrayExecutor` — the device-resident backend.  A worker's
  partition is ONE :class:`RecordBatch` that stays on device across
  stages: UDF apply -> bucket_partition kernel -> argsort/gather ->
  device concat on the destination worker, with host bytes touched only
  when reading Sector chunks (stage 0) and materialising final outputs.
  Stage UDFs that declare ``pad_value`` are applied through a jit-once
  wrapper: inputs are padded to a fixed block shape (the next power of
  two at or above ``pad_block`` rows) so tasks share one traced shape
  instead of recompiling per task shape.

Both executors report identical shuffle flows (per-bucket origin bytes),
so the planner charges movement from each bucket's *actual* origin
workers and simulated time agrees across backends for the same job.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.job import SphereJob, SphereStage
from repro.core.planner import SphereReport, StagePlan
from repro.core.records import RecordBatch
from repro.core.shuffle import _quarter_rows, scatter_pieces_dispatch
from repro.sector.server import ServerDown

# per-bucket origin accounting: origins[i][worker] = bytes of bucket i
# that were produced on that worker
Origins = List[Dict[str, int]]


class _ExecutorBase:
    def __init__(self, client, workers: Sequence[str], max_retries: int = 3,
                 cache_chunks: bool = False, prefetch: bool = True):
        self.client = client
        self.workers = list(workers)
        self.max_retries = max_retries
        self.prefetch = prefetch
        # session mode: stage-0 chunks, once fetched and decoded, stay
        # resident (bytes: record lists; array: device RecordBatches) so
        # a chain of jobs over the same file pays the host round-trip
        # exactly once.  Keyed by chunk id; cleared by session.refresh().
        self._chunk_cache: Optional[Dict[str, object]] = \
            {} if cache_chunks else None

    def clear_chunk_cache(self) -> None:
        if self._chunk_cache is not None:
            self._chunk_cache.clear()

    def evict_chunks(self, keys) -> None:
        """Drop specific cached chunks — stream window retirement: an
        expired file's decoded chunks are released while every surviving
        cache entry stays untouched (and, on the array backend,
        device-resident)."""
        if self._chunk_cache is not None:
            for k in keys:
                self._chunk_cache.pop(k, None)

    def _fetch_chunk(self, key: str, rep: SphereReport) -> Optional[bytes]:
        """Read a stage-0 chunk, retrying over surviving replicas."""
        for _ in range(self.max_retries):
            try:
                return self.client.read_chunk(key)
            except (IOError, ServerDown):
                rep.retried += 1
                self.client.run_repair()
        return None

    def _stage0_input(self, job: SphereJob, key: str, rep: SphereReport):
        """Decoded stage-0 input for one chunk task, through the session
        chunk cache when enabled.  Returns None when every replica is
        gone."""
        if self._chunk_cache is not None and key in self._chunk_cache:
            return self._chunk_cache[key]
        blob = self._fetch_chunk(key, rep)
        if blob is None:
            return None
        decoded = self._decode_chunk(job, blob)
        if self._chunk_cache is not None:
            self._chunk_cache[key] = decoded
        return decoded

    # ------------------------------------------------- stage-0 prefetch
    def _prefetch_start(self, job: SphereJob, key: str):
        """Kick off fetch+decode of one chunk on a worker thread (None on
        a chunk-cache hit).  The thread makes ONE bare ``read_chunk``
        attempt — retry accounting and repair stay on the main thread so
        reports are bit-identical with prefetching off."""
        if self._chunk_cache is not None and key in self._chunk_cache:
            return None
        box: Dict[str, object] = {}

        def work():
            try:
                box["decoded"] = self._decode_chunk(
                    job, self.client.read_chunk(key))
            except BaseException as err:  # noqa: BLE001 — re-raised below
                box["error"] = err

        t = threading.Thread(target=work, daemon=True,
                             name=f"sphere-prefetch-{key}")
        t.start()
        return t, box

    def _prefetch_finish(self, job: SphereJob, key: str, handle,
                         rep: SphereReport):
        """Join a prefetch.  A failed read replays the chunk through the
        main-thread retry loop (:meth:`_stage0_input`) from attempt one,
        so ``rep.retried`` and repair behaviour match the synchronous
        path exactly; unexpected errors propagate."""
        if handle is None:  # cache hit at start time
            return self._stage0_input(job, key, rep)
        thread, box = handle
        thread.join()
        if "error" in box:
            if isinstance(box["error"], (IOError, ServerDown)):
                return self._stage0_input(job, key, rep)
            raise box["error"]
        decoded = box["decoded"]
        if self._chunk_cache is not None:
            self._chunk_cache[key] = decoded
        return decoded

    def _stage0_batches(self, job: SphereJob, tasks, rep: SphereReport
                        ) -> Iterator[tuple]:
        """Yield ``(task, decoded_input)`` for the stage-0 task list with
        a one-deep decode prefetch: while the caller runs (dispatches)
        task i, a worker thread fetches and decodes chunk i+1, so host
        I/O overlaps device compute.  Reads stay strictly sequential —
        the next fetch starts only after the previous one finished — so
        Sector client state (transfer log, cache warmth) evolves exactly
        as in the synchronous loop.  ``decoded_input`` is None when every
        replica of a chunk is gone (the caller skips the task)."""
        if not self.prefetch:
            for t in tasks:
                yield t, self._stage0_input(job, t.key, rep)
            return
        pending = None
        for i, t in enumerate(tasks):
            if pending is None:
                cur = self._stage0_input(job, t.key, rep)
            else:
                cur = self._prefetch_finish(job, t.key, pending, rep)
            pending = (self._prefetch_start(job, tasks[i + 1].key)
                       if i + 1 < len(tasks) else None)
            yield t, cur


class BytesExecutor(_ExecutorBase):
    """Reference data plane: partitions are lists of Python bytes."""

    def empty_parts(self) -> Dict[str, List[bytes]]:
        return {w: [] for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: sum(len(r) for r in parts[w]) for w in self.workers}

    def _decode_chunk(self, job: SphereJob, blob: bytes) -> List[bytes]:
        return job.split_records(blob)

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool
                  ) -> Dict[str, List[bytes]]:
        out: Dict[str, List[bytes]] = {w: [] for w in self.workers}
        if first_stage:
            source = self._stage0_batches(job, plan.tasks, rep)
        else:
            source = ((t, parts.get(t.key)) for t in plan.tasks)
        for t, records in source:
            if not records:
                continue
            if first_stage and self._chunk_cache is not None:
                # hand UDFs a copy: an in-place-mutating UDF (sort,
                # pop) must not corrupt the cache for later jobs
                records = list(records)
            out[t.executor].extend(stage.apply_bytes(records))
        return out

    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[bytes]], Origins]:
        """Reference shuffle: one partitioner call per Python record.
        Pure host work — a bytes shuffle round never syncs a device
        (``rep.host_syncs`` stays 0)."""
        buckets: List[List[bytes]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        rep.shuffle_rounds += 1
        t0 = time.perf_counter()
        for w in self.workers:
            for r in out[w]:
                b = stage.partitioner(r, n)
                buckets[b].append(r)
                origins[b][w] = origins[b].get(w, 0) + len(r)
                rep.partitioned_records += 1
        rep.partition_seconds += time.perf_counter() - t0
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        for w in self.workers:
            parts[w] = []
        for i, bucket in enumerate(buckets):
            parts[self.workers[i % len(self.workers)]].extend(bucket)

    def set_parts(self, parts, out) -> None:
        for w in self.workers:
            parts[w] = out[w]

    def outputs(self, parts) -> List[bytes]:
        return [b"".join(parts[w]) for w in self.workers if parts[w]]


class _TracedUDF:
    """jit wrapper around a pad-stable (or mask-aware) UDF that counts
    trace events — the trace-time side effect fires once per distinct
    input shape, so ``traces == 1`` certifies the stage compiled exactly
    once.

    Both modes jit over ``(data, n_valid, ...)`` with ``n_valid``
    dynamic, and normalise the block's padding tail to the stage's pad
    byte ON DEVICE before the UDF sees it: the executor hands over raw
    fixed-shape blocks (:meth:`RecordBatch.block`) whose padding content
    is junk — there is no host-side slice-then-repad copy per hop, and
    the one fused ``where`` inside the trace replaces it.

    Masked mode additionally passes the params pytree as a *dynamic*
    argument: every task of the stage — and every re-run of the stage
    across a chained session (e.g. k-means iterations with fresh
    centroids in ``params``) — shares one trace."""

    def __init__(self, name: str, udf, *, masked: bool = False,
                 pad_value: int = 0):
        self.name = name
        self.udf = udf
        self.pad_value = pad_value
        self.traces = 0
        self._jit = jax.jit(self._call_masked if masked else
                            self._call_padded)

    def _check(self, out) -> jax.Array:
        if not isinstance(out, RecordBatch):
            raise TypeError(f"stage {self.name!r} UDF must return "
                            f"a RecordBatch, got {type(out).__name__}")
        return out.data

    def _normalize(self, data: jax.Array, n_valid):
        """(mask, block with padding rows set to the stage pad byte) —
        junk tails must never reach a UDF: a pad-stable sort keys on the
        pad byte, and masked reductions may bitcast rows to floats where
        junk could be NaN (NaN * 0 still poisons a sum)."""
        mask = jnp.arange(data.shape[0], dtype=jnp.int32) < n_valid
        return mask, jnp.where(mask[:, None], data,
                               jnp.asarray(self.pad_value, data.dtype))

    def _call_padded(self, data: jax.Array, n_valid) -> jax.Array:
        self.traces += 1
        _, norm = self._normalize(data, n_valid)
        return self._check(self.udf(RecordBatch(norm)))

    def _call_masked(self, data: jax.Array, n_valid, params) -> jax.Array:
        self.traces += 1
        mask, norm = self._normalize(data, n_valid)
        return self._check(self.udf(RecordBatch(norm), mask, params))

    def __call__(self, *args) -> jax.Array:
        return self._jit(*args)


class ArrayExecutor(_ExecutorBase):
    """Device-resident data plane: one RecordBatch per worker partition."""

    def __init__(self, client, workers: Sequence[str], max_retries: int = 3,
                 pad_block: int = 4096, cache_chunks: bool = False,
                 prefetch: bool = True, timing_sync: bool = False):
        super().__init__(client, workers, max_retries,
                         cache_chunks=cache_chunks, prefetch=prefetch)
        self.pad_block = pad_block
        # benchmark honesty knob: block on every shuffled piece before
        # stopping the partition_seconds clock, so deferred-sync timing
        # can never report still-in-flight device work as finished.
        # Off by default — a timing-only barrier, excluded from the
        # host_syncs data-plane accounting.
        self.timing_sync = timing_sync

    def empty_parts(self) -> Dict[str, Optional[RecordBatch]]:
        return {w: None for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: (parts[w].nbytes if parts[w] is not None else 0)
                for w in self.workers}

    def _decode_chunk(self, job: SphereJob, blob: bytes) -> RecordBatch:
        return job.split_batch(blob)

    # --------------------------------------------------------- UDF apply
    def _traced_for(self, stage: SphereStage, udf, *,
                    masked: bool = False) -> _TracedUDF:
        pad_value = stage.pad_value or 0
        # the wrapper lives ON the stage object (not in an executor-side
        # id()-keyed dict): same-named stages keep their own traced UDFs,
        # a stage re-run across a whole session chain keeps one compiled
        # wrapper, and — now that the executor outlives individual jobs —
        # a dead stage can never collide with a new stage allocated at
        # the same address, nor does trace state accumulate unboundedly
        traced = getattr(stage, "_traced", None)
        if traced is None or traced.udf is not udf \
                or traced.pad_value != pad_value:
            traced = _TracedUDF(stage.name, udf, masked=masked,
                                pad_value=pad_value)
            stage._traced = traced
        return traced

    def _note_traces(self, stage: SphereStage, traced: _TracedUDF,
                     rep: SphereReport) -> None:
        # max-aggregate per report label: a retracing stage must not be
        # masked by a later same-named stage that traced once
        rep.udf_traces[stage.name] = max(rep.udf_traces.get(stage.name, 0),
                                         traced.traces)

    def _apply_masked(self, stage: SphereStage, batch: RecordBatch,
                      target: int, rep: SphereReport) -> RecordBatch:
        """Mask-aware reduction path: hand the UDF the stage's fixed
        block (padding normalised on device by the traced wrapper), a
        validity mask, and the stage's current params.  The output is
        returned whole — reduction outputs have no padding rows to
        slice off."""
        traced = self._traced_for(stage, stage.masked_udf, masked=True)
        out = traced(batch.block(target), batch.num_records, stage.params)
        self._note_traces(stage, traced, rep)
        return RecordBatch(out)

    def _apply_padded(self, stage: SphereStage, batch: RecordBatch,
                      target: int, rep: SphereReport) -> RecordBatch:
        """Pad-stable path: the UDF runs on the stage's fixed block and
        its output STAYS at block shape — the result is a
        padding-resident batch (``n_valid``) handed to the next hop
        as-is, instead of a slice-to-n copy here and a re-pad copy
        there."""
        traced = self._traced_for(stage, stage.batch_udf)
        n = batch.num_records
        out = traced(batch.block(target), n)
        self._note_traces(stage, traced, rep)
        if out.shape[0] != target:
            raise ValueError(
                f"stage {stage.name!r} declares pad_value but its batch_udf "
                f"changed the row count ({target} -> {out.shape[0]}); "
                f"pad-stable UDFs must map padding rows to tail padding")
        return RecordBatch(out, n_valid=n)

    def _stage_block_shape(self, job: SphereJob, plan: StagePlan, parts,
                           first_stage: bool) -> int:
        """Fixed block shape for a pad-stable stage: the stage's largest
        task rounded up on the quarter-octave
        {2^k, 1.25 * 2^k, 1.5 * 2^k, 1.75 * 2^k} ladder, floored at
        pad_block.  This shape is computed once per stage, so the finer
        ladder costs no extra traces while capping the junk-tail of
        resident pieces at ~25% worst case — typically a few percent —
        junk the segmented scatter would otherwise mask, scan and fetch
        every round (a pure power-of-two ceiling wastes up to ~100%).
        Row counts come from the plan's task sizes / resident
        partitions, so no batch has to be fetched (or held) to compute
        it."""
        max_rows = 0
        for t in plan.tasks:
            if first_stage:
                rows = t.nbytes // job.record_size
            else:
                batch = parts.get(t.key)
                rows = batch.num_records if batch is not None else 0
            max_rows = max(max_rows, rows)
        if not max_rows:
            return 0
        return _quarter_rows(max_rows, self.pad_block)

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool
                  ) -> Dict[str, List[RecordBatch]]:
        masked = stage.masked_udf is not None
        pad_stable = (stage.batch_udf is not None
                      and stage.pad_value is not None)
        # the one fixed shape every task of this stage pads to, so the
        # UDF traces exactly once per stage
        target = (self._stage_block_shape(job, plan, parts, first_stage)
                  if masked or pad_stable else 0)
        out: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        if first_stage:
            source = self._stage0_batches(job, plan.tasks, rep)
        else:
            source = ((t, parts.get(t.key)) for t in plan.tasks)
        for t, batch in source:
            if batch is None or not batch.num_records:
                continue
            if masked:
                # a mask-aware stage NEVER leaves the fixed-shape array
                # path — even a single tiny partial batch in a chained
                # reduce job pads up to the block shape rather than
                # silently taking a decode/bytes fallback
                if batch.num_records:
                    out[t.executor].append(
                        self._apply_masked(stage, batch, target, rep))
            elif pad_stable and target:
                out[t.executor].append(
                    self._apply_padded(stage, batch, target, rep))
            else:
                # legacy/compat path: bytes-udf decode, per-shape tracing
                # (shape-polymorphic UDFs see exact batches, never junk
                # padding rows)
                out[t.executor].append(stage.apply_batch(batch.compact()))
        return out

    # ----------------------------------------------------------- shuffle
    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[RecordBatch]], Origins]:
        """Dispatch-then-sync array shuffle.

        Phase 1 enqueues each worker's scatter without blocking —
        :func:`scatter_pieces_dispatch` takes the worker's resident
        pieces straight into ONE jitted call (stack + junk-tail mask +
        key-extract + kernel trace as one fused program; no eager
        concat-and-re-pad copy) whenever the pieces share a ladder
        shape, and concatenates to the shape ladder otherwise.  Phase 2
        harvests every dispatch's metadata behind ONE barrier and
        resolves each worker's per-bucket pieces.  One kernel-path
        shuffle round therefore costs exactly one host sync —
        ``rep.host_syncs`` advances by 1 per round, not by the worker
        count — which is the invariant tests assert.  Degenerate
        batches (reduce rounds, single bucket) resolve at dispatch
        time; a round of only those syncs zero times (host-loop
        fallbacks excepted — they pay their sync at dispatch and say
        so).

        Batches pad to power-of-two-ladder row counts (floored at
        ``pad_block``), so the kernel traces once per padded shape, not
        once per batch size; padding-resident stage outputs feed the
        scatter at their resident shape (junk tails ride to the kernel's
        trash bucket) instead of being sliced and re-padded."""
        buckets: List[List[RecordBatch]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        rep.shuffle_rounds += 1
        t0 = time.perf_counter()
        round_: List[Tuple[str, int, object]] = []
        for w in self.workers:                      # phase 1: dispatch all
            pieces = out[w]
            if not pieces:
                continue
            disp = scatter_pieces_dispatch(pieces, stage.partitioner, n,
                                           pad_block=self.pad_block)
            rep.host_syncs += disp.host_syncs
            round_.append((w, sum(p.num_records for p in pieces), disp))
        pending = [d for (_, _, d) in round_ if d.pending]
        if pending:                                 # phase 2: one barrier
            synced = jax.device_get([d.sync_arrays for d in pending])
            rep.host_syncs += 1
            for d, s in zip(pending, synced):
                d.harvest(synced=s)
        for w, nrec, disp in round_:
            for i, piece in enumerate(disp.harvest()):
                if piece.num_records:
                    buckets[i].append(piece)
                    origins[i][w] = piece.nbytes
            rep.partitioned_records += nrec
        if self.timing_sync:
            jax.block_until_ready([p.data for bucket in buckets
                                   for p in bucket])
        rep.partition_seconds += time.perf_counter() - t0
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        # bucket i lives on worker i % len(workers); a destination holding
        # several buckets keeps them in bucket order (matching the bytes
        # path's append order), merged into one device-resident batch
        incoming: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        for i, pieces in enumerate(buckets):
            incoming[self.workers[i % len(self.workers)]].extend(pieces)
        for w in self.workers:
            parts[w] = (RecordBatch.concat(incoming[w])
                        if incoming[w] else None)

    def set_parts(self, parts, out) -> None:
        for w in self.workers:
            parts[w] = RecordBatch.concat(out[w]) if out[w] else None

    def outputs(self, parts) -> List[bytes]:
        # the ONLY host materialisation of record data after stage 0
        return [parts[w].to_bytes() for w in self.workers
                if parts[w] is not None and parts[w].num_records]


def make_executor(backend: str, client, workers: Sequence[str], *,
                  max_retries: int = 3, pad_block: int = 4096,
                  cache_chunks: bool = False, prefetch: bool = True,
                  timing_sync: bool = False):
    if backend == "array":
        return ArrayExecutor(client, workers, max_retries=max_retries,
                             pad_block=pad_block, cache_chunks=cache_chunks,
                             prefetch=prefetch, timing_sync=timing_sync)
    return BytesExecutor(client, workers, max_retries=max_retries,
                         cache_chunks=cache_chunks, prefetch=prefetch)
