"""Sphere data plane: per-backend executors (planner/executor split).

An executor owns everything that touches record data — fetching chunks
from Sector (with bounded retries), running stage UDFs on the worker the
planner chose, bucketizing stage output for the shuffle, and materialising
the final per-bucket blobs.  The planner (:mod:`repro.core.planner`)
never sees a record; the executor never makes a placement decision.

* :class:`BytesExecutor` — the per-record Python reference.  A worker's
  partition is a list of ``bytes`` records.

* :class:`ArrayExecutor` — the device-resident backend.  A worker's
  partition is ONE :class:`RecordBatch` that stays on device across
  stages: UDF apply -> bucket_partition kernel -> argsort/gather ->
  device concat on the destination worker, with host bytes touched only
  when reading Sector chunks (stage 0) and materialising final outputs.
  Stage UDFs that declare ``pad_value`` are applied through a jit-once
  wrapper: inputs are padded to a fixed block shape (the next power of
  two at or above ``pad_block`` rows) so tasks share one traced shape
  instead of recompiling per task shape.

Both executors report identical shuffle flows (per-bucket origin bytes),
so the planner charges movement from each bucket's *actual* origin
workers and simulated time agrees across backends for the same job.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.job import SphereJob, SphereStage
from repro.core.planner import SphereReport, StagePlan
from repro.core.records import RecordBatch, StackedBatch
from repro.core.shuffle import (FusedRoundResult, _quarter_rows,
                                scatter_pieces_dispatch,
                                scatter_round_dispatch)
from repro.core.trace import NULL_TRACER
from repro.sector.server import ServerDown

# per-bucket origin accounting: origins[i][worker] = bytes of bucket i
# that were produced on that worker
Origins = List[Dict[str, int]]


class _ExecutorBase:
    def __init__(self, client, workers: Sequence[str], max_retries: int = 3,
                 cache_chunks: bool = False, prefetch: bool = True,
                 prefetch_depth: int = 1, tracer=None):
        self.client = client
        self.workers = list(workers)
        self.max_retries = max_retries
        self.prefetch = prefetch
        self.prefetch_depth = max(1, prefetch_depth)
        # wall-clock span tracer (NULL_TRACER = record nothing, but
        # spans still time themselves — the one timing idiom)
        self.tracer = tracer or NULL_TRACER
        # session mode: stage-0 chunks, once fetched and decoded, stay
        # resident (bytes: record lists; array: device RecordBatches) so
        # a chain of jobs over the same file pays the host round-trip
        # exactly once.  Keyed by chunk id; cleared by session.refresh().
        self._chunk_cache: Optional[Dict[str, object]] = \
            {} if cache_chunks else None

    def clear_chunk_cache(self) -> None:
        if self._chunk_cache is not None:
            self._chunk_cache.clear()

    def evict_chunks(self, keys) -> None:
        """Drop specific cached chunks — stream window retirement: an
        expired file's decoded chunks are released while every surviving
        cache entry stays untouched (and, on the array backend,
        device-resident)."""
        if self._chunk_cache is not None:
            for k in keys:
                self._chunk_cache.pop(k, None)

    def _fetch_chunk(self, key: str, rep: SphereReport) -> Optional[bytes]:
        """Read a stage-0 chunk, retrying over surviving replicas."""
        for _ in range(self.max_retries):
            try:
                return self.client.read_chunk(key)
            except (IOError, ServerDown):
                rep.retried += 1
                self.client.run_repair()
        return None

    def _stage0_input(self, job: SphereJob, key: str, rep: SphereReport):
        """Decoded stage-0 input for one chunk task, through the session
        chunk cache when enabled.  Returns None when every replica is
        gone."""
        if self._chunk_cache is not None and key in self._chunk_cache:
            return self._chunk_cache[key]
        with self.tracer.span("fetch-chunk", track="fetch",
                              attrs={"key": key}) as sp:
            blob = self._fetch_chunk(key, rep)
            if blob is None:
                sp.set_attrs(lost=True)
                return None
            decoded = self._decode_chunk(job, blob)
        if self._chunk_cache is not None:
            self._chunk_cache[key] = decoded
        return decoded

    # ------------------------------------------------- stage-0 prefetch
    def _stage0_batches(self, job: SphereJob, tasks, rep: SphereReport
                        ) -> Iterator[tuple]:
        """Yield ``(task, decoded_input)`` for the stage-0 task list with
        a ``prefetch_depth``-deep fetch+decode pipeline: ONE producer
        thread walks the chunks strictly in task order — so Sector
        client state (transfer log, cache warmth) evolves exactly as in
        the synchronous loop — pushing decoded inputs into a bounded
        queue the caller drains, so host I/O of up to ``prefetch_depth``
        chunks overlaps device compute.  The producer makes one bare
        ``read_chunk`` attempt per chunk; a failed read is replayed on
        the MAIN thread through :meth:`_stage0_input`'s retry loop from
        attempt one, so ``rep.retried`` and repair behaviour are
        bit-identical with prefetching off (and across depths).
        ``decoded_input`` is None when every replica of a chunk is gone
        (the caller skips the task)."""
        if not self.prefetch or len(tasks) <= 1:
            for t in tasks:
                yield t, self._stage0_input(job, t.key, rep)
            return
        q: "queue.Queue[tuple]" = queue.Queue(maxsize=self.prefetch_depth)

        def produce():
            for t in tasks:
                if self._chunk_cache is not None \
                        and t.key in self._chunk_cache:
                    # cache hits are resolved by the consumer (the cache
                    # may gain entries while this thread runs ahead)
                    q.put(("cache", None))
                    continue
                try:
                    with self.tracer.span("fetch-chunk", track="prefetch",
                                          attrs={"key": t.key}):
                        payload = self._decode_chunk(
                            job, self.client.read_chunk(t.key))
                    q.put(("ok", payload))
                except (IOError, ServerDown):
                    q.put(("retry", None))
                except BaseException as err:  # noqa: BLE001 — re-raised
                    q.put(("error", err))
                    return

        th = threading.Thread(target=produce, daemon=True,
                              name="sphere-prefetch")
        th.start()
        for t in tasks:
            kind, payload = q.get()
            if kind == "ok":
                if self._chunk_cache is not None:
                    self._chunk_cache[t.key] = payload
                yield t, payload
            elif kind in ("cache", "retry"):
                yield t, self._stage0_input(job, t.key, rep)
            else:
                raise payload
        th.join()


class BytesExecutor(_ExecutorBase):
    """Reference data plane: partitions are lists of Python bytes."""

    def empty_parts(self) -> Dict[str, List[bytes]]:
        return {w: [] for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: sum(len(r) for r in parts[w]) for w in self.workers}

    def _decode_chunk(self, job: SphereJob, blob: bytes) -> List[bytes]:
        return job.split_records(blob)

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool
                  ) -> Dict[str, List[bytes]]:
        out: Dict[str, List[bytes]] = {w: [] for w in self.workers}
        if first_stage:
            source = self._stage0_batches(job, plan.tasks, rep)
        else:
            source = ((t, parts.get(t.key)) for t in plan.tasks)
        for t, records in source:
            if not records:
                continue
            if first_stage and self._chunk_cache is not None:
                # hand UDFs a copy: an in-place-mutating UDF (sort,
                # pop) must not corrupt the cache for later jobs
                records = list(records)
            # stage-0 chunks land wherever they were computed; a later
            # stage's partition keeps its OWNER slot even when the
            # planner priced the compute elsewhere — merging two
            # partitions into one executor slot would destroy partition
            # identity (and a sort stage's per-partition record order)
            dst = t.executor if first_stage else t.key
            out[dst].extend(stage.apply_bytes(records))
        return out

    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[bytes]], Origins]:
        """Reference shuffle: one partitioner call per Python record.
        Pure host work — a bytes shuffle round never syncs a device
        (``rep.host_syncs`` stays 0)."""
        buckets: List[List[bytes]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        rep.shuffle_rounds += 1
        with self.tracer.span("shuffle-round", track="shuffle",
                              attrs={"backend": "bytes",
                                     "buckets": n}) as sp:
            for w in self.workers:
                for r in out[w]:
                    b = stage.partitioner(r, n)
                    buckets[b].append(r)
                    origins[b][w] = origins[b].get(w, 0) + len(r)
                    rep.partitioned_records += 1
        rep.partition_seconds += sp.wall_seconds
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        for w in self.workers:
            parts[w] = []
        for i, bucket in enumerate(buckets):
            parts[self.workers[i % len(self.workers)]].extend(bucket)

    def set_parts(self, parts, out) -> None:
        for w in self.workers:
            parts[w] = out[w]

    def outputs(self, parts) -> List[bytes]:
        return [b"".join(parts[w]) for w in self.workers if parts[w]]


class _TracedUDF:
    """jit wrapper around a pad-stable (or mask-aware) UDF that counts
    trace events — the trace-time side effect fires once per distinct
    input shape, so ``traces == 1`` certifies the stage compiled exactly
    once.

    Both modes jit over ``(data, n_valid, ...)`` with ``n_valid``
    dynamic, and normalise the block's padding tail to the stage's pad
    byte ON DEVICE before the UDF sees it: the executor hands over raw
    fixed-shape blocks (:meth:`RecordBatch.block`) whose padding content
    is junk — there is no host-side slice-then-repad copy per hop, and
    the one fused ``where`` inside the trace replaces it.

    Masked mode additionally passes the params pytree as a *dynamic*
    argument: every task of the stage — and every re-run of the stage
    across a chained session (e.g. k-means iterations with fresh
    centroids in ``params``) — shares one trace."""

    def __init__(self, name: str, udf, *, masked: bool = False,
                 pad_value: int = 0, mesh=None):
        self.name = name
        self.udf = udf
        self.pad_value = pad_value
        self.mesh = mesh
        self.traces = 0
        self._jit = jax.jit(self._call_masked if masked else
                            self._call_padded)
        # fused-round entry points: the whole stage as ONE vmapped call
        # over the stacked slot axis (``target`` static so one trace
        # serves every round at the stage's block shape)
        self._jit_stacked = jax.jit(self._call_stacked,
                                    static_argnames=("target",))
        self._jit_stack_pieces = jax.jit(self._call_stack_pieces,
                                         static_argnames=("target",))

    def _check(self, out) -> jax.Array:
        if not isinstance(out, RecordBatch):
            raise TypeError(f"stage {self.name!r} UDF must return "
                            f"a RecordBatch, got {type(out).__name__}")
        return out.data

    def _normalize(self, data: jax.Array, n_valid):
        """(mask, block with padding rows set to the stage pad byte) —
        junk tails must never reach a UDF: a pad-stable sort keys on the
        pad byte, and masked reductions may bitcast rows to floats where
        junk could be NaN (NaN * 0 still poisons a sum)."""
        mask = jnp.arange(data.shape[0], dtype=jnp.int32) < n_valid
        return mask, jnp.where(mask[:, None], data,
                               jnp.asarray(self.pad_value, data.dtype))

    def _call_padded(self, data: jax.Array, n_valid) -> jax.Array:
        self.traces += 1
        _, norm = self._normalize(data, n_valid)
        return self._check(self.udf(RecordBatch(norm)))

    def _call_masked(self, data: jax.Array, n_valid, params) -> jax.Array:
        self.traces += 1
        mask, norm = self._normalize(data, n_valid)
        return self._check(self.udf(RecordBatch(norm), mask, params))

    def _vmapped(self, data3: jax.Array, n_valids: jax.Array) -> jax.Array:
        """The per-slot body vmapped over the slot axis — and, when a
        mesh was supplied, lowered through ``shard_map`` over the
        ``data`` axis so each device runs only its resident slots."""
        fn = jax.vmap(self._call_padded)
        if self.mesh is not None:
            from repro.core.spmd import sphere_map
            fn = sphere_map(fn, self.mesh)
        return fn(data3, n_valids)

    def _call_stacked(self, data3: jax.Array, n_valids: jax.Array, *,
                      target: int) -> jax.Array:
        """Stacked [s, rows, width] input (a previous fused round's
        resident partitions); rows are adjusted to ``target`` in-jit —
        slicing off junk tail or growing it — before the vmapped body."""
        rows = data3.shape[1]
        if rows > target:
            data3 = data3[:, :target, :]
        elif rows < target:
            data3 = jnp.pad(data3, ((0, 0), (0, target - rows), (0, 0)))
        return self._vmapped(data3, n_valids)

    def _call_stack_pieces(self, pieces, n_valids: jax.Array, *,
                           target: int) -> jax.Array:
        """Tuple of per-task 2-D pieces (stage-0 decoded chunks) stacked
        INSIDE the trace: each piece pads/slices to ``target`` rows, one
        fused concatenate+reshape forms the [s, target, width] block —
        no eager per-piece dispatch, mirroring _scatter_dest_segments'
        in-jit stack rationale."""
        width = pieces[0].shape[1]
        blocks = []
        for p in pieces:
            r = p.shape[0]
            if r > target:
                p = p[:target]
            elif r < target:
                p = jnp.pad(p, ((0, target - r), (0, 0)))
            blocks.append(p)
        data3 = jnp.concatenate(blocks, axis=0) \
            .reshape(len(pieces), target, width)
        return self._vmapped(data3, n_valids)

    def stacked(self, data3: jax.Array, n_valids, target: int) -> jax.Array:
        return self._jit_stacked(data3, n_valids, target=target)

    def stack_pieces(self, pieces, n_valids, target: int) -> jax.Array:
        return self._jit_stack_pieces(tuple(pieces), n_valids,
                                      target=target)

    def __call__(self, *args) -> jax.Array:
        return self._jit(*args)


class _SlotRef:
    """One worker's partition as a VIEW into a round-stacked array.

    A fused round leaves every destination worker's records inside one
    [n_workers, block, width] device array (:class:`FusedRoundResult`);
    installing per-worker ``RecordBatch`` copies would undo the fusion
    with n_workers slice dispatches.  A ``_SlotRef`` instead records
    (stacked, slot index) and answers the host-side shape queries
    (``num_records``/``nbytes`` from the host count vector, no device
    op); :meth:`batch` materialises the slot as a padding-resident
    RecordBatch only when a non-fused consumer actually needs one.
    """

    __slots__ = ("stacked", "idx")

    def __init__(self, stacked: StackedBatch, idx: int):
        self.stacked = stacked
        self.idx = idx

    @property
    def num_records(self) -> int:
        return int(self.stacked.n_valid[self.idx])

    @property
    def record_size(self) -> int:
        return self.stacked.record_size

    @property
    def nbytes(self) -> int:
        return self.num_records * self.record_size

    def batch(self) -> RecordBatch:
        return self.stacked.slot(self.idx)


def _as_batch(part) -> Optional[RecordBatch]:
    """A parts-dict value as a RecordBatch (None stays None) — the
    read-side adapter every non-fused consumer goes through."""
    return part.batch() if isinstance(part, _SlotRef) else part


@dataclass
class _StackedOut:
    """A fused run_stage result: the whole stage output as ONE
    StackedBatch, plus each slot's origin worker (index into the
    executor's worker ring).  Slots are ordered worker-major (ascending
    worker order, plan order within a worker), which is exactly the
    iteration order of the per-worker dict path — so fused and
    per-worker rounds see records in the same global order."""

    stacked: StackedBatch
    slot_workers: np.ndarray

    def to_worker_dict(self, workers: Sequence[str]
                       ) -> Dict[str, List[RecordBatch]]:
        """Downgrade to the legacy per-worker pieces dict (used when the
        following shuffle cannot stay on the fused kernel path)."""
        out: Dict[str, List[RecordBatch]] = {w: [] for w in workers}
        for i in range(self.stacked.n_slots):
            if self.stacked.n_valid[i]:
                out[workers[int(self.slot_workers[i])]].append(
                    self.stacked.slot(i))
        return out


class ArrayExecutor(_ExecutorBase):
    """Device-resident data plane: one RecordBatch per worker partition.

    With ``fused_rounds`` (the default), pad-stable stages run the whole
    round — every task's UDF apply, every worker's bucket scatter, and
    the regrouping onto destination workers — through O(1) compiled
    dispatches over a stacked slot axis instead of a Python loop of
    per-task/per-worker calls (see :class:`_StackedOut`,
    :func:`scatter_round_dispatch` and :class:`FusedRoundResult`).
    Mask-aware, shape-polymorphic and host-loop shapes keep the
    per-task path.  Supplying ``mesh`` lowers the fused round through
    ``shard_map`` over the mesh's ``data`` axis with the bucket exchange
    as ``lax.all_to_all`` (``core.spmd.fused_scatter_round``)."""

    def __init__(self, client, workers: Sequence[str], max_retries: int = 3,
                 pad_block: int = 4096, cache_chunks: bool = False,
                 prefetch: bool = True, timing_sync: bool = False,
                 fused_rounds: bool = True, mesh=None,
                 prefetch_depth: int = 1, tracer=None):
        super().__init__(client, workers, max_retries,
                         cache_chunks=cache_chunks, prefetch=prefetch,
                         prefetch_depth=prefetch_depth, tracer=tracer)
        self.pad_block = pad_block
        self.fused_rounds = fused_rounds
        # the mesh only carries rounds whose slot/worker counts divide
        # its data axis; others silently use the single-device lowering
        self.mesh = mesh
        # benchmark honesty knob: block on every shuffled piece before
        # stopping the partition_seconds clock, so deferred-sync timing
        # can never report still-in-flight device work as finished.
        # Off by default — a timing-only barrier, excluded from the
        # host_syncs data-plane accounting.
        self.timing_sync = timing_sync

    def empty_parts(self) -> Dict[str, Optional[RecordBatch]]:
        return {w: None for w in self.workers}

    def part_sizes(self, parts) -> Dict[str, int]:
        return {w: (parts[w].nbytes if parts[w] is not None else 0)
                for w in self.workers}

    def _decode_chunk(self, job: SphereJob, blob: bytes) -> RecordBatch:
        return job.split_batch(blob)

    # --------------------------------------------------------- UDF apply
    def _traced_for(self, stage: SphereStage, udf, *,
                    masked: bool = False) -> _TracedUDF:
        pad_value = stage.pad_value or 0
        # the wrapper lives ON the stage object (not in an executor-side
        # id()-keyed dict): same-named stages keep their own traced UDFs,
        # a stage re-run across a whole session chain keeps one compiled
        # wrapper, and — now that the executor outlives individual jobs —
        # a dead stage can never collide with a new stage allocated at
        # the same address, nor does trace state accumulate unboundedly
        traced = getattr(stage, "_traced", None)
        if traced is None or traced.udf is not udf \
                or traced.pad_value != pad_value \
                or traced.mesh is not self.mesh:
            traced = _TracedUDF(stage.name, udf, masked=masked,
                                pad_value=pad_value, mesh=self.mesh)
            stage._traced = traced
        return traced

    def _note_traces(self, stage: SphereStage, traced: _TracedUDF,
                     rep: SphereReport) -> None:
        rep.note_udf_traces(stage.name, traced.traces)

    def _apply_masked(self, stage: SphereStage, batch: RecordBatch,
                      target: int, rep: SphereReport) -> RecordBatch:
        """Mask-aware reduction path: hand the UDF the stage's fixed
        block (padding normalised on device by the traced wrapper), a
        validity mask, and the stage's current params.  The output is
        returned whole — reduction outputs have no padding rows to
        slice off."""
        traced = self._traced_for(stage, stage.masked_udf, masked=True)
        with self.tracer.span("dispatch:udf", track="dispatch",
                              attrs={"stage": stage.name, "rows": target}):
            out = traced(batch.block(target), batch.num_records,
                         stage.params)
        rep.device_dispatches += 1
        self._note_traces(stage, traced, rep)
        return RecordBatch(out)

    def _apply_padded(self, stage: SphereStage, batch: RecordBatch,
                      target: int, rep: SphereReport) -> RecordBatch:
        """Pad-stable path: the UDF runs on the stage's fixed block and
        its output STAYS at block shape — the result is a
        padding-resident batch (``n_valid``) handed to the next hop
        as-is, instead of a slice-to-n copy here and a re-pad copy
        there."""
        traced = self._traced_for(stage, stage.batch_udf)
        n = batch.num_records
        with self.tracer.span("dispatch:udf", track="dispatch",
                              attrs={"stage": stage.name, "rows": target}):
            out = traced(batch.block(target), n)
        rep.device_dispatches += 1
        self._note_traces(stage, traced, rep)
        if out.shape[0] != target:
            raise ValueError(
                f"stage {stage.name!r} declares pad_value but its batch_udf "
                f"changed the row count ({target} -> {out.shape[0]}); "
                f"pad-stable UDFs must map padding rows to tail padding")
        return RecordBatch(out, n_valid=n)

    def _stage_block_shape(self, job: SphereJob, plan: StagePlan, parts,
                           first_stage: bool) -> int:
        """Fixed block shape for a pad-stable stage: the stage's largest
        task rounded up on the quarter-octave
        {2^k, 1.25 * 2^k, 1.5 * 2^k, 1.75 * 2^k} ladder, floored at
        pad_block.  This shape is computed once per stage, so the finer
        ladder costs no extra traces while capping the junk-tail of
        resident pieces at ~25% worst case — typically a few percent —
        junk the segmented scatter would otherwise mask, scan and fetch
        every round (a pure power-of-two ceiling wastes up to ~100%).
        Row counts come from the plan's task sizes / resident
        partitions, so no batch has to be fetched (or held) to compute
        it."""
        max_rows = 0
        for t in plan.tasks:
            if first_stage:
                rows = t.nbytes // job.record_size
            else:
                batch = parts.get(t.key)
                rows = batch.num_records if batch is not None else 0
            max_rows = max(max_rows, rows)
        if not max_rows:
            return 0
        return _quarter_rows(max_rows, self.pad_block)

    def run_stage(self, job: SphereJob, stage: SphereStage, plan: StagePlan,
                  parts, rep: SphereReport, *, first_stage: bool):
        masked = stage.masked_udf is not None
        pad_stable = (stage.batch_udf is not None
                      and stage.pad_value is not None)
        # the one fixed shape every task of this stage pads to, so the
        # UDF traces exactly once per stage
        target = (self._stage_block_shape(job, plan, parts, first_stage)
                  if masked or pad_stable else 0)
        if self.fused_rounds and pad_stable and target and plan.tasks:
            fused = self._run_stage_fused(job, stage, plan, parts, rep,
                                          first_stage, target)
            if fused is not None:
                return fused
        out: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        if first_stage:
            source = self._stage0_batches(job, plan.tasks, rep)
        else:
            source = ((t, _as_batch(parts.get(t.key))) for t in plan.tasks)
        for t, batch in source:
            if batch is None or not batch.num_records:
                continue
            # same owner-slot rule as the bytes executor: a later stage's
            # partition stays in its owner's slot regardless of where the
            # planner priced the compute
            dst = t.executor if first_stage else t.key
            if masked:
                # a mask-aware stage NEVER leaves the fixed-shape array
                # path — even a single tiny partial batch in a chained
                # reduce job pads up to the block shape rather than
                # silently taking a decode/bytes fallback
                if batch.num_records:
                    out[dst].append(
                        self._apply_masked(stage, batch, target, rep))
            elif pad_stable and target:
                out[dst].append(
                    self._apply_padded(stage, batch, target, rep))
            else:
                # legacy/compat path: bytes-udf decode, per-shape tracing
                # (shape-polymorphic UDFs see exact batches, never junk
                # padding rows)
                with self.tracer.span("dispatch:udf", track="dispatch",
                                      attrs={"stage": stage.name,
                                             "rows": batch.num_records}):
                    out[dst].append(stage.apply_batch(batch.compact()))
                rep.device_dispatches += 1
        return out

    def _check_stacked(self, stage: SphereStage, out, s: int, target: int
                       ) -> None:
        if out.ndim != 3 or out.shape[0] != s or out.shape[1] != target:
            raise ValueError(
                f"stage {stage.name!r} declares pad_value but its batch_udf "
                f"changed the row count ({target} -> {out.shape[1]}); "
                f"pad-stable UDFs must map padding rows to tail padding")

    def _mesh_slots(self, n: int) -> int:
        """Slot count padded up to a multiple of the mesh data axis (the
        shard_map sharding requirement); extra slots ride through with
        zero valid rows.  1 when no mesh is bound."""
        if self.mesh is None:
            return n
        d = self.mesh.shape.get("data", 1)
        return -(-n // d) * d

    def _aligned_stacked(self, parts) -> Optional[StackedBatch]:
        """The previous fused round's StackedBatch, when every worker's
        resident part is exactly its slot of ONE stack (the steady state
        of chained fused rounds) — lets the next stage consume the stack
        directly with zero per-worker slicing."""
        base: Optional[StackedBatch] = None
        for i, w in enumerate(self.workers):
            p = parts.get(w)
            if p is None:
                continue
            if not isinstance(p, _SlotRef) or p.idx != i:
                return None
            if base is None:
                base = p.stacked
            elif p.stacked is not base:
                return None
        if base is None or base.n_slots != len(self.workers):
            return None
        # empty workers hold None — consistent only if their slot counts
        # are zero (place_buckets guarantees this)
        return base

    def _run_stage_fused(self, job: SphereJob, stage: SphereStage,
                         plan: StagePlan, parts, rep: SphereReport,
                         first_stage: bool, target: int):
        """The whole stage as ONE vmapped UDF dispatch over a stacked
        slot axis.  Slots collect worker-major (ascending slot-worker
        order — the chunk's executor at stage 0, the partition's OWNER
        later, matching the per-worker dict path — plan order within a
        worker, so record order is preserved exactly).  Returns None
        when the stage must take the per-task path (a task placed on an
        unknown worker)."""
        windex = {w: i for i, w in enumerate(self.workers)}
        if any(t.executor not in windex for t in plan.tasks):
            return None
        traced = self._traced_for(stage, stage.batch_udf)
        if not first_stage:
            stacked = self._aligned_stacked(parts)
            if stacked is not None \
                    and stacked.n_slots == self._mesh_slots(stacked.n_slots):
                # steady state: the resident stack IS the stage input
                with self.tracer.span("dispatch:udf-fused", track="dispatch",
                                      attrs={"stage": stage.name,
                                             "slots": stacked.n_slots,
                                             "rows": target}):
                    out = traced.stacked(
                        stacked.data,
                        jnp.asarray(stacked.n_valid, jnp.int32), target)
                rep.device_dispatches += 1
                self._note_traces(stage, traced, rep)
                self._check_stacked(stage, out, stacked.n_slots, target)
                return _StackedOut(
                    StackedBatch(out, stacked.n_valid),
                    np.arange(stacked.n_slots, dtype=np.int64))
        items: List[Tuple[int, RecordBatch]] = []
        if first_stage:
            for t, batch in self._stage0_batches(job, plan.tasks, rep):
                if batch is not None and batch.num_records:
                    items.append((windex[t.executor], batch))
        else:
            for t in plan.tasks:
                batch = _as_batch(parts.get(t.key))
                if batch is not None and batch.num_records:
                    items.append((windex[t.key], batch))
        if not items:
            # nothing to run — return the legacy-shaped empty dict
            # directly (falling back to the per-task loop would replay
            # the stage-0 fetches, double-counting retries)
            return {w: [] for w in self.workers}
        items.sort(key=lambda p: p[0])          # stable: worker-major
        n_valid = np.fromiter((b.num_records for _, b in items), np.int32,
                              count=len(items))
        slot_workers = np.fromiter((i for i, _ in items), np.int64,
                                   count=len(items))
        pieces = [b.data for _, b in items]
        pad_slots = self._mesh_slots(len(items)) - len(items)
        if pad_slots:
            zero = jnp.zeros((target, items[0][1].record_size), jnp.uint8)
            pieces.extend([zero] * pad_slots)
            n_valid = np.concatenate([n_valid,
                                      np.zeros(pad_slots, np.int32)])
            slot_workers = np.concatenate(
                [slot_workers, np.zeros(pad_slots, np.int64)])
        with self.tracer.span("dispatch:udf-fused", track="dispatch",
                              attrs={"stage": stage.name,
                                     "slots": len(pieces), "rows": target}):
            out = traced.stack_pieces(pieces,
                                      jnp.asarray(n_valid, jnp.int32),
                                      target)
        rep.device_dispatches += 1
        self._note_traces(stage, traced, rep)
        self._check_stacked(stage, out, len(pieces), target)
        return _StackedOut(StackedBatch(out, n_valid), slot_workers)

    # ----------------------------------------------------------- shuffle
    def _bucketize_mesh(self, stage: SphereStage, out: _StackedOut, n: int,
                        rep: SphereReport):
        """The fused round through ``shard_map`` + ``all_to_all`` (see
        ``core.spmd.fused_scatter_round``).  Returns None when the round
        cannot ride the mesh (indivisible slot/worker counts, host-loop
        partitioner) — the caller then uses the single-device fused
        lowering."""
        from repro.core.shuffle import ReducePartitioner
        from repro.core.spmd import fused_scatter_round
        stacked = out.stacked
        W, S = len(self.workers), stacked.n_slots
        d = self.mesh.shape.get("data", 1)
        if W % d or S % d or n <= 1 \
                or isinstance(stage.partitioner, ReducePartitioner) \
                or getattr(stage.partitioner, "scatter_spec", None) is None:
            return None
        spec = stage.partitioner.scatter_spec(
            RecordBatch.empty(stacked.record_size), n)
        if spec is None:
            return None
        key_spec, bounds = spec
        rep.shuffle_rounds += 1
        with self.tracer.span("shuffle-round", track="shuffle",
                              attrs={"backend": "array", "path": "mesh",
                                     "buckets": n}) as sp:
            parts_dev, counts_dev, hist_dev = fused_scatter_round(
                stacked.data, jnp.asarray(stacked.n_valid, jnp.int32),
                bounds, key_spec=key_spec, n_buckets=n, n_workers=W,
                mesh=self.mesh)
            rep.device_dispatches += 1
            counts, hist_sb = jax.device_get((counts_dev, hist_dev))
            rep.host_syncs += 1
            if self.tracer.enabled:
                self.tracer.instant("host-sync", track="host-sync",
                                    attrs={"where": "mesh-harvest"})
            origin_counts = np.zeros((n, W), np.int64)
            for s in range(S):
                origin_counts[:, int(out.slot_workers[s])] += hist_sb[s]
            origins: Origins = [
                {self.workers[w]:
                 int(origin_counts[b, w]) * stacked.record_size
                 for w in np.nonzero(origin_counts[b])[0]}
                for b in range(n)]
            result = FusedRoundResult(parts_dev, counts.astype(np.int64),
                                      origins, 1)
            rep.partitioned_records += stacked.num_records
            if self.timing_sync:
                jax.block_until_ready(result.data)
        rep.partition_seconds += sp.wall_seconds
        return result, origins

    def _bucketize_fused(self, stage: SphereStage, out: _StackedOut, n: int,
                         rep: SphereReport):
        """One fused shuffle round: O(1) dispatches, one host sync, one
        regrouping gather — regardless of task or worker count.  Returns
        None when the round cannot stay on the fused kernel path (the
        caller downgrades to the per-worker loop)."""
        if self.mesh is not None:
            mesh_res = self._bucketize_mesh(stage, out, n, rep)
            if mesh_res is not None:
                return mesh_res
        rd = scatter_round_dispatch(out.stacked, stage.partitioner, n,
                                    worker_names=self.workers,
                                    slot_workers=out.slot_workers,
                                    pad_block=self.pad_block)
        if rd is None:
            return None
        rep.shuffle_rounds += 1
        with self.tracer.span("shuffle-round", track="shuffle",
                              attrs={"backend": "array", "path": "fused",
                                     "buckets": n}) as sp:
            rep.device_dispatches += rd.dispatches
            synced = jax.device_get(rd.sync_arrays)  # the round's ONE sync
            rep.host_syncs += 1
            if self.tracer.enabled:
                self.tracer.instant("host-sync", track="host-sync",
                                    attrs={"where": "fused-harvest"})
            result = rd.harvest(synced)
            rep.device_dispatches += result.dispatches
            rep.partitioned_records += out.stacked.num_records
            if self.timing_sync:
                if result.data is not None:
                    jax.block_until_ready(result.data)
                elif result.groups:
                    jax.block_until_ready([g for _, g in result.groups])
        rep.partition_seconds += sp.wall_seconds
        return result, result.origins

    def bucketize(self, stage: SphereStage, out, n: int, rep: SphereReport
                  ) -> Tuple[List[List[RecordBatch]], Origins]:
        """Dispatch-then-sync array shuffle.

        Phase 1 enqueues each worker's scatter without blocking —
        :func:`scatter_pieces_dispatch` takes the worker's resident
        pieces straight into ONE jitted call (stack + junk-tail mask +
        key-extract + kernel trace as one fused program; no eager
        concat-and-re-pad copy) whenever the pieces share a ladder
        shape, and concatenates to the shape ladder otherwise.  Phase 2
        harvests every dispatch's metadata behind ONE barrier and
        resolves each worker's per-bucket pieces.  One kernel-path
        shuffle round therefore costs exactly one host sync —
        ``rep.host_syncs`` advances by 1 per round, not by the worker
        count — which is the invariant tests assert.  Degenerate
        batches (reduce rounds, single bucket) resolve at dispatch
        time; a round of only those syncs zero times (host-loop
        fallbacks excepted — they pay their sync at dispatch and say
        so).

        Batches pad to power-of-two-ladder row counts (floored at
        ``pad_block``), so the kernel traces once per padded shape, not
        once per batch size; padding-resident stage outputs feed the
        scatter at their resident shape (junk tails ride to the kernel's
        trash bucket) instead of being sliced and re-padded.

        With ``fused_rounds`` the stage output arrives stacked and the
        whole round — every worker's scatter plus the regrouping onto
        destination workers — runs through :func:`scatter_round_dispatch`
        (or ``spmd.fused_scatter_round`` on a mesh) instead of this loop,
        keeping ``device_dispatches`` O(1) per round."""
        if self.timing_sync:
            # start-of-timing barrier (benchmarks only, same policy as
            # the stop barrier below): ``partition_seconds`` measures
            # the shuffle round alone, so drain the stage's async
            # output before starting the clock.  The fused round is one
            # dependency chain — its single sync would otherwise charge
            # the stacked UDF apply to the round, where the per-worker
            # loop's many small dispatches drain on their own during
            # intervening host work.
            if isinstance(out, _StackedOut):
                jax.block_until_ready(out.stacked.data)
            else:
                jax.block_until_ready([p.data for ps in out.values()
                                       for p in ps])
        if isinstance(out, _StackedOut):
            fused = self._bucketize_fused(stage, out, n, rep)
            if fused is not None:
                return fused
            # ineligible round (reduce partitioner, single bucket, odd
            # record widths): downgrade to the per-worker loop
            out = out.to_worker_dict(self.workers)
        buckets: List[List[RecordBatch]] = [[] for _ in range(n)]
        origins: Origins = [{} for _ in range(n)]
        rep.shuffle_rounds += 1
        with self.tracer.span("shuffle-round", track="shuffle",
                              attrs={"backend": "array",
                                     "path": "per-worker",
                                     "buckets": n}) as sp:
            round_: List[Tuple[str, int, object]] = []
            for w in self.workers:                  # phase 1: dispatch all
                pieces = out[w]
                if not pieces:
                    continue
                disp = scatter_pieces_dispatch(pieces, stage.partitioner, n,
                                               pad_block=self.pad_block)
                rep.host_syncs += disp.host_syncs
                if disp.host_syncs and self.tracer.enabled:
                    self.tracer.instant(
                        "host-sync", track="host-sync",
                        attrs={"where": "dispatch-fallback", "worker": w,
                               "count": disp.host_syncs})
                rep.device_dispatches += 1          # the worker's scatter
                round_.append((w, sum(p.num_records for p in pieces), disp))
            pending = [d for (_, _, d) in round_ if d.pending]
            if pending:                             # phase 2: one barrier
                synced = jax.device_get([d.sync_arrays for d in pending])
                rep.host_syncs += 1
                if self.tracer.enabled:
                    self.tracer.instant(
                        "host-sync", track="host-sync",
                        attrs={"where": "round-barrier",
                               "dispatches": len(pending)})
                for d, s in zip(pending, synced):
                    d.harvest(synced=s)
                    rep.device_dispatches += d.n    # per-bucket slices
            for w, nrec, disp in round_:
                for i, piece in enumerate(disp.harvest()):
                    if piece.num_records:
                        buckets[i].append(piece)
                        origins[i][w] = piece.nbytes
                rep.partitioned_records += nrec
            if self.timing_sync:
                jax.block_until_ready([p.data for bucket in buckets
                                       for p in bucket])
        rep.partition_seconds += sp.wall_seconds
        return buckets, origins

    def place_buckets(self, buckets, parts) -> None:
        # bucket i lives on worker i % len(workers); a destination holding
        # several buckets keeps them in bucket order (matching the bytes
        # path's append order), merged into one device-resident batch
        if isinstance(buckets, FusedRoundResult):
            # the fused round already regrouped on device: slot i of the
            # stacked result IS worker i's merged partition — parts hold
            # zero-copy views into the stack, so chained stages restack
            # for free (see _aligned_stacked)
            if buckets.groups is not None:
                # big rounds arrive as a few worker-contiguous group
                # stacks (gather rows per call are capped, see
                # FusedRoundResult.groups); every worker still gets a
                # zero-copy view into its group's stack
                for w0, arr in buckets.groups:
                    g = StackedBatch(arr,
                                     buckets.counts[w0:w0 + arr.shape[0]])
                    for j in range(arr.shape[0]):
                        parts[self.workers[w0 + j]] = (
                            _SlotRef(g, j) if int(g.n_valid[j]) else None)
                return
            if buckets.data is None:
                for w in self.workers:
                    parts[w] = None
                return
            stacked = StackedBatch(buckets.data, buckets.counts)
            for i, w in enumerate(self.workers):
                parts[w] = (_SlotRef(stacked, i)
                            if int(stacked.n_valid[i]) else None)
            return
        incoming: Dict[str, List[RecordBatch]] = {w: [] for w in self.workers}
        for i, pieces in enumerate(buckets):
            incoming[self.workers[i % len(self.workers)]].extend(pieces)
        for w in self.workers:
            parts[w] = (RecordBatch.concat(incoming[w])
                        if incoming[w] else None)

    def set_parts(self, parts, out) -> None:
        if isinstance(out, _StackedOut):
            # partitionerless stage: each worker keeps its own slots
            slots: Dict[str, List[int]] = {w: [] for w in self.workers}
            for s, wi in enumerate(out.slot_workers):
                if int(out.stacked.n_valid[s]):
                    slots[self.workers[int(wi)]].append(s)
            for w in self.workers:
                own = slots[w]
                if not own:
                    parts[w] = None
                elif len(own) == 1:
                    parts[w] = _SlotRef(out.stacked, own[0])
                else:
                    parts[w] = RecordBatch.concat(
                        [out.stacked.slot(s) for s in own])
            return
        for w in self.workers:
            parts[w] = RecordBatch.concat(out[w]) if out[w] else None

    def outputs(self, parts) -> List[bytes]:
        # the ONLY host materialisation of record data after stage 0
        return [_as_batch(parts[w]).to_bytes() for w in self.workers
                if parts[w] is not None and parts[w].num_records]


def make_executor(backend: str, client, workers: Sequence[str], *,
                  max_retries: int = 3, pad_block: int = 4096,
                  cache_chunks: bool = False, prefetch: bool = True,
                  prefetch_depth: int = 1, timing_sync: bool = False,
                  fused_rounds: bool = True, mesh=None, tracer=None):
    if backend == "array":
        return ArrayExecutor(client, workers, max_retries=max_retries,
                             pad_block=pad_block, cache_chunks=cache_chunks,
                             prefetch=prefetch, prefetch_depth=prefetch_depth,
                             timing_sync=timing_sync,
                             fused_rounds=fused_rounds, mesh=mesh,
                             tracer=tracer)
    return BytesExecutor(client, workers, max_retries=max_retries,
                         cache_chunks=cache_chunks, prefetch=prefetch,
                         prefetch_depth=prefetch_depth, tracer=tracer)
