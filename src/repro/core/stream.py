"""Sphere Streams: windowed multi-file dataflow over the Sector event bus.

The paper's flagship application, Angle, continuously mines TCP-flow
feature windows *as they land in Sector* — the companion papers
(arXiv:0808.3019, arXiv:0809.1181) describe Sphere UDFs applied
incrementally to a growing, windowed collection of Sector files, with
compute following the data across the wide-area topology.

:class:`SphereStream` is that workload's engine-side half: a multi-file
generalization of :class:`repro.core.engine.SphereSession` that

* subscribes to a Sector path prefix (e.g. ``angle/window_``) on the
  master's event bus: every ``file-created`` whose path matches is an
  *arrival*;
* maintains a window policy (:class:`WindowPolicy` — tumbling, sliding,
  count-based, or event-**timed** with a simulated-clock watermark and a
  late-arrival grace period, for files landing at different sites at
  different times) over the arrival sequence; when the policy fires, the
  stream's current window becomes the policy's file set and the optional
  ``on_window`` callback runs — synchronously, during the upload that
  completed the window, which is exactly "the data waits for the task";
* plans **only the delta** when the window advances: a file entering the
  window gets one Sector lookup and one locality-scheduled group plan
  (:class:`repro.core.planner.IncrementalPlan`), files that stay keep
  their cached plan *and* their decoded device-resident chunks, and
  files that expire are retired — plan group dropped, chunks evicted —
  without touching surviving state.  ``SphereReport.planned_tasks`` /
  ``reused_tasks`` count the split, so the delta guarantee is testable;
* keeps per-window reduce state warm: the stage objects (and therefore
  their traced UDFs) outlive windows, so a streaming k-means re-fitting
  every window reports ``udf_traces == 1`` across the entire stream and
  warm-starts each window's centroids from the previous window's.

Membership events (``server-joined`` / ``server-died``) invalidate the
stream automatically: cached lookups, plans and chunks are keyed to the
old membership and are dropped, and the executor re-binds to the live
workers — the event-driven replacement for the old manual
``SphereSession.refresh()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.executor import make_executor
from repro.core.job import SphereJob
from repro.core.planner import (IncrementalPlan, SpherePlanner, SphereReport,
                                TaskSpec)
from repro.core.trace import NULL_TRACER, link_track
from repro.sector.events import weak_subscribe

__all__ = ["SphereStream", "WindowPolicy"]

# on_window callback: (stream, window_index, window_files)
WindowCallback = Callable[["SphereStream", int, Tuple[str, ...]], None]


# the weakref-subscription helper grew up and moved to the event bus
# module (the replication daemon needs it too); re-exported here for
# backwards compatibility with callers that imported the private name
_weak_subscribe = weak_subscribe


@dataclass(frozen=True)
class WindowPolicy:
    """Which arrivals form a window, and when windows fire.

    ``size`` is the window extent in files (``None`` = every arrival so
    far — a growing landmark window); ``step`` is how many arrivals pass
    between firings.  The classic shapes are classmethods:

    * ``tumbling(size)``   — non-overlapping: fires every ``size``
      arrivals over the latest ``size`` files;
    * ``sliding(size, step=1)`` — overlapping: fires every ``step``
      arrivals (once ``size`` have arrived) over the latest ``size``;
    * ``count(every=1)``   — count-based landmark: fires every ``every``
      arrivals over *all* files so far;
    * ``timed(span_s, grace_s=0.0)`` — EVENT-time tumbling windows on
      the simulated clock, for files landing at different sites at
      different times: arrival ``i`` belongs to bucket
      ``int(event_time // span_s)``, and a bucket fires once the
      *watermark* — the latest event time seen, minus the ``grace_s``
      late-arrival allowance — passes the bucket's end.  Buckets fire
      in order; a file whose bucket already fired is counted as late
      and dropped (``SphereStream.late_dropped``), never silently
      merged into the wrong window.  Count-based ``fires``/``window``
      do not apply to timed policies (windowing is driven by
      event time, not arrival count).
    """
    kind: str
    size: Optional[int]
    step: int
    span_s: float = 0.0     # timed windows: event-time extent, seconds
    grace_s: float = 0.0    # timed windows: late-arrival allowance, seconds

    def __post_init__(self):
        if self.kind not in ("tumbling", "sliding", "count", "time"):
            raise ValueError(f"unknown window kind {self.kind!r}")
        if self.size is not None and self.size < 1:
            raise ValueError("window size must be >= 1")
        if self.step < 1:
            raise ValueError("window step must be >= 1")
        if self.kind == "time":
            if self.span_s <= 0:
                raise ValueError("timed window span_s must be > 0")
            if self.grace_s < 0:
                raise ValueError("timed window grace_s must be >= 0")

    @classmethod
    def tumbling(cls, size: int) -> "WindowPolicy":
        return cls("tumbling", size, size)

    @classmethod
    def sliding(cls, size: int, step: int = 1) -> "WindowPolicy":
        return cls("sliding", size, step)

    @classmethod
    def count(cls, every: int = 1) -> "WindowPolicy":
        return cls("count", None, every)

    @classmethod
    def timed(cls, span_s: float, grace_s: float = 0.0) -> "WindowPolicy":
        return cls("time", None, 1, span_s, grace_s)

    def fires(self, n_arrivals: int) -> bool:
        """Does the ``n_arrivals``-th arrival complete a window?
        (Count-based policies only; timed windows fire on watermark.)"""
        if self.kind == "time":
            return False
        if self.size is None:
            return n_arrivals % self.step == 0
        return (n_arrivals >= self.size
                and (n_arrivals - self.size) % self.step == 0)

    def window(self, arrivals: Sequence[str]) -> Tuple[str, ...]:
        """The file set of the window ending at the latest arrival."""
        if self.size is None:
            return tuple(arrivals)
        return tuple(arrivals[-self.size:])


class SphereStream:
    """One planner + one executor shared by every window of a stream.

    See the module docstring for the model.  Jobs run against the
    *current* window with :meth:`run`, exactly like a session: stage 0
    reads the window's files through the merged incremental plan and the
    shared chunk cache, later stages plan fresh per job, and
    ``input="chained"`` consumes the previous job's output partitions
    (chained state is per-window — it is dropped when the window
    advances).  :class:`repro.core.engine.SphereSession` is the
    single-file special case: a stream pinned to one file with no
    subscription-driven window advance.
    """

    _kind = "stream"

    def __init__(self, engine, prefix: Optional[str] = None, *,
                 window: Optional[WindowPolicy] = None,
                 record_size: int = 0, backend: str = "bytes",
                 cache_chunks: bool = True, files: Sequence[str] = ()):
        self.engine = engine
        self.prefix = prefix
        self.window_policy = window or WindowPolicy.count(1)
        self.record_size = record_size
        self.backend = backend
        self._cache_chunks = cache_chunks
        # contention-aware engines hand the planner the physical-path
        # mapping so cross-site transfers queue per link; blind engines
        # (and engines predating the knob) plan with private links
        link_of = (engine._link_of
                   if getattr(engine, "contention_aware", False)
                   and hasattr(engine, "_link_of") else None)
        self.tracer = getattr(engine, "tracer", None) or NULL_TRACER
        self.planner = SpherePlanner(speeds=engine.speeds,
                                     speculate_factor=engine.speculate_factor,
                                     move_time=engine._move_time,
                                     link_of=link_of,
                                     offload=getattr(engine, "offload",
                                                     False),
                                     tracer=self.tracer)
        self._plan = IncrementalPlan()           # one group per window file
        self._file_tasks: Dict[str, List[TaskSpec]] = {}
        self._stragglers: Dict[str, Dict[str, int]] = {}
        self._parts = None                       # last job's output partitions
        self._window_cb: Optional[WindowCallback] = None
        # arrivals holds only what the policy can still use: the full
        # history for landmark count() windows, the trailing `size` for
        # bounded windows (a stream runs indefinitely — it must not
        # accumulate every file name ever seen).  _arrived is the O(1)
        # dedup set, trimmed in lockstep (Sector file names are unique —
        # create_file raises on a duplicate — so dedup only guards
        # against a re-published event for a still-windowed file);
        # _n_arrivals is the lifetime count driving fires().
        self.arrivals: List[str] = []
        self._arrived: set = set()
        self._n_arrivals = 0
        # timed-window state (kind == "time"): files buffered per
        # event-time bucket until the watermark passes the bucket's end;
        # buckets fire strictly in order starting at _next_bucket, and a
        # unique file landing in an already-fired bucket bumps
        # late_dropped instead of joining a window.
        self._timed_pending: Dict[int, List[str]] = {}
        self._max_event_time = float("-inf")
        self._next_bucket = 0
        self.late_dropped = 0
        self.window_files: Tuple[str, ...] = tuple(files)
        self.windows_formed = 0
        self.jobs_run = 0
        self.closed = False
        self._needs_bind = False
        self._bind_cluster()
        bus = engine.master.events
        self._subs = [_weak_subscribe(bus, self, "_on_membership_event",
                                      types=("server-joined",
                                             "server-died"))]
        if prefix is not None:
            self._subs.append(_weak_subscribe(bus, self, "_on_file_event",
                                              types=("file-created",),
                                              prefix=prefix))

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Unsubscribe from the event bus (idempotent).  A closed stream
        keeps its caches and can still run jobs; it just stops reacting
        to cluster events."""
        for sub in self._subs:
            self.engine.master.events.unsubscribe(sub)
        self._subs = []
        self.closed = True

    def __enter__(self) -> "SphereStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _bind_cluster(self) -> None:
        self._workers = self.engine._workers()
        if not self._workers:
            raise RuntimeError("no live workers")
        self.executor = make_executor(self.backend, self.engine.client,
                                      self._workers,
                                      max_retries=self.engine.max_retries,
                                      pad_block=self.engine.pad_block,
                                      cache_chunks=self._cache_chunks,
                                      prefetch=self.engine.prefetch,
                                      prefetch_depth=getattr(
                                          self.engine, "prefetch_depth", 1),
                                      timing_sync=self.engine.timing_sync,
                                      fused_rounds=getattr(
                                          self.engine, "fused_rounds", True),
                                      mesh=getattr(self.engine, "mesh", None),
                                      tracer=self.tracer)
        self._needs_bind = False

    @property
    def workers(self) -> List[str]:
        """Live workers this stream is bound to, re-derived lazily after
        a membership event invalidated the binding."""
        if self._needs_bind:
            self._bind_cluster()
        return self._workers

    # ------------------------------------------------------------- events
    def on_window(self, callback: WindowCallback) -> "SphereStream":
        """Register the per-window callback, invoked synchronously as
        ``callback(stream, window_index, window_files)`` whenever the
        policy fires (i.e. during the upload that completed a window)."""
        self._window_cb = callback
        return self

    def _on_file_event(self, event) -> None:
        name = event.path
        if self.closed or name in self._arrived:
            return
        if self.window_policy.kind == "time":
            self._on_timed_arrival(name, event)
            return
        self._arrived.add(name)
        self.arrivals.append(name)
        self._n_arrivals += 1
        size = self.window_policy.size
        if size is not None and len(self.arrivals) > size:
            del self.arrivals[:-size]
            self._arrived = set(self.arrivals)
        if self.window_policy.fires(self._n_arrivals):
            self._advance(self.window_policy.window(self.arrivals))

    # ------------------------------------------------------ timed windows
    def _on_timed_arrival(self, name: str, event) -> None:
        """Event-time windowing: bucket the arrival by the file's real
        landing time (``event_time`` in the event detail — the master's
        published ``time`` is its monotonic clock, which would clamp a
        late landing forward and hide its lateness), then flush every
        bucket the watermark has passed."""
        pol = self.window_policy
        self._arrived.add(name)  # late files dedup + count exactly once
        t = float(event.detail.get("event_time", event.time))
        bucket = int(t // pol.span_s)
        if bucket < self._next_bucket:
            self.late_dropped += 1
            return
        self._n_arrivals += 1
        self._timed_pending.setdefault(bucket, []).append(name)
        if t > self._max_event_time:
            self._max_event_time = t
        self._flush_watermark()

    @property
    def watermark(self) -> float:
        """Current event-time watermark: the latest landing time seen,
        minus the grace allowance (``-inf`` before any timed arrival)."""
        return self._max_event_time - self.window_policy.grace_s

    def advance_watermark(self, now: float) -> None:
        """Declare that simulated time has reached ``now`` even though
        no file said so (the stream's clock only advances on arrivals):
        fires every pending timed bucket whose end the new watermark
        passes.  Callers use this to flush the final window(s) of a
        bounded run, or to time out a quiet period."""
        if self.window_policy.kind != "time":
            raise ValueError("advance_watermark applies to timed "
                             "windows only")
        if now > self._max_event_time:
            self._max_event_time = float(now)
        self._flush_watermark()

    def _flush_watermark(self) -> None:
        pol = self.window_policy
        watermark = self._max_event_time - pol.grace_s
        while (self._next_bucket + 1) * pol.span_s <= watermark:
            files = self._timed_pending.pop(self._next_bucket, None)
            self._next_bucket += 1
            if files:  # empty event-time spans form no window
                self._advance(tuple(files))

    def _advance(self, new_window: Tuple[str, ...]) -> None:
        for f in self.window_files:
            if f not in new_window:
                self._retire_file(f)
        # chained partitions are per-window state: the window changed
        self._parts = None
        self.window_files = tuple(new_window)
        self.windows_formed += 1
        if self.tracer.enabled:
            self.tracer.instant("stream:window-advance", track="stream",
                                attrs={"window": self.windows_formed - 1,
                                       "files": len(new_window)})
        if self._window_cb is not None:
            self._window_cb(self, self.windows_formed - 1, self.window_files)

    def _retire_file(self, name: str) -> None:
        """Expire one file: drop its plan group and evict its decoded
        chunks.  Surviving files' state is untouched."""
        tasks = self._file_tasks.pop(name, None)
        if tasks:
            self.executor.evict_chunks(t.key for t in tasks)
        self._plan.retire(name)
        self._stragglers.pop(name, None)
        if self.tracer.enabled:
            self.tracer.instant("stream:evict-file", track="stream",
                                attrs={"file": name,
                                       "chunks": len(tasks or ())})

    def _on_membership_event(self, event) -> None:
        if not self.closed:
            self._invalidate()

    def _invalidate(self) -> None:
        """Membership changed: every cached lookup, plan and chunk was
        keyed to the old cluster.  Drop them now, but re-bind to the
        live workers lazily at the next :meth:`run` — the death of the
        LAST worker must not blow up the master's failure sweep from
        inside an event callback; it surfaces as "no live workers" to
        the next caller instead.  Traced stage UDFs live on the stage
        objects, not the executor, so re-running a job after
        invalidation re-plans and re-fetches but does NOT re-trace."""
        self._plan = IncrementalPlan()
        self._file_tasks = {}
        self._stragglers = {}
        self._parts = None
        self._needs_bind = True

    # -------------------------------------------------------------- plans
    def _ensure_planned(self, rep: SphereReport) -> None:
        """Extend the incremental plan to cover the current window: only
        files without a cached group pay a Sector lookup + placement."""
        master = self.engine.master
        for f in self.window_files:
            if f in self._plan:
                rep.reused_tasks += len(self._plan.groups[f].tasks)
                continue
            tasks = self._file_tasks.get(f)
            if tasks is None:
                metas = master.lookup(f, self.engine.client.user)
                tasks = [TaskSpec(m.chunk_id, m.size,
                                  tuple(s for s in m.locations
                                        if s in master.servers
                                        and master.servers[s].alive))
                         for m in metas]
                self._file_tasks[f] = tasks
            plan, contrib = self.planner.extend_plan(
                self._plan, f, self.engine._schedule_view(tasks),
                self.workers)
            self._stragglers[f] = contrib
            rep.planned_tasks += len(plan.tasks)
            if self.tracer.enabled:
                self.tracer.instant("stream:plan-extend", track="stream",
                                    attrs={"file": f,
                                           "planned": len(plan.tasks)})

    # ----------------------------------------------------------- validate
    @property
    def _job_input(self) -> Optional[str]:
        """What a job's ``input_file`` must name (None = not checked):
        the subscription prefix, or the pinned file of a single-file
        stream/session."""
        if self.prefix is not None:
            return self.prefix
        if len(self.window_files) == 1:
            return self.window_files[0]
        return None

    @property
    def job_input_name(self) -> str:
        """A valid ``SphereJob.input_file`` for jobs run on this stream."""
        return self._job_input or ""

    def _validate(self, job: SphereJob, input: str) -> None:
        if input not in ("file", "chained"):
            raise ValueError(f"unknown {self._kind} input {input!r}; "
                             f"choose 'file' or 'chained'")
        if job.backend != self.backend:
            raise ValueError(f"job backend {job.backend!r} != {self._kind} "
                             f"backend {self.backend!r}")
        if job.record_size != self.record_size:
            raise ValueError(f"job record_size {job.record_size} != "
                             f"{self._kind} record_size {self.record_size}")
        if (input == "file" and self._job_input is not None
                and job.input_file != self._job_input):
            raise ValueError(f"job reads {job.input_file!r} but this "
                             f"{self._kind} chains over {self._job_input!r}")
        chunk = self.engine.master.chunk_size
        if job.record_size and chunk % job.record_size:
            raise ValueError(
                f"chunk_size {chunk} must be a multiple of "
                f"record_size {job.record_size} (records must not straddle "
                f"chunk boundaries)")

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None, *,
            input: str = "file") -> Tuple[List[bytes], SphereReport]:
        """Execute one job against the current window.  ``input="file"``
        reads the window's Sector files through the cached delta plans
        and chunk cache; ``"chained"`` consumes the previous job's output
        partitions in place (dropped when the window advances).  Returns
        (per-bucket output blobs, report)."""
        self._validate(job, input)
        rep = report or SphereReport()
        tracer = self.tracer
        metrics = getattr(self.engine, "metrics", None)
        if metrics is not None and rep.__dict__.get("_metrics") is None:
            # mirror this report's counters into the engine's registry;
            # the unique per-bind ``run`` label keeps two reports (e.g.
            # a chained A/B pair) on distinct series
            rep.bind_metrics(metrics, kind=self._kind,
                             backend=self.backend,
                             **metrics.next_run_labels())
        workers = self.workers
        planner, executor = self.planner, self.executor
        planner.reset_job_state()
        with tracer.span(f"job:{job.name}", track="control",
                         attrs={"kind": self._kind,
                                "backend": self.backend,
                                "input": input}):
            return self._run_stages(job, rep, input, workers,
                                    planner, executor, tracer)

    def _run_stages(self, job: SphereJob, rep: SphereReport, input: str,
                    workers, planner, executor, tracer
                    ) -> Tuple[List[bytes], SphereReport]:
        if input == "chained":
            if self._parts is None:
                raise RuntimeError("no previous job output to chain from")
            parts = self._parts
            sizes = executor.part_sizes(parts)
            tasks = [TaskSpec(w, sz, (w,))
                     for w, sz in sizes.items() if sz]
            first = False
        else:
            if not self.window_files:
                raise RuntimeError(
                    f"no window formed yet on this {self._kind} (waiting "
                    f"for file-created events matching {self.prefix!r})")
            self._ensure_planned(rep)
            parts = executor.empty_parts()
            tasks = []
            first = True

        for stage in job.stages:
            with tracer.span(f"plan:{stage.name}", track="control",
                             attrs={"first": first}):
                if first:
                    plan = self._plan.merged()
                    # replay the straggler observations planning each
                    # window file's group made, so later stages of every
                    # job over this window see exactly the per-job state
                    # a fresh plan would produce
                    for contrib in self._stragglers.values():
                        for w, c in contrib.items():
                            planner.job_stragglers[w] = \
                                planner.job_stragglers.get(w, 0) + c
                else:
                    plan = planner.plan_stage(
                        self.engine._schedule_view(tasks), workers)
            rep.tasks += len(plan.tasks)
            rep.bytes_local += plan.bytes_local
            rep.bytes_moved += plan.bytes_moved
            rep.speculated += plan.speculated
            rep.speculation_wins += plan.speculation_wins
            rep.link_wait_seconds += plan.link_wait
            t_stage = plan.seconds
            if tracer.enabled:
                # simulated-clock timeline: one span per task on its
                # executing worker's track, one per reserved transfer on
                # its physical link's track, all offset to the job's
                # running simulated clock
                offset = rep.sim_seconds
                for p in plan.tasks:
                    end = offset + p.finish
                    begin = max(offset, end - planner._proc_time(
                        p.executor, p.nbytes))
                    tracer.add_span(
                        f"task:{p.key}", track=f"worker:{p.executor}",
                        t0=begin, t1=end, clock="sim",
                        attrs={"nbytes": p.nbytes, "planned": p.worker,
                               "stage": stage.name})
                for key, tkey, begin, end in plan.transfers:
                    tracer.add_span(
                        f"xfer:{tkey}", track=link_track(key),
                        t0=offset + begin, t1=offset + end, clock="sim",
                        attrs={"task": tkey, "stage": stage.name})

            with tracer.span(f"exec:{stage.name}", track="control",
                             attrs={"tasks": len(plan.tasks)}):
                out = executor.run_stage(job, stage, plan, parts, rep,
                                         first_stage=first)
            if stage.partitioner is not None:
                with tracer.span(f"shuffle:{stage.name}", track="control"):
                    n = stage.n_buckets or len(workers)
                    buckets, origins = executor.bucketize(stage, out, n,
                                                          rep)
                    # bucket i lives on worker i % len(workers); charge
                    # the movement of each fragment from its actual
                    # origin worker
                    flows = [(src, workers[i % len(workers)], nbytes)
                             for i, origin in enumerate(origins)
                             for src, nbytes in origin.items()]
                    t_shuffle, moved, local = planner.plan_shuffle(flows)
                    rep.bytes_moved += moved
                    rep.bytes_local += local
                    t_stage += t_shuffle
                    executor.place_buckets(buckets, parts)
            else:
                executor.set_parts(parts, out)

            sizes = executor.part_sizes(parts)
            t_stage += self.engine._stage_barrier_seconds(sum(sizes.values()))
            rep.observe_stage(t_stage)
            rep.sim_seconds += t_stage
            first = False
            # next stage's tasks are the current partitions (local to owner)
            tasks = [TaskSpec(w, sz, (w,))
                     for w, sz in sizes.items() if sz]

        moved_total = rep.bytes_moved + rep.bytes_local
        rep.locality_fraction = (rep.bytes_local / moved_total
                                 if moved_total else 1.0)
        self._parts = parts
        self.jobs_run += 1
        return executor.outputs(parts), rep
