"""Sphere engine: locality-aware scheduling, load balancing, stragglers,
fault tolerance (paper §4) — the thin orchestrator over the
planner/executor split.

Per the paper, Sphere provides: locating data, moving data **only if
required**, locating/managing compute, load balancing, and fault tolerance;
parallelisation is implicit.  The execution model:

  * compute workers are the Sector chunk servers themselves (compute sits
    on the storage cloud — "data waits for the task");
  * the **planner** (:mod:`repro.core.planner`) is pure: it schedules each
    chunk task on a replica holder when one has capacity (zero movement),
    else on the least-loaded worker; speculatively re-executes observed
    stragglers on idle replicas (earliest copy wins); and prices the
    shuffle from the actual per-bucket origin flows — all in simulated
    time, with no access to record data;
  * the **executor** (:mod:`repro.core.executor`) is the data plane: it
    fetches chunks (bounded retries over surviving replicas — Sector's
    replication guarantee), runs UDFs for real on the planned workers,
    and bucketizes stage output.  ``backend="bytes"`` is the per-record
    reference; ``backend="array"`` keeps each worker's partition as one
    device-resident RecordBatch across stages and traces pad-stable
    stage UDFs once;
  * between stages, records are bucketed by the stage partitioner and
    buckets move to their owning worker over the simulated WAN — the
    Sphere shuffle, charged from each bucket's real origin workers.

UDF outputs are correct Python bytes while time is fully simulated, so
unit tests assert both output correctness and scheduling properties
(locality fraction, speculation wins, retry counts) — and because the
planner only sees task *sizes*, every scheduling counter and simulated
second agrees across the two backends for the same job.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.executor import make_executor
from repro.core.job import SphereJob
from repro.core.planner import (PROCESS_RATE, SpherePlanner, SphereReport,
                                TaskSpec)
from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster
from repro.sector.transport import simulate_transfer

__all__ = ["SphereEngine", "SphereReport", "PROCESS_RATE"]


class SphereEngine:
    def __init__(self, master: SectorMaster, client: SectorClient,
                 speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8, max_retries: int = 3,
                 pad_block: int = 4096):
        self.master = master
        self.client = client
        self.speeds = speeds or {}
        self.speculate_factor = speculate_factor
        self.max_retries = max_retries
        self.pad_block = pad_block

    # ------------------------------------------------------------- helpers
    def _workers(self) -> List[str]:
        return sorted(sid for sid in self.master.ring.servers()
                      if self.master.servers[sid].alive)

    def _move_time(self, nbytes: int, src: str, dst: str) -> float:
        link = self.master.topology.link(self.master.servers[src].site,
                                         self.master.servers[dst].site)
        return simulate_transfer(nbytes, link, self.client.protocol).seconds

    # ------------------------------------------------- benchmark hooks
    def _schedule_view(self, tasks: List[TaskSpec]) -> List[TaskSpec]:
        """What replica placement the scheduler sees (overridden by the
        Hadoop-style comparison engine to hide locality)."""
        return tasks

    def _stage_barrier_seconds(self, stage_output_nbytes: int) -> float:
        """Extra materialisation cost after a stage (0 for Sphere; the
        Hadoop-style engine charges a write+read barrier here)."""
        return 0.0

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None
            ) -> Tuple[List[bytes], SphereReport]:
        """Execute all stages. Returns (per-bucket output blobs, report)."""
        rep = report or SphereReport()
        workers = self._workers()
        if not workers:
            raise RuntimeError("no live workers")
        if job.record_size and self.master.chunk_size % job.record_size:
            raise ValueError(
                f"chunk_size {self.master.chunk_size} must be a multiple of "
                f"record_size {job.record_size} (records must not straddle "
                f"chunk boundaries)")

        planner = SpherePlanner(speeds=self.speeds,
                                speculate_factor=self.speculate_factor,
                                move_time=self._move_time)
        executor = make_executor(job, self.client, workers,
                                 max_retries=self.max_retries,
                                 pad_block=self.pad_block)

        # stage 0 input: Sector chunks with their live replica locations
        metas = self.master.lookup(job.input_file, self.client.user)
        tasks = [TaskSpec(m.chunk_id, m.size,
                          tuple(s for s in m.locations
                                if s in self.master.servers
                                and self.master.servers[s].alive))
                 for m in metas]

        parts = executor.empty_parts()
        first = True
        for stage in job.stages:
            plan = planner.plan_stage(self._schedule_view(tasks), workers)
            rep.tasks += len(plan.tasks)
            rep.bytes_local += plan.bytes_local
            rep.bytes_moved += plan.bytes_moved
            rep.speculated += plan.speculated
            rep.speculation_wins += plan.speculation_wins
            t_stage = plan.seconds

            out = executor.run_stage(job, stage, plan, parts, rep,
                                     first_stage=first)
            if stage.partitioner is not None:
                n = stage.n_buckets or len(workers)
                buckets, origins = executor.bucketize(stage, out, n, rep)
                # bucket i lives on worker i % len(workers); charge the
                # movement of each fragment from its actual origin worker
                flows = [(src, workers[i % len(workers)], nbytes)
                         for i, origin in enumerate(origins)
                         for src, nbytes in origin.items()]
                t_shuffle, moved, local = planner.plan_shuffle(flows)
                rep.bytes_moved += moved
                rep.bytes_local += local
                t_stage += t_shuffle
                executor.place_buckets(buckets, parts)
            else:
                executor.set_parts(parts, out)

            sizes = executor.part_sizes(parts)
            t_stage += self._stage_barrier_seconds(sum(sizes.values()))
            rep.stage_seconds.append(t_stage)
            rep.sim_seconds += t_stage
            first = False
            # next stage's tasks are the current partitions (local to owner)
            tasks = [TaskSpec(w, sz, (w,))
                     for w, sz in sizes.items() if sz]

        moved_total = rep.bytes_moved + rep.bytes_local
        rep.locality_fraction = (rep.bytes_local / moved_total
                                 if moved_total else 1.0)
        return executor.outputs(parts), rep
