"""Sphere engine: locality-aware scheduling, load balancing, stragglers,
fault tolerance (paper §4) — the thin orchestrator over the
planner/executor split.

Per the paper, Sphere provides: locating data, moving data **only if
required**, locating/managing compute, load balancing, and fault tolerance;
parallelisation is implicit.  The execution model:

  * compute workers are the Sector chunk servers themselves (compute sits
    on the storage cloud — "data waits for the task");
  * the **planner** (:mod:`repro.core.planner`) is pure: it schedules each
    chunk task on a replica holder when one has capacity (zero movement),
    else on the least-loaded worker; speculatively re-executes observed
    stragglers on idle replicas (earliest copy wins); and prices the
    shuffle from the actual per-bucket origin flows — all in simulated
    time, with no access to record data;
  * the **executor** (:mod:`repro.core.executor`) is the data plane: it
    fetches chunks (bounded retries over surviving replicas — Sector's
    replication guarantee), runs UDFs for real on the planned workers,
    and bucketizes stage output.  ``backend="bytes"`` is the per-record
    reference; ``backend="array"`` keeps each worker's partition as one
    device-resident RecordBatch across stages and traces pad-stable
    stage UDFs once;
  * between stages, records are bucketed by the stage partitioner and
    buckets move to their owning worker over the simulated WAN — the
    Sphere shuffle, charged from each bucket's real origin workers.

Iterative / multi-job workloads run through a :class:`SphereSession` —
one planner + one executor amortised across a *chain* of jobs over the
same dataset (the paper's "a stream of jobs over the same data" use
case, dominant for the Angle data-mining workload).  The session runs
the Sector chunk lookup once, computes replica placement (the stage-0
plan) once, keeps stage-0 chunks and job output partitions
device-resident between jobs, and preserves the executor's traced-UDF
cache so a stage re-run every iteration compiles exactly once.

A session is the single-file special case of a
:class:`repro.core.stream.SphereStream` — the windowed multi-file
generalization that subscribes to a Sector path prefix on the master's
event bus and plans only the per-window delta (see
:mod:`repro.core.stream`).  Both invalidate automatically on
``server-joined`` / ``server-died`` events; the old manual
``SphereSession.refresh()`` is a deprecated no-op.

UDF outputs are correct Python bytes while time is fully simulated, so
unit tests assert both output correctness and scheduling properties
(locality fraction, speculation wins, retry counts) — and because the
planner only sees task *sizes*, every scheduling counter and simulated
second agrees across the two backends for the same job.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple

from repro.core.job import SphereJob
from repro.core.metrics import MetricsRegistry
from repro.core.planner import (PROCESS_RATE, SphereReport, TaskSpec)
from repro.core.stream import SphereStream, WindowPolicy
from repro.core.trace import NULL_TRACER, Tracer
from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster
from repro.sector.transport import simulate_transfer

__all__ = ["SphereEngine", "SphereSession", "SphereStream", "SphereReport",
           "WindowPolicy", "PROCESS_RATE", "Tracer", "MetricsRegistry"]


class SphereEngine:
    def __init__(self, master: SectorMaster, client: SectorClient,
                 speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8, max_retries: int = 3,
                 pad_block: int = 4096, prefetch: bool = True,
                 prefetch_depth: int = 1, timing_sync: bool = False,
                 fused_rounds: bool = True, mesh=None,
                 contention_aware: bool = True, offload: bool = False,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        self.master = master
        self.client = client
        # observability plane: a recording Tracer threads spans through
        # every planner/executor/stream this engine builds and turns the
        # master's bus events into timeline instants; the default
        # NULL_TRACER records nothing and costs nothing.  The metrics
        # registry mirrors every report the engine's runs write.
        self.tracer = tracer or NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if self.tracer.enabled:
            self.master.tracer = self.tracer
            self.tracer.attach_bus(master.events)
        self.speeds = speeds or {}
        self.speculate_factor = speculate_factor
        self.max_retries = max_retries
        self.pad_block = pad_block
        # contention_aware: planners built by this engine's sessions and
        # streams price cross-site transfers with per-link capacity
        # accounting (tasks sharing a wide-area wave queue on it) rather
        # than as private parallel links; the contention-blind estimate
        # is kept available (off) for the WAN benchmark's comparison.
        # offload: let the planner place stage tasks on non-replica
        # workers when the priced cross-site fetch still wins (default
        # off = the paper's locality-first placement).
        self.contention_aware = contention_aware
        self.offload = offload
        # prefetch: overlap stage-0 chunk fetch+decode of the next
        # ``prefetch_depth`` tasks with the dispatch of task i
        # (result-identical at any depth — off only for A/B tests and
        # debugging).  timing_sync: block on shuffled pieces before
        # stopping the partition_seconds clock — the benchmark-honesty
        # knob; leave off in production, where eager timers would
        # serialise the async data plane they measure.
        self.prefetch = prefetch
        self.prefetch_depth = prefetch_depth
        self.timing_sync = timing_sync
        # fused_rounds: run each array-backend round (UDF applies +
        # scatter + regrouping) over a stacked worker axis in O(1)
        # compiled dispatches; with ``mesh`` the stacked round lowers
        # through shard_map with an all_to_all exchange (spmd module).
        self.fused_rounds = fused_rounds
        self.mesh = mesh

    # ------------------------------------------------------------- helpers
    def _workers(self) -> List[str]:
        return sorted(sid for sid in self.master.ring.servers()
                      if self.master.servers[sid].alive)

    def _move_time(self, nbytes: int, src: str, dst: str) -> float:
        link = self.master.topology.link(self.master.servers[src].site,
                                         self.master.servers[dst].site)
        return simulate_transfer(nbytes, link, self.client.protocol).seconds

    def _link_of(self, src: str, dst: str):
        """Physical path a worker-to-worker transfer rides — the
        planner's per-link capacity-accounting key (None = uncontended
        intra-site movement).  Workers at the same site pair share a
        key, so their transfers queue on the one wide-area wave."""
        return self.master.topology.link_key(self.master.servers[src].site,
                                             self.master.servers[dst].site)

    # ------------------------------------------------- benchmark hooks
    def _schedule_view(self, tasks: List[TaskSpec]) -> List[TaskSpec]:
        """What replica placement the scheduler sees (overridden by the
        Hadoop-style comparison engine to hide locality)."""
        return tasks

    def _stage_barrier_seconds(self, stage_output_nbytes: int) -> float:
        """Extra materialisation cost after a stage (0 for Sphere; the
        Hadoop-style engine charges a write+read barrier here)."""
        return 0.0

    # ------------------------------------------------------------ sessions
    def session(self, input_file: str, *, record_size: int = 0,
                backend: str = "bytes", cache_chunks: bool = True
                ) -> "SphereSession":
        """Open a job-chaining session over ``input_file`` (one planner,
        one executor, one Sector lookup for the whole chain)."""
        return SphereSession(self, input_file, record_size=record_size,
                             backend=backend, cache_chunks=cache_chunks)

    def stream(self, prefix: str, *, window: Optional[WindowPolicy] = None,
               record_size: int = 0, backend: str = "bytes",
               cache_chunks: bool = True) -> SphereStream:
        """Open a windowed multi-file stream subscribed to every Sector
        file whose name starts with ``prefix`` (see
        :mod:`repro.core.stream`)."""
        return SphereStream(self, prefix, window=window,
                            record_size=record_size, backend=backend,
                            cache_chunks=cache_chunks)

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None
            ) -> Tuple[List[bytes], SphereReport]:
        """Execute all stages. Returns (per-bucket output blobs, report).

        One-shot form: builds a throwaway session (fresh planner, fresh
        executor, no cross-job caches) — iterative callers should hold a
        :meth:`session` instead.
        """
        session = SphereSession(self, job.input_file,
                                record_size=job.record_size,
                                backend=job.backend, cache_chunks=False)
        try:
            return session.run(job, report)
        finally:
            session.close()


class SphereSession(SphereStream):
    """One planner + one executor shared by a chain of Sphere jobs.

    The per-job engine path re-derives everything on every ``run``:
    Sector metadata lookup, replica placement, a cold executor whose
    pad-stable/mask-aware UDFs must re-trace.  A session hoists all of
    that to the chain level:

      * the Sector chunk lookup for ``input_file`` runs once, lazily, and
        the resulting stage-0 task specs are reused by every job that
        reads the file;
      * replica placement for stage 0 (the dominant planning cost) is
        computed once — the planner is deterministic over task sizes, so
        the cached plan is exactly what re-planning would produce, and
        its counters are re-charged to each job's report;
      * the executor persists: stage-0 chunks are fetched and decoded
        once (``cache_chunks``), traced UDFs stay compiled (a stage
        object re-run each iteration reports ``udf_traces == 1`` across
        the whole chain), and each job's output partitions stay
        device-resident;
      * ``run(job, input="chained")`` feeds the previous job's output
        partitions straight into the next job's stage 0 — no host
        round-trip, no Sector traffic;
      * speculation/straggler observations reset at every job boundary
        (:meth:`SpherePlanner.reset_job_state`), so behaviour per job is
        identical to a fresh engine run.

    Implementation-wise this is a :class:`SphereStream` pinned to one
    file: the window never advances, so the incremental stage-0 plan has
    exactly one group for the whole chain.  Membership changes
    (``server-joined`` / ``server-died`` on the master's event bus)
    invalidate the cached lookup/plan/chunks automatically — chained
    partitions too, since they are keyed to the old membership.
    """

    _kind = "session"

    def __init__(self, engine: SphereEngine, input_file: str, *,
                 record_size: int = 0, backend: str = "bytes",
                 cache_chunks: bool = True):
        super().__init__(engine, record_size=record_size, backend=backend,
                         cache_chunks=cache_chunks, files=(input_file,))
        self.input_file = input_file

    def refresh(self) -> None:
        """Deprecated no-op.  Sessions subscribe to the master's event
        bus and invalidate automatically when membership changes; there
        is nothing left to refresh by hand."""
        warnings.warn(
            "SphereSession.refresh() is deprecated and now a no-op: "
            "sessions invalidate automatically on server-joined/"
            "server-died events from the Sector master's event bus",
            DeprecationWarning, stacklevel=2)
