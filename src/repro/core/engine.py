"""Sphere engine: locality-aware scheduling, load balancing, stragglers,
fault tolerance (paper §4).

Per the paper, Sphere provides: locating data, moving data **only if
required**, locating/managing compute, load balancing, and fault tolerance;
parallelisation is implicit. The execution model here:

  * compute workers are the Sector chunk servers themselves (compute sits
    on the storage cloud — "data waits for the task");
  * each chunk task is scheduled on a replica holder when one has capacity
    (zero movement), else on the least-loaded worker (movement is charged
    through the transport simulator);
  * a worker has a deterministic ``speed`` factor; processing time is
    bytes / (rate * speed). Slow workers create stragglers;
  * speculative re-execution: when every task is dispatched, tasks whose
    expected completion exceeds ``speculate_factor`` x the median are
    duplicated on idle replica holders; the earliest copy wins (paper §4
    "load balancing" over replicas);
  * failures: a dead worker's tasks are retried on surviving replicas
    (bounded retries), matching Sector's replication guarantee;
  * between stages, records are bucketed by the stage partitioner and
    buckets move to their owning worker over the simulated WAN — the Sphere
    shuffle.

The engine executes UDFs for real (results are correct Python bytes), while
time is fully simulated — so unit tests assert both output correctness and
scheduling properties (locality fraction, speculation wins, retry counts).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from repro.core.job import SphereJob, SphereStage
from repro.core.records import RecordBatch, scatter_by_ids
from repro.core.shuffle import partition_batch
from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster
from repro.sector.server import ServerDown
from repro.sector.transport import simulate_transfer

PROCESS_RATE = 400e6  # bytes/s of UDF processing on a speed-1.0 worker

# a worker's partition holds bytes records or RecordBatches, per backend
Record = Union[bytes, RecordBatch]


def _rec_nbytes(rec: Record) -> int:
    return rec.nbytes if isinstance(rec, RecordBatch) else len(rec)


@dataclass
class SphereReport:
    sim_seconds: float = 0.0
    bytes_moved: int = 0
    bytes_local: int = 0
    tasks: int = 0
    speculated: int = 0
    speculation_wins: int = 0
    retried: int = 0
    locality_fraction: float = 1.0
    stage_seconds: List[float] = field(default_factory=list)
    # REAL wall-clock spent computing bucket assignments + scattering
    # records in shuffles (everything else above is simulated time) —
    # the bytes-vs-array backend comparison the benchmarks report.
    partition_seconds: float = 0.0
    partitioned_records: int = 0


class SphereEngine:
    def __init__(self, master: SectorMaster, client: SectorClient,
                 speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8, max_retries: int = 3):
        self.master = master
        self.client = client
        self.speeds = speeds or {}
        self.speculate_factor = speculate_factor
        self.max_retries = max_retries

    # ------------------------------------------------------------- helpers
    def _workers(self) -> List[str]:
        return sorted(sid for sid in self.master.ring.servers()
                      if self.master.servers[sid].alive)

    def _speed(self, sid: str) -> float:
        return self.speeds.get(sid, 1.0)

    def _proc_time(self, sid: str, nbytes: int) -> float:
        return nbytes / (PROCESS_RATE * self._speed(sid))

    def _move_time(self, nbytes: int, src_site: str, dst_site: str) -> float:
        link = self.master.topology.link(src_site, dst_site)
        return simulate_transfer(nbytes, link, self.client.protocol).seconds

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None
            ) -> Tuple[List[bytes], SphereReport]:
        """Execute all stages. Returns (per-bucket output blobs, report)."""
        rep = report or SphereReport()
        workers = self._workers()
        if not workers:
            raise RuntimeError("no live workers")
        if job.record_size and self.master.chunk_size % job.record_size:
            raise ValueError(
                f"chunk_size {self.master.chunk_size} must be a multiple of "
                f"record_size {job.record_size} (records must not straddle "
                f"chunk boundaries)")

        # stage 0 input: Sector chunks with their replica locations
        metas = self.master.lookup(job.input_file, self.client.user)
        tasks: List[Tuple[str, int, List[str]]] = []  # (key, bytes, locs)
        for m in metas:
            locs = [s for s in m.locations
                    if s in self.master.servers
                    and self.master.servers[s].alive]
            tasks.append((m.chunk_id, m.size, locs))

        # records partitioned per worker across stages
        parts: Dict[str, List[Record]] = {w: [] for w in workers}
        first = True
        for stage in job.stages:
            t_stage = self._run_stage(job, stage, tasks, parts, rep,
                                      first_stage=first)
            rep.stage_seconds.append(t_stage)
            rep.sim_seconds += t_stage
            first = False
            # next stage's tasks are the current partitions (local to owner)
            tasks = [(w, sum(_rec_nbytes(r) for r in parts[w]), [w])
                     for w in workers if parts[w]]

        moved_total = rep.bytes_moved + rep.bytes_local
        rep.locality_fraction = (rep.bytes_local / moved_total
                                 if moved_total else 1.0)
        if job.backend == "array":
            outputs = [b"".join(p.to_bytes() for p in parts[w])
                       for w in workers if parts[w]]
        else:
            outputs = [b"".join(parts[w]) for w in workers if parts[w]]
        return outputs, rep

    # ---------------------------------------------------------- one stage
    def _run_stage(self, job: SphereJob, stage: SphereStage,
                   tasks, parts, rep: SphereReport, *, first_stage: bool
                   ) -> float:
        workers = self._workers()
        site = {w: self.master.servers[w].site for w in workers}
        # Scheduling uses ESTIMATED speeds (uniform — the scheduler does not
        # know a node is slow until it runs); execution reveals actual
        # speeds, and speculation re-runs the surprises on replicas. This
        # mirrors the paper's load balancing: replicas exist precisely so
        # slow nodes can be routed around after the fact.
        est_ready = {w: 0.0 for w in workers}
        act_ready = {w: 0.0 for w in workers}

        # --- schedule: locality first, then least-(estimated)-loaded -------
        assignments: List[Tuple[str, str, int, List[str], float]] = []
        for key, nbytes, locs in sorted(tasks, key=lambda t: -t[1]):
            live_locs = [w for w in locs if w in est_ready]
            candidates = live_locs or workers
            w = min(candidates, key=lambda x: est_ready[x]
                    + nbytes / PROCESS_RATE)
            move = 0.0
            if w in live_locs:
                rep.bytes_local += nbytes
            else:
                src = live_locs[0] if live_locs else workers[0]
                move = self._move_time(nbytes, site[src], site[w])
                rep.bytes_moved += nbytes
            est_ready[w] += move + nbytes / PROCESS_RATE
            act_fin = act_ready[w] + move + self._proc_time(w, nbytes)
            act_ready[w] = act_fin
            assignments.append((key, w, nbytes, locs, act_fin))
            rep.tasks += 1

        # --- speculative re-execution of (observed) stragglers --------------
        fins = sorted(a[4] for a in assignments)
        median = fins[len(fins) // 2] if fins else 0.0
        final: Dict[str, float] = {}
        executor: Dict[str, str] = {}
        for key, w, nbytes, locs, fin in assignments:
            best_w, best_fin = w, fin
            if fin > self.speculate_factor * median:
                for alt in [x for x in locs if x != w and x in act_ready]:
                    alt_fin = act_ready[alt] + self._proc_time(alt, nbytes)
                    rep.speculated += 1
                    if alt_fin < best_fin:
                        best_w, best_fin = alt, alt_fin
                        act_ready[alt] = alt_fin
                        rep.speculation_wins += 1
                        break
            final[key] = best_fin
            executor[key] = best_w

        # --- execute UDFs for real (with failure retries) ------------------
        array = job.backend == "array"
        out_records: Dict[str, List[Record]] = {w: [] for w in workers}
        for key, w, nbytes, locs, _ in assignments:
            w = executor[key]
            blob = self._fetch(job, key, locs, rep, first_stage, parts)
            if blob is None:
                continue
            if array:
                if first_stage:
                    batch = job.split_batch(blob)
                else:
                    batch = RecordBatch.concat(blob)
                out_records[w].append(stage.apply_batch(batch))
            else:
                records = job.split_records(blob) if first_stage else blob
                out_records[w].extend(stage.apply_bytes(records))

        # --- shuffle (if the stage has a partitioner) -----------------------
        if stage.partitioner is not None:
            n = stage.n_buckets or len(workers)
            if array:
                buckets = self._bucketize_array(stage, out_records, workers,
                                                n, rep)
            else:
                buckets = self._bucketize_bytes(stage, out_records, workers,
                                                n, rep)
            # bucket i lives on worker i % len(workers); charge movement
            shuffle_time = 0.0
            for i, bucket in enumerate(buckets):
                dst = workers[i % len(workers)]
                nbytes = sum(_rec_nbytes(r) for r in bucket)
                # half the records on average originate elsewhere
                src = workers[(i + 1) % len(workers)]
                if nbytes:
                    t = self._move_time(nbytes, site[src], site[dst])
                    shuffle_time = max(shuffle_time, t)
                    rep.bytes_moved += nbytes // 2
            for w in workers:
                parts[w] = []
            for i, bucket in enumerate(buckets):
                parts[workers[i % len(workers)]].extend(bucket)
            return (max(final.values()) if final else 0.0) + shuffle_time

        for w in workers:
            parts[w] = out_records[w]
        return max(final.values()) if final else 0.0

    # ---------------------------------------------------------- bucketize
    def _bucketize_bytes(self, stage: SphereStage, out_records, workers,
                         n: int, rep: SphereReport) -> List[List[bytes]]:
        """Reference shuffle: one partitioner call per Python record."""
        buckets: List[List[bytes]] = [[] for _ in range(n)]
        t0 = time.perf_counter()
        for w in workers:
            for r in out_records[w]:
                buckets[stage.partitioner(r, n)].append(r)
                rep.partitioned_records += 1
        rep.partition_seconds += time.perf_counter() - t0
        return buckets

    def _bucketize_array(self, stage: SphereStage, out_records, workers,
                         n: int, rep: SphereReport
                         ) -> List[List[RecordBatch]]:
        """Array shuffle: per worker, one Pallas bucket-partition kernel
        call (ids + histogram) and one argsort/segment gather."""
        buckets: List[List[RecordBatch]] = [[] for _ in range(n)]
        t0 = time.perf_counter()
        for w in workers:
            if not out_records[w]:
                continue
            batch = RecordBatch.concat(out_records[w])
            ids, hist = partition_batch(batch, stage.partitioner, n)
            for i, piece in enumerate(scatter_by_ids(batch, ids, hist)):
                if piece.num_records:
                    buckets[i].append(piece)
            rep.partitioned_records += batch.num_records
        rep.partition_seconds += time.perf_counter() - t0
        return buckets

    # ------------------------------------------------------------- fetch
    def _fetch(self, job, key, locs, rep, first_stage, parts):
        if not first_stage:
            data = parts.get(key)
            return data if data else None
        for attempt in range(self.max_retries):
            try:
                return self.client.read_chunk(key)
            except (IOError, ServerDown):
                rep.retried += 1
                self.client.run_repair()
        return None
