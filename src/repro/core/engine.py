"""Sphere engine: locality-aware scheduling, load balancing, stragglers,
fault tolerance (paper §4) — the thin orchestrator over the
planner/executor split.

Per the paper, Sphere provides: locating data, moving data **only if
required**, locating/managing compute, load balancing, and fault tolerance;
parallelisation is implicit.  The execution model:

  * compute workers are the Sector chunk servers themselves (compute sits
    on the storage cloud — "data waits for the task");
  * the **planner** (:mod:`repro.core.planner`) is pure: it schedules each
    chunk task on a replica holder when one has capacity (zero movement),
    else on the least-loaded worker; speculatively re-executes observed
    stragglers on idle replicas (earliest copy wins); and prices the
    shuffle from the actual per-bucket origin flows — all in simulated
    time, with no access to record data;
  * the **executor** (:mod:`repro.core.executor`) is the data plane: it
    fetches chunks (bounded retries over surviving replicas — Sector's
    replication guarantee), runs UDFs for real on the planned workers,
    and bucketizes stage output.  ``backend="bytes"`` is the per-record
    reference; ``backend="array"`` keeps each worker's partition as one
    device-resident RecordBatch across stages and traces pad-stable
    stage UDFs once;
  * between stages, records are bucketed by the stage partitioner and
    buckets move to their owning worker over the simulated WAN — the
    Sphere shuffle, charged from each bucket's real origin workers.

Iterative / multi-job workloads run through a :class:`SphereSession` —
one planner + one executor amortised across a *chain* of jobs over the
same dataset (the paper's "a stream of jobs over the same data" use
case, dominant for the Angle data-mining workload).  The session runs
the Sector chunk lookup once, computes replica placement (the stage-0
plan) once, keeps stage-0 chunks and job output partitions
device-resident between jobs, and preserves the executor's traced-UDF
cache so a stage re-run every iteration compiles exactly once.

UDF outputs are correct Python bytes while time is fully simulated, so
unit tests assert both output correctness and scheduling properties
(locality fraction, speculation wins, retry counts) — and because the
planner only sees task *sizes*, every scheduling counter and simulated
second agrees across the two backends for the same job.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.executor import make_executor
from repro.core.job import SphereJob
from repro.core.planner import (PROCESS_RATE, SpherePlanner, SphereReport,
                                TaskSpec)
from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster
from repro.sector.transport import simulate_transfer

__all__ = ["SphereEngine", "SphereSession", "SphereReport", "PROCESS_RATE"]


class SphereEngine:
    def __init__(self, master: SectorMaster, client: SectorClient,
                 speeds: Optional[Dict[str, float]] = None,
                 speculate_factor: float = 1.8, max_retries: int = 3,
                 pad_block: int = 4096):
        self.master = master
        self.client = client
        self.speeds = speeds or {}
        self.speculate_factor = speculate_factor
        self.max_retries = max_retries
        self.pad_block = pad_block

    # ------------------------------------------------------------- helpers
    def _workers(self) -> List[str]:
        return sorted(sid for sid in self.master.ring.servers()
                      if self.master.servers[sid].alive)

    def _move_time(self, nbytes: int, src: str, dst: str) -> float:
        link = self.master.topology.link(self.master.servers[src].site,
                                         self.master.servers[dst].site)
        return simulate_transfer(nbytes, link, self.client.protocol).seconds

    # ------------------------------------------------- benchmark hooks
    def _schedule_view(self, tasks: List[TaskSpec]) -> List[TaskSpec]:
        """What replica placement the scheduler sees (overridden by the
        Hadoop-style comparison engine to hide locality)."""
        return tasks

    def _stage_barrier_seconds(self, stage_output_nbytes: int) -> float:
        """Extra materialisation cost after a stage (0 for Sphere; the
        Hadoop-style engine charges a write+read barrier here)."""
        return 0.0

    # ------------------------------------------------------------ sessions
    def session(self, input_file: str, *, record_size: int = 0,
                backend: str = "bytes", cache_chunks: bool = True
                ) -> "SphereSession":
        """Open a job-chaining session over ``input_file`` (one planner,
        one executor, one Sector lookup for the whole chain)."""
        return SphereSession(self, input_file, record_size=record_size,
                             backend=backend, cache_chunks=cache_chunks)

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None
            ) -> Tuple[List[bytes], SphereReport]:
        """Execute all stages. Returns (per-bucket output blobs, report).

        One-shot form: builds a throwaway session (fresh planner, fresh
        executor, no cross-job caches) — iterative callers should hold a
        :meth:`session` instead.
        """
        session = SphereSession(self, job.input_file,
                                record_size=job.record_size,
                                backend=job.backend, cache_chunks=False)
        return session.run(job, report)


class SphereSession:
    """One planner + one executor shared by a chain of Sphere jobs.

    The per-job engine path re-derives everything on every ``run``:
    Sector metadata lookup, replica placement, a cold executor whose
    pad-stable/mask-aware UDFs must re-trace.  A session hoists all of
    that to the chain level:

      * the Sector chunk lookup for ``input_file`` runs once, lazily, and
        the resulting stage-0 task specs are reused by every job that
        reads the file;
      * replica placement for stage 0 (the dominant planning cost) is
        computed once — the planner is deterministic over task sizes, so
        the cached :class:`StagePlan` is exactly what re-planning would
        produce, and its counters are re-charged to each job's report;
      * the executor persists: stage-0 chunks are fetched and decoded
        once (``cache_chunks``), traced UDFs stay compiled (a stage
        object re-run each iteration reports ``udf_traces == 1`` across
        the whole chain), and each job's output partitions stay
        device-resident;
      * ``run(job, input="chained")`` feeds the previous job's output
        partitions straight into the next job's stage 0 — no host
        round-trip, no Sector traffic;
      * speculation/straggler observations reset at every job boundary
        (:meth:`SpherePlanner.reset_job_state`), so behaviour per job is
        identical to a fresh engine run.

    The session assumes stable cluster membership; after a server joins
    or dies, call :meth:`refresh` to re-bind to the live workers and drop
    the cached lookup/plan/chunks (chained partitions are dropped too —
    they are keyed to the old membership).
    """

    def __init__(self, engine: SphereEngine, input_file: str, *,
                 record_size: int = 0, backend: str = "bytes",
                 cache_chunks: bool = True):
        self.engine = engine
        self.input_file = input_file
        self.record_size = record_size
        self.backend = backend
        self._cache_chunks = cache_chunks
        self.planner = SpherePlanner(speeds=engine.speeds,
                                     speculate_factor=engine.speculate_factor,
                                     move_time=engine._move_time)
        self._stage0_tasks: Optional[List[TaskSpec]] = None
        self._stage0_plan = None
        self._stage0_stragglers: Dict[str, int] = {}
        self._parts = None          # last job's output partitions
        self.jobs_run = 0
        self._bind_cluster()

    def _bind_cluster(self) -> None:
        self.workers = self.engine._workers()
        if not self.workers:
            raise RuntimeError("no live workers")
        self.executor = make_executor(self.backend, self.engine.client,
                                      self.workers,
                                      max_retries=self.engine.max_retries,
                                      pad_block=self.engine.pad_block,
                                      cache_chunks=self._cache_chunks)

    # --------------------------------------------------------------- cache
    def refresh(self) -> None:
        """Re-bind the session to the current cluster: re-derive live
        workers, rebuild the executor (dropping the chunk, traced-UDF and
        chained-partition state, which are keyed to the old membership),
        and drop the cached lookup/placement."""
        self._stage0_tasks = None
        self._stage0_plan = None
        self._stage0_stragglers = {}
        self._parts = None
        self._bind_cluster()

    def _file_tasks(self) -> List[TaskSpec]:
        if self._stage0_tasks is None:
            master = self.engine.master
            metas = master.lookup(self.input_file, self.engine.client.user)
            self._stage0_tasks = [
                TaskSpec(m.chunk_id, m.size,
                         tuple(s for s in m.locations
                               if s in master.servers
                               and master.servers[s].alive))
                for m in metas]
        return self._stage0_tasks

    def _validate(self, job: SphereJob, input: str) -> None:
        if input not in ("file", "chained"):
            raise ValueError(f"unknown session input {input!r}; "
                             f"choose 'file' or 'chained'")
        if job.backend != self.backend:
            raise ValueError(f"job backend {job.backend!r} != session "
                             f"backend {self.backend!r}")
        if job.record_size != self.record_size:
            raise ValueError(f"job record_size {job.record_size} != session "
                             f"record_size {self.record_size}")
        if input == "file" and job.input_file != self.input_file:
            raise ValueError(f"job reads {job.input_file!r} but this session "
                             f"chains over {self.input_file!r}")
        chunk = self.engine.master.chunk_size
        if job.record_size and chunk % job.record_size:
            raise ValueError(
                f"chunk_size {chunk} must be a multiple of "
                f"record_size {job.record_size} (records must not straddle "
                f"chunk boundaries)")

    # ----------------------------------------------------------------- run
    def run(self, job: SphereJob, report: Optional[SphereReport] = None, *,
            input: str = "file") -> Tuple[List[bytes], SphereReport]:
        """Execute one job of the chain.  ``input="file"`` reads the
        session's Sector file (cached lookup/plan/chunks); ``"chained"``
        consumes the previous job's output partitions in place — on the
        array backend they are still device-resident RecordBatches.
        Returns (per-bucket output blobs, report)."""
        self._validate(job, input)
        rep = report or SphereReport()
        workers = self.workers
        planner, executor = self.planner, self.executor
        planner.reset_job_state()

        if input == "chained":
            if self._parts is None:
                raise RuntimeError("no previous job output to chain from")
            parts = self._parts
            sizes = executor.part_sizes(parts)
            tasks = [TaskSpec(w, sz, (w,))
                     for w, sz in sizes.items() if sz]
            first = False
        else:
            tasks = self._file_tasks()
            parts = executor.empty_parts()
            first = True

        for stage in job.stages:
            if first and self._stage0_plan is not None:
                plan = self._stage0_plan
                # replay the straggler observations planning this stage
                # made the first time, so later stages of every chained
                # job see exactly the state a fresh plan would produce
                planner.job_stragglers.update(self._stage0_stragglers)
            else:
                plan = planner.plan_stage(self.engine._schedule_view(tasks),
                                          workers)
                if first:
                    self._stage0_plan = plan
                    # job_stragglers was empty at job start (reset above),
                    # so this is exactly stage 0's contribution
                    self._stage0_stragglers = dict(planner.job_stragglers)
            rep.tasks += len(plan.tasks)
            rep.bytes_local += plan.bytes_local
            rep.bytes_moved += plan.bytes_moved
            rep.speculated += plan.speculated
            rep.speculation_wins += plan.speculation_wins
            t_stage = plan.seconds

            out = executor.run_stage(job, stage, plan, parts, rep,
                                     first_stage=first)
            if stage.partitioner is not None:
                n = stage.n_buckets or len(workers)
                buckets, origins = executor.bucketize(stage, out, n, rep)
                # bucket i lives on worker i % len(workers); charge the
                # movement of each fragment from its actual origin worker
                flows = [(src, workers[i % len(workers)], nbytes)
                         for i, origin in enumerate(origins)
                         for src, nbytes in origin.items()]
                t_shuffle, moved, local = planner.plan_shuffle(flows)
                rep.bytes_moved += moved
                rep.bytes_local += local
                t_stage += t_shuffle
                executor.place_buckets(buckets, parts)
            else:
                executor.set_parts(parts, out)

            sizes = executor.part_sizes(parts)
            t_stage += self.engine._stage_barrier_seconds(sum(sizes.values()))
            rep.stage_seconds.append(t_stage)
            rep.sim_seconds += t_stage
            first = False
            # next stage's tasks are the current partitions (local to owner)
            tasks = [TaskSpec(w, sz, (w,))
                     for w, sz in sizes.items() if sz]

        moved_total = rep.bytes_moved + rep.bytes_local
        rep.locality_fraction = (rep.bytes_local / moved_total
                                 if moved_total else 1.0)
        self._parts = parts
        self.jobs_run += 1
        return executor.outputs(parts), rep
