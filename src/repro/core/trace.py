"""Sphere tracing plane: spans, instants, and Perfetto-ready export.

The Sector/Sphere papers make monitoring a first-class master component
(the master "maintains the metadata ... and monitors the slave nodes");
this module is the reproduction's equivalent: a span tracer threaded
through the planner, executor, stream/session and Sector master so a
whole job — every per-task span, every shuffle round, every host sync,
every bus event — is inspectable on one timeline instead of being
summed away into end-of-job aggregates.

Two clock domains coexist, and every span/instant belongs to exactly one:

* ``wall``  — real host seconds (``time.perf_counter`` relative to the
  tracer's construction).  The data plane lives here: chunk fetches,
  UDF dispatches, shuffle rounds, host-sync markers.
* ``sim``   — the engine's simulated seconds.  The control plane lives
  here: per-task execution spans on ``worker:*`` tracks, transfer
  reservations on ``link:*`` tracks, Sector bus events.

:meth:`Tracer.export_chrome` writes Chrome trace-event JSON (the format
Perfetto and ``chrome://tracing`` open directly): one *process* per
clock domain, one *thread* (track) per worker / physical link / lane,
complete ("X") events for spans and instant ("i") events for markers.
Timestamps are microseconds within their domain.

Zero-cost-when-off contract: the default tracer everywhere is
:data:`NULL_TRACER`, whose :meth:`~NullTracer.span` returns a minimal
timer object (the data plane still reads ``wall_seconds`` off it — one
timing idiom whether tracing is on or not) and records nothing; every
other method is a no-op.  Neither tracer ever touches a device or adds
a host sync: span metadata rides the data plane's existing
one-sync-per-round harvest.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Dict, Hashable, List, Optional, Tuple

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]

WALL = "wall"
SIM = "sim"
_CLOCKS = (WALL, SIM)

# Chrome trace-event pids, one per clock domain (Perfetto renders each
# pid as its own process group with an independent time axis origin)
_PID = {SIM: 1, WALL: 2}
_PID_NAME = {SIM: "sim-clock", WALL: "wall-clock"}


class Span:
    """One traced operation: explicit start/end, a parent link, a track,
    timestamps in ONE clock domain, and free-form attributes.

    Used as a context manager for wall-clock spans (``t0``/``t1`` are
    captured on enter/exit); already-closed spans (the planner's
    simulated-time task and transfer spans) are appended via
    :meth:`Tracer.add_span` with both timestamps supplied."""

    __slots__ = ("name", "track", "clock", "span_id", "parent_id",
                 "t0", "t1", "attrs", "kind", "_tracer")

    def __init__(self, name: str, track: str, clock: str, span_id: int,
                 parent_id: Optional[int], attrs: Optional[dict],
                 tracer: Optional["Tracer"] = None, kind: str = "span"):
        self.name = name
        self.track = track
        self.clock = clock
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.kind = kind                      # "span" | "instant"
        self.t0: Optional[float] = None
        self.t1: Optional[float] = None
        self._tracer = tracer

    @property
    def wall_seconds(self) -> float:
        """Measured duration (valid after exit; wall-clock spans)."""
        return (self.t1 or 0.0) - (self.t0 or 0.0)

    def set_attrs(self, **attrs) -> "Span":
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if self.t0 is None:
            self.t0 = self._tracer._now()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = self._tracer._now()
        self._tracer._close(self)


class _NullSpan:
    """The disabled tracer's span: a bare wall-clock timer.  Records
    nothing anywhere, but still measures, so call sites read
    ``wall_seconds`` identically whether tracing is on or off."""

    __slots__ = ("t0", "t1")

    @property
    def wall_seconds(self) -> float:
        return (self.t1 or 0.0) - (self.t0 or 0.0)

    def set_attrs(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = time.perf_counter()


class NullTracer:
    """The default, zero-cost tracer: every hook is a no-op (spans still
    time themselves — see :class:`_NullSpan`)."""

    enabled = False

    def span(self, name: str, *, track: str = "control",
             parent: Optional[int] = None,
             attrs: Optional[dict] = None) -> _NullSpan:
        return _NullSpan()

    def add_span(self, name: str, *, track: str, t0: float, t1: float,
                 clock: str = SIM, parent: Optional[int] = None,
                 attrs: Optional[dict] = None) -> None:
        return None

    def instant(self, name: str, *, track: str, t: Optional[float] = None,
                clock: str = WALL, attrs: Optional[dict] = None) -> None:
        return None

    def attach_bus(self, bus, *, replay: bool = True):
        return None

    def export_chrome(self, path: str) -> dict:
        raise RuntimeError("tracing is disabled (NullTracer); construct "
                           "the engine with tracer=Tracer() to record")


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer.  Thread-safe appends (the executor's stage-0
    prefetch thread emits fetch spans concurrently with the main
    thread); the implicit parent stack is thread-local, so a producer
    thread's spans parent to its own enclosing span or none at all,
    never to another thread's."""

    enabled = True

    def __init__(self):
        self._epoch = time.perf_counter()
        self._events: List[Span] = []
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._open = 0

    # ---------------------------------------------------------- recording
    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def _stack(self) -> List[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, *, track: str = "control",
             parent: Optional[int] = None,
             attrs: Optional[dict] = None) -> Span:
        """A wall-clock span, used as a context manager.  ``parent``
        defaults to the innermost open span on this thread."""
        stack = self._stack()
        if parent is None and stack:
            parent = stack[-1]
        sp = Span(name, track, WALL, next(self._ids), parent,
                  dict(attrs) if attrs else None, tracer=self)
        stack.append(sp.span_id)
        with self._lock:
            self._open += 1
        return sp

    def _close(self, sp: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] == sp.span_id:
            stack.pop()
        elif sp.span_id in stack:          # exited out of order: still drop
            stack.remove(sp.span_id)
        with self._lock:
            self._open -= 1
            self._events.append(sp)

    def add_span(self, name: str, *, track: str, t0: float, t1: float,
                 clock: str = SIM, parent: Optional[int] = None,
                 attrs: Optional[dict] = None) -> Span:
        """Append an already-closed span (simulated-clock spans are
        computed after the fact from the planner's task finish times)."""
        if clock not in _CLOCKS:
            raise ValueError(f"unknown clock {clock!r}; choose {_CLOCKS}")
        stack = self._stack()
        if parent is None and stack and clock == WALL:
            parent = stack[-1]
        sp = Span(name, track, clock, next(self._ids), parent,
                  dict(attrs) if attrs else None)
        sp.t0, sp.t1 = float(t0), float(t1)
        with self._lock:
            self._events.append(sp)
        return sp

    def instant(self, name: str, *, track: str, t: Optional[float] = None,
                clock: str = WALL, attrs: Optional[dict] = None) -> Span:
        """A zero-duration marker (host syncs, bus events, window
        advances)."""
        if clock not in _CLOCKS:
            raise ValueError(f"unknown clock {clock!r}; choose {_CLOCKS}")
        at = self._now() if t is None else float(t)
        sp = Span(name, track, clock, next(self._ids), None,
                  dict(attrs) if attrs else None, kind="instant")
        sp.t0 = sp.t1 = at
        with self._lock:
            self._events.append(sp)
        return sp

    # ----------------------------------------------------------- event bus
    def attach_bus(self, bus, *, replay: bool = True):
        """Turn every :class:`~repro.sector.events.EventBus` event into a
        zero-duration instant on the simulated-clock ``events`` track.
        With ``replay`` (default) the bus's bounded history is replayed
        first, so a tracer attached after the cloud was built still
        shows the recent control-plane past.  Returns the subscription."""
        if replay:
            for ev in bus.replay():
                self._bus_instant(ev)
        return bus.subscribe(self._bus_instant)

    def _bus_instant(self, ev) -> None:
        attrs = {"seq": ev.seq, "path": ev.path}
        for k, v in ev.detail.items():
            if isinstance(v, (int, float, str, bool)):
                attrs[k] = v
        self.instant(f"event:{ev.type}", track="events", t=ev.time,
                     clock=SIM, attrs=attrs)

    # -------------------------------------------------------------- export
    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self._events)

    def count(self, name: Optional[str] = None) -> int:
        """Recorded events, optionally filtered by exact name (tests)."""
        evs = self.snapshot()
        return len(evs) if name is None else \
            sum(1 for e in evs if e.name == name)

    def counts_by_name(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.snapshot():
            out[e.name] = out.get(e.name, 0) + 1
        return out

    def export_chrome(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON: one process per clock domain, one
        thread per track, events sorted by timestamp within each track
        (the monotonicity :mod:`scripts.check_trace` validates).  When
        ``path`` is given the document is also written there.  Returns
        the document."""
        events = self.snapshot()
        # stable track ids: (clock, track) in first-appearance order
        tids: Dict[Tuple[str, str], int] = {}
        per_track: Dict[Tuple[str, str], List[Span]] = {}
        for sp in events:
            key = (sp.clock, sp.track)
            if key not in tids:
                tids[key] = len(tids) + 1
                per_track[key] = []
            per_track[key].append(sp)

        doc_events: List[dict] = []
        for clock in (SIM, WALL):
            if any(k[0] == clock for k in tids):
                doc_events.append({"name": "process_name", "ph": "M",
                                   "pid": _PID[clock],
                                   "args": {"name": _PID_NAME[clock]}})
        for (clock, track), tid in tids.items():
            doc_events.append({"name": "thread_name", "ph": "M",
                               "pid": _PID[clock], "tid": tid,
                               "args": {"name": track}})
        for key, spans in per_track.items():
            clock, _track = key
            spans.sort(key=lambda s: (s.t0, s.span_id))
            for sp in spans:
                ev = {"name": sp.name, "pid": _PID[clock],
                      "tid": tids[key],
                      "ts": round(sp.t0 * 1e6, 3),
                      "args": {"id": sp.span_id}}
                if sp.parent_id is not None:
                    ev["args"]["parent"] = sp.parent_id
                if sp.attrs:
                    ev["args"].update(sp.attrs)
                if sp.kind == "instant":
                    ev["ph"] = "i"
                    ev["s"] = "t"          # thread-scoped marker
                else:
                    ev["ph"] = "X"
                    ev["dur"] = round((sp.t1 - sp.t0) * 1e6, 3)
                doc_events.append(ev)

        with self._lock:
            open_spans = self._open
        doc = {
            "traceEvents": doc_events,
            "displayTimeUnit": "ms",
            "otherData": {
                "open_spans": open_spans,
                "spans": sum(1 for e in events if e.kind == "span"),
                "instants": sum(1 for e in events if e.kind == "instant"),
                "clock_domains": {
                    SIM: "simulated engine seconds (pid 1)",
                    WALL: "host perf_counter seconds since tracer "
                          "construction (pid 2)",
                },
            },
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, default=repr)
        return doc


def link_track(key: Hashable) -> str:
    """Canonical track name for a physical link's reservation spans."""
    return f"link:{key}"
