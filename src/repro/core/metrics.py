"""Sphere metrics plane: labeled counters, gauges and histograms.

:class:`MetricsRegistry` is the single write path the engine's
end-of-job aggregates flow through: a :class:`~repro.core.planner.
SphereReport` bound to a registry (``report.bind_metrics(registry,
**labels)``) mirrors every counter mutation into the registry *as it
happens* — the mirror lives inside ``SphereReport.__setattr__``, so the
report's fields and the registry's series are two reads of one write and
can never disagree (tested in ``tests/test_trace.py``).

The registry is deliberately small and dependency-free (no Prometheus
client): three instrument kinds, each identified by ``(name, labels)``:

* **counter**   — monotonically-growing total (``inc``);
* **gauge**     — last-set value (``set``);
* **histogram** — count / sum / min / max of observations (``observe``)
  — enough to answer "how many stages and how long" without binning
  policy.

Registering the same ``(name, labels)`` under two different kinds is an
error: a series' kind is part of its contract.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram"]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    kind = "?"

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = dict(labels)


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, name: str, labels: Dict[str, str]):
        super().__init__(name, labels)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, name: str, labels: Dict[str, str]):
        super().__init__(name, labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, labels: Dict[str, str]):
        super().__init__(name, labels)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def stats(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min, "max": self.max}


class MetricsRegistry:
    """Instrument factory + store.  ``counter``/``gauge``/``histogram``
    get-or-create the series for ``(name, labels)``; ``value`` reads a
    scalar series back (0.0 when the series was never written, so reads
    and an untouched report field agree)."""

    def __init__(self):
        self._series: Dict[Tuple[str, LabelKey], _Instrument] = {}
        self._binds = 0

    def _get(self, cls, name: str, labels: Dict[str, str]) -> _Instrument:
        key = (name, _label_key(labels))
        inst = self._series.get(key)
        if inst is None:
            inst = self._series[key] = cls(name, labels)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} {labels} already registered "
                            f"as a {inst.kind}, not a {cls.kind}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    # ---------------------------------------------------------------- reads
    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge series (0.0 if unwritten)."""
        inst = self._series.get((name, _label_key(labels)))
        if inst is None:
            return 0.0
        if isinstance(inst, Histogram):
            raise TypeError(f"metric {name!r} is a histogram; use "
                            f"histogram(...).stats()")
        return inst.value

    def series(self, name: Optional[str] = None) -> List[_Instrument]:
        """Every registered instrument, optionally filtered by name."""
        return [inst for (n, _), inst in sorted(self._series.items())
                if name is None or n == name]

    def snapshot(self) -> List[dict]:
        """Plain-data dump (benchmark JSON, debugging)."""
        out = []
        for (name, _), inst in sorted(self._series.items()):
            row = {"name": name, "kind": inst.kind, "labels": inst.labels}
            if isinstance(inst, Histogram):
                row.update(inst.stats())
            else:
                row["value"] = inst.value
            out.append(row)
        return out

    def next_run_labels(self) -> Dict[str, str]:
        """A unique ``run`` label per report binding, so two reports
        mirrored into one registry never collide on a series (each
        report's fields equal ITS labeled series exactly)."""
        self._binds += 1
        return {"run": f"r{self._binds}"}
