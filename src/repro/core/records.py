"""Array-native record batches for the Sphere engine.

The paper's Sphere engine streams fixed-size records between UDF stages;
the seed implementation models a record as a Python ``bytes`` object and
pays a Python-level loop (md5 / binary search per record) in the shuffle.
``RecordBatch`` packs the same records into a single ``uint8 [n, width]``
JAX array so that key extraction, partitioning (via the Pallas
``bucket_partition`` kernel) and record movement are single vectorised
array operations.

Conventions shared by the bytes reference path and the array path:

* **Range keys** are rows of big-endian ``uint32`` words covering a
  record's key prefix (``key_words`` — the tail word is zero-padded, and
  an optional trailing length word breaks ties exactly like Python's
  shorter-prefix-sorts-first rule).  Comparing word rows
  lexicographically is identical to comparing the byte prefixes, so the
  array path agrees with ``range_partitioner`` record-for-record for
  boundaries of any length (10-byte TeraSort keys use 3 words).
* **Hash keys** are FNV-1a 32-bit over the first ``key_bytes`` bytes —
  ``fnv1a32`` is the scalar reference, ``hash_keys_u32`` the vectorised
  twin.  Both paths then map the hash onto buckets by counting the
  ``uniform_hash_bounds`` thresholds below it, which is exactly the
  comparison the Pallas kernel implements.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

FNV_OFFSET32 = 0x811C9DC5
FNV_PRIME32 = 0x01000193


def fnv1a32(data: bytes) -> int:
    """Scalar FNV-1a 32-bit — the reference for ``hash_keys_u32``."""
    h = FNV_OFFSET32
    for b in data:
        h = ((h ^ b) * FNV_PRIME32) & 0xFFFFFFFF
    return h


def uniform_hash_bounds(n_buckets: int) -> np.ndarray:
    """Sorted uint32 thresholds splitting hash space into n equal ranges.

    ``bucket(h) = #{i : bounds[i] < h}`` — the same "count boundaries
    below the key" rule the bucket_partition kernel computes, so one
    kernel serves both hash and range partitioning.
    """
    return np.array([(((i + 1) << 32) // n_buckets) - 1
                     for i in range(n_buckets - 1)], dtype=np.uint32)


@dataclass(frozen=True)
class RecordBatch:
    """Fixed-width records packed as a uint8 [rows, record_size] array.

    A batch may be *padding-resident*: ``n_valid`` (when set) says only
    the first ``n_valid`` rows are real records and the tail rows are
    shape padding whose CONTENT IS JUNK — never normalised, never
    inspected.  Every consumer of a possibly-padded batch either slices
    the valid prefix (``valid_data`` / the codecs below), masks the tail
    inside a jitted call (pad-stable / mask-aware stage UDFs normalise
    padding to their declared pad byte on device), or routes it to the
    scatter kernel's trash bucket (``scatter_batch``'s dynamic
    ``n_valid``).  This is what lets the engine pass fixed-shape blocks
    between stages and shuffles without a slice-then-repad copy per hop.
    ``n_valid is None`` means every row is real (the pre-existing exact
    batch — all constructors outside the executor produce these).
    """

    data: jax.Array
    n_valid: Optional[int] = None

    def __post_init__(self):
        if self.data.ndim != 2:
            raise ValueError(f"RecordBatch data must be 2-D, "
                             f"got shape {self.data.shape}")
        if self.n_valid is not None:
            if not 0 <= self.n_valid <= self.data.shape[0]:
                raise ValueError(f"n_valid {self.n_valid} outside "
                                 f"[0, {self.data.shape[0]}]")
            if self.n_valid == self.data.shape[0]:
                # a fully-valid batch IS an exact batch — normalising to
                # None keeps "padded" meaning strictly padded (and the
                # concat fast path returning `is`-identical batches)
                object.__setattr__(self, "n_valid", None)

    # ------------------------------------------------------------ shape
    @property
    def num_records(self) -> int:
        """Real (valid) records — NOT the padded row count."""
        return self.n_valid if self.n_valid is not None \
            else self.data.shape[0]

    @property
    def padded_rows(self) -> int:
        """Physical rows of the resident block, padding included."""
        return self.data.shape[0]

    @property
    def record_size(self) -> int:
        return self.data.shape[1]

    @property
    def nbytes(self) -> int:
        """Valid payload bytes — padding is free, so planner movement
        pricing and part sizes agree with the bytes backend exactly."""
        return self.num_records * self.data.shape[1]

    # ---------------------------------------------------- padding views
    @property
    def valid_data(self) -> jax.Array:
        """The [num_records, record_size] valid prefix (zero-copy for
        exact batches)."""
        return self.data if self.n_valid is None else self.data[:self.n_valid]

    def compact(self) -> "RecordBatch":
        """An exact batch holding only the valid rows (self when already
        exact)."""
        return self if self.n_valid is None \
            else RecordBatch(self.data[:self.n_valid])

    def block(self, n_rows: int) -> jax.Array:
        """A [n_rows, record_size] block whose first ``num_records`` rows
        are the valid records — tail content is JUNK (reused resident
        padding, or zeros when the block grows).  This is the no-copy
        hand-off into fixed-shape jitted consumers: same shape reuses the
        resident array as-is, a larger resident block is prefix-sliced.
        """
        n = self.num_records
        if n > n_rows:
            raise ValueError(f"cannot fit {n} records in a {n_rows}-row "
                             f"block")
        rows = self.data.shape[0]
        if rows == n_rows:
            return self.data
        if rows > n_rows:
            return self.data[:n_rows]
        return jnp.pad(self.data, ((0, n_rows - rows), (0, 0)))

    # ------------------------------------------------------------ codecs
    @staticmethod
    def from_bytes(blob: bytes, record_size: int) -> "RecordBatch":
        if record_size <= 0:
            raise ValueError("array backend needs a fixed record_size > 0")
        if len(blob) % record_size:
            raise ValueError(f"blob of {len(blob)} bytes is not a multiple "
                             f"of record_size {record_size}")
        arr = np.frombuffer(blob, np.uint8).reshape(-1, record_size)
        return RecordBatch(jnp.asarray(arr))

    @staticmethod
    def from_records(records: Sequence[bytes]) -> "RecordBatch":
        if not records:
            raise ValueError("cannot infer record_size from zero records")
        width = len(records[0])
        if any(len(r) != width for r in records):
            raise ValueError("RecordBatch requires uniform record size")
        return RecordBatch.from_bytes(b"".join(records), width)

    def to_bytes(self) -> bytes:
        # valid rows only — padding never leaks into materialised output
        return np.asarray(self.data)[:self.num_records].tobytes()

    def to_records(self) -> List[bytes]:
        raw = np.asarray(self.data)
        return [raw[i].tobytes() for i in range(self.num_records)]

    # ------------------------------------------------------ restructuring
    @staticmethod
    def empty(record_size: int) -> "RecordBatch":
        return RecordBatch(jnp.zeros((0, record_size), jnp.uint8))

    @staticmethod
    def concat(batches: Sequence["RecordBatch"]) -> "RecordBatch":
        """Concatenate valid records.  A single non-empty input returns
        ITSELF (no copy — and a padding-resident batch stays resident);
        multi-input concat materialises the valid prefixes."""
        if not batches:
            raise ValueError("cannot concat zero batches")
        nonempty = [b for b in batches if b.num_records]
        if not nonempty:
            return batches[0]
        if len(nonempty) == 1:
            return nonempty[0]
        return RecordBatch(jnp.concatenate([b.valid_data for b in nonempty],
                                           axis=0))

    @staticmethod
    def concat_block(batches: Sequence["RecordBatch"], n_rows: int
                     ) -> "RecordBatch":
        """Concatenate valid records straight into an ``n_rows`` block —
        the concat+pad fusion.  The result is padding-resident (zeros
        tail) at exactly ``n_rows`` rows, so a downstream
        ``block(n_rows)`` hands the array over untouched: one copy total
        where ``concat`` + ``block`` would pay two.  A single non-empty
        input already at ``n_rows`` rows returns ITSELF."""
        if not batches:
            raise ValueError("cannot concat zero batches")
        nonempty = [b for b in batches if b.num_records]
        if len(nonempty) == 1 and nonempty[0].padded_rows == n_rows:
            return nonempty[0]
        nrec = sum(b.num_records for b in nonempty)
        if nrec > n_rows:
            raise ValueError(f"cannot fit {nrec} records in a {n_rows}-row "
                             f"block")
        width = batches[0].record_size
        parts = [b.valid_data for b in nonempty]
        if nrec < n_rows:
            parts.append(jnp.zeros((n_rows - nrec, width), jnp.uint8))
        return RecordBatch(jnp.concatenate(parts, axis=0), n_valid=nrec)

    def take(self, idx) -> "RecordBatch":
        """Gather rows by index.  Valid rows always form the block's
        prefix, so indices < ``num_records`` address the same records on
        exact and padding-resident batches alike."""
        return RecordBatch(jnp.take(self.data, jnp.asarray(idx), axis=0))

    def pad_to(self, n_rows: int, pad_value: int = 0) -> "RecordBatch":
        """Right-pad with MATERIALISED ``pad_value`` rows up to ``n_rows``
        and return an exact batch — the explicit-padding legacy/API path
        (the executor's hot path uses :meth:`block`, whose padding stays
        junk and is normalised on device instead)."""
        n = self.num_records
        if n_rows < n:
            raise ValueError(f"cannot pad {n} records down to {n_rows}")
        if n_rows == n:
            return self.compact() if self.n_valid is not None else self
        return RecordBatch(jnp.pad(self.valid_data,
                                   ((0, n_rows - n), (0, 0)),
                                   constant_values=pad_value))

    # --------------------------------------------------------------- keys
    # Key views are BLOCK-level: they cover every physical row, padding
    # included (the scatter kernel trash-buckets rows >= its dynamic
    # n_valid, and in-jit callers see normalised padding).  Host-side
    # analysis paths compact() a padding-resident batch first.
    def keys_u32(self, width: int = 4) -> jax.Array:
        """Big-endian uint32 of each record's first ``width`` (<= 4) bytes,
        zero-padded — order-isomorphic to lexicographic comparison of the
        same ``width``-byte prefixes.
        """
        w = min(width, 4, self.record_size)
        d = self.data[:, :w]
        if w < 4:
            d = jnp.pad(d, ((0, 0), (0, 4 - w)))
        k = d.astype(jnp.uint32)
        return (k[:, 0] << 24) | (k[:, 1] << 16) | (k[:, 2] << 8) | k[:, 3]

    def hash_keys_u32(self, key_bytes: int) -> jax.Array:
        """Vectorised FNV-1a 32-bit over each record's first key_bytes."""
        d = self.data
        h = jnp.full((d.shape[0],), FNV_OFFSET32, jnp.uint32)
        for j in range(min(key_bytes, d.shape[1])):
            h = (h ^ d[:, j].astype(jnp.uint32)) * jnp.uint32(FNV_PRIME32)
        return h

    def _key_words(self, key_bytes: int) -> List[jax.Array]:
        """Big-endian uint32 words covering the first key_bytes bytes.

        The tail word is zero-padded — payload bytes past key_bytes must
        not leak into the sort key (ties keep the stable input order,
        matching the bytes backend's ``sorted(key=r[:kb])``).
        """
        d = self.data
        kb = min(key_bytes, d.shape[1])
        d = d[:, :kb]
        pad = (-kb) % 4
        if pad:
            d = jnp.pad(d, ((0, 0), (0, pad)))
        words = []
        for i in range(0, kb, 4):
            w = d[:, i:i + 4].astype(jnp.uint32)
            words.append((w[:, 0] << 24) | (w[:, 1] << 16)
                         | (w[:, 2] << 8) | w[:, 3])
        return words

    def key_words(self, key_bytes: int, *, n_words: int | None = None,
                  length_word: int | None = None) -> jax.Array:
        """[n, k] big-endian uint32 key rows for the multi-word kernel.

        The first ``key_bytes`` bytes of each record, zero-padded into
        4-byte words.  ``n_words`` right-pads with zero columns (aligning
        a batch against a wider boundary table); ``length_word`` appends
        one constant trailing word so variable-length boundary strings
        compare exactly like Python ``bytes`` (when the zero-padded words
        tie, the shorter string sorts first).
        """
        words = self._key_words(key_bytes)
        n = self.num_records
        if n_words is not None:
            while len(words) < n_words:
                words.append(jnp.zeros((n,), jnp.uint32))
        if not words:
            words.append(jnp.zeros((n,), jnp.uint32))
        if length_word is not None:
            words.append(jnp.full((n,), length_word, jnp.uint32))
        return jnp.stack(words, axis=1)

    def sort_by_key(self, key_bytes: int) -> "RecordBatch":
        """Stable sort by the full key prefix (lexicographic, any length).

        Sorts the VALID records (junk padding rows must not interleave);
        pad-stable stage UDFs call this on in-jit blocks whose padding
        was already normalised, where compact() is a no-op."""
        base = self.compact()
        words = base._key_words(key_bytes)
        # jnp.lexsort treats the LAST key as primary
        order = jnp.lexsort(tuple(reversed(words)))
        return base.take(order)

    # ------------------------------------------------------- float views
    def to_points(self, dim: int) -> jax.Array:
        """Reinterpret valid records as little-endian float32 [n, dim]
        points (junk padding rows would bitcast to garbage floats)."""
        if self.record_size != 4 * dim:
            raise ValueError(f"record_size {self.record_size} != 4*dim")
        return jax.lax.bitcast_convert_type(
            self.valid_data.reshape(self.num_records, dim, 4), jnp.float32)

    @staticmethod
    def from_points(points: jax.Array) -> "RecordBatch":
        """float32 [n, d] points -> records of d*4 bytes each."""
        n, d = points.shape
        raw = jax.lax.bitcast_convert_type(points.astype(jnp.float32),
                                           jnp.uint8)
        return RecordBatch(raw.reshape(n, d * 4))


def _pow2_rows(n: int, floor: int) -> int:
    """Smallest padded row count >= n from the {2^k, 1.5 * 2^k} ladder,
    floored at ``floor`` — the fixed shapes batches pad to so kernel
    traces are shared across batch sizes.  The half-octave step caps
    padding waste at ~33% (a pure power-of-two ladder can waste ~100%)
    while keeping the number of distinct traced shapes per octave at 2."""
    target = max(floor, 2)
    while target < n:
        if target + target // 2 >= n:
            return target + target // 2
        target *= 2
    return target


def _quarter_rows(n: int, floor: int) -> int:
    """Smallest padded row count >= n from the quarter-octave
    {2^k, 1.25*2^k, 1.5*2^k, 1.75*2^k} ladder, floored at ``floor``.

    Finer than :func:`_pow2_rows` on purpose: the once-per-stage block
    shape is computed a single time from the plan's largest task, so a
    denser ladder costs no extra traces there — and it caps the
    junk-tail at ~25% worst case (typically a few percent) where the
    half-octave ladder allows ~33%.  That junk tail is not free: every
    padding row rides through the segmented scatter's mask, kernel scan
    and destination fetch each round (e.g. 5 000-record stage-0 chunks
    pad to 5 120 here vs 6 144 on the half-octave ladder — an 18%
    shuffle-volume cut at the TeraSort 1M scale).  Ad-hoc batch padding
    (``scatter_batch``) keeps the coarser ladder, where fewer rungs
    means more trace sharing across varying batch sizes."""
    base = max(floor, 4)
    while base * 2 < n:
        base *= 2
    if n <= base:
        return base
    for num in (5, 6, 7):
        cand = base * num // 4
        if cand >= n:
            return cand
    return base * 2


@dataclass(frozen=True)
class StackedBatch:
    """A whole round's worth of batches as ONE device array.

    ``data`` is uint8 [n_slots, block, width]: one slot per task/worker
    of a fused engine round, every slot padded to the same quarter-octave
    ``block`` row count so the stack is a single rectangular array.
    ``n_valid`` is a HOST [n_slots] int32 vector of real row counts —
    slot tails are junk padding exactly as in a padding-resident
    :class:`RecordBatch`, and keeping the counts host-side means shape
    queries (part sizes, plan block shapes) never touch the device.

    This is the unit the fused data plane operates on: one vmapped UDF
    call, one stacked scatter dispatch and one regrouping gather per
    round, instead of a Python loop of per-slot dispatches.  A slot with
    ``n_valid == 0`` is a real (empty) participant — empty workers ride
    through the fused round for free rather than forcing a fallback.
    """

    data: jax.Array
    n_valid: np.ndarray

    def __post_init__(self):
        if self.data.ndim != 3:
            raise ValueError(f"StackedBatch data must be 3-D, "
                             f"got shape {self.data.shape}")
        nv = np.asarray(self.n_valid, dtype=np.int32)
        if nv.shape != (self.data.shape[0],):
            raise ValueError(f"n_valid shape {nv.shape} != "
                             f"({self.data.shape[0]},)")
        if nv.size and (int(nv.min()) < 0
                        or int(nv.max()) > self.data.shape[1]):
            raise ValueError(f"n_valid outside [0, {self.data.shape[1]}]")
        object.__setattr__(self, "n_valid", nv)

    # ------------------------------------------------------------ shape
    @property
    def n_slots(self) -> int:
        return self.data.shape[0]

    @property
    def block_rows(self) -> int:
        """Padded rows per slot (every slot shares one block shape)."""
        return self.data.shape[1]

    @property
    def record_size(self) -> int:
        return self.data.shape[2]

    @property
    def num_records(self) -> int:
        """Real records across all slots."""
        return int(self.n_valid.sum())

    @property
    def nbytes(self) -> int:
        """Valid payload bytes across all slots (padding is free)."""
        return self.num_records * self.record_size

    # ------------------------------------------------------- conversions
    def slot(self, i: int) -> RecordBatch:
        """Slot ``i`` as a padding-resident RecordBatch (device slice)."""
        return RecordBatch(self.data[i], n_valid=int(self.n_valid[i]))

    def unpack(self) -> List[RecordBatch]:
        return [self.slot(i) for i in range(self.n_slots)]

    @staticmethod
    def pack(batches: Sequence[RecordBatch], block: int | None = None,
             pad_block: int = 4096) -> "StackedBatch":
        """Stack batches into one [s, block, width] array.

        ``block`` defaults to the quarter-octave ladder shape of the
        largest batch (floored at ``pad_block``) so every slot shares
        one padded shape; slot tails are junk, never materialised.
        NOTE: this is the eager convenience — the executor's hot path
        stacks inside its jitted UDF call instead, so the concat fuses
        with the stage body (see ``_TracedUDF``)."""
        if not batches:
            raise ValueError("cannot stack zero batches")
        width = batches[0].record_size
        if any(b.record_size != width for b in batches):
            raise ValueError("StackedBatch requires uniform record size")
        n_valid = np.fromiter((b.num_records for b in batches), np.int32,
                              count=len(batches))
        if block is None:
            block = _quarter_rows(max(int(n_valid.max()), 1), pad_block)
        data = jnp.stack([b.block(block) for b in batches])
        return StackedBatch(data, n_valid)


def scatter_by_ids(batch: RecordBatch, ids, hist) -> List[RecordBatch]:
    """Split a batch into per-bucket batches given kernel (ids, hist).

    One stable argsort of the bucket ids, then one contiguous gather per
    bucket — record order within a bucket matches the bytes backend's
    append order.  The argsort runs on the host: numpy's radix sort beats
    XLA:CPU's generic sort by ~20x, and ids are a tiny [n] int32 array.
    """
    ids_np = np.asarray(ids)
    hist_np = np.asarray(hist)
    order = np.argsort(ids_np, kind="stable")
    pieces = np.split(order, np.cumsum(hist_np)[:-1])
    return [batch.take(p.astype(np.int32)) for p in pieces]
