"""Distributed k-means as a Sphere job (paper §5.3, Table 2).

Angle's per-pcap clustering: aggregate packet data by source entity, compute
feature points, cluster with k-means. Structured as iterated two-stage
Sphere jobs:

  stage 1 (UDF, runs where the chunks live): assign each local point to the
      nearest centroid; emit per-centroid (sum, count) partials;
  shuffle: partials are tiny — they all go to bucket 0 (a reduce);
  stage 2 (UDF): fold partials into new centroids.

The device-level twin (``kmeans_step_jax``) is the same computation as a
shard_map over the mesh; the Pallas kernel in ``repro.kernels.kmeans_assign``
accelerates the assignment hot loop on TPU.
"""
from __future__ import annotations

import struct
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.engine import SphereEngine, SphereReport
from repro.core.job import SphereJob, SphereStage
from repro.core.records import RecordBatch


# --------------------------- record codecs ---------------------------------

def encode_points(pts: np.ndarray) -> bytes:
    """float32 points [N, D] -> fixed-size records."""
    return pts.astype("<f4").tobytes()


def decode_points(blob: bytes, dim: int) -> np.ndarray:
    return np.frombuffer(blob, "<f4").reshape(-1, dim)


def _encode_partial(sums: np.ndarray, counts: np.ndarray) -> bytes:
    k, d = sums.shape
    return struct.pack("<II", k, d) + sums.astype("<f8").tobytes() + \
        counts.astype("<i8").tobytes()


def _decode_partial(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    k, d = struct.unpack("<II", blob[:8])
    off = 8
    sums = np.frombuffer(blob[off:off + 8 * k * d], "<f8").reshape(k, d)
    off += 8 * k * d
    counts = np.frombuffer(blob[off:off + 8 * k], "<i8")
    return sums.copy(), counts.copy()


# --------------------------- Sphere job ------------------------------------

@jax.jit
def _assign_partial_batch(data_u8: jax.Array, c: jax.Array) -> jax.Array:
    """Array-backend assign UDF body: uint8 records [n, 4*dim] + centroids
    [k, dim] -> one partial record [1, 4*k*(dim+1)] holding float32
    (per-centroid sums ++ counts)."""
    n = data_u8.shape[0]
    pts = jax.lax.bitcast_convert_type(data_u8.reshape(n, -1, 4),
                                       jnp.float32)          # [n, dim]
    d2 = (jnp.sum(pts**2, 1)[:, None] - 2 * pts @ c.T
          + jnp.sum(c**2, 1)[None])
    a = jnp.argmin(d2, 1)
    oh = jax.nn.one_hot(a, c.shape[0], dtype=jnp.float32)
    sums = oh.T @ pts                                        # [k, dim]
    counts = oh.sum(0)                                       # [k]
    row = jnp.concatenate([sums, counts[:, None]], axis=1)[None]
    return jax.lax.bitcast_convert_type(row, jnp.uint8).reshape(1, -1)


def kmeans_sphere(engine: SphereEngine, file: str, dim: int, k: int,
                  iters: int, seed: int = 0, backend: str = "bytes"
                  ) -> Tuple[np.ndarray, SphereReport]:
    """Run k-means over a Sector file of float32 points via Sphere.

    ``backend="bytes"`` treats each chunk as one record and loops in
    numpy; ``backend="array"`` packs points into a :class:`RecordBatch`
    and runs the jitted assign UDF per chunk batch.
    """
    rng = np.random.default_rng(seed)
    centroids = rng.normal(size=(k, dim)).astype(np.float32)
    report = SphereReport()

    for _ in range(iters):
        c = centroids.copy()

        def assign_udf(records: List[bytes]) -> List[bytes]:
            out = []
            for blob in records:
                pts = decode_points(blob, dim)
                d2 = ((pts[:, None, :] - c[None]) ** 2).sum(-1)
                a = d2.argmin(1)
                sums = np.zeros((k, dim))
                counts = np.zeros(k, np.int64)
                np.add.at(sums, a, pts)
                np.add.at(counts, a, 1)
                out.append(_encode_partial(sums, counts))
            return out

        if backend == "array":
            c_dev = jnp.asarray(c)

            def assign_batch(batch: RecordBatch) -> RecordBatch:
                return RecordBatch(_assign_partial_batch(batch.data, c_dev))

            job = SphereJob(
                name="kmeans-assign", input_file=file,
                stages=[SphereStage("assign", batch_udf=assign_batch,
                                    partitioner=lambda r, n: 0)],
                record_size=4 * dim, backend="array")
        else:
            job = SphereJob(
                name="kmeans-assign", input_file=file,
                stages=[SphereStage("assign", assign_udf,
                                    partitioner=lambda r, n: 0)],  # reduce
                record_size=0)
        outputs, report = engine.run(job, report)
        sums = np.zeros((k, dim))
        counts = np.zeros(k, np.float64)
        for blob in outputs:
            if backend == "array":
                arr = np.frombuffer(blob, "<f4").reshape(-1, k, dim + 1)
                sums += arr[..., :dim].sum(0)
                counts += arr[..., dim].sum(0)
                continue
            off = 0
            while off < len(blob):
                kk, dd = struct.unpack("<II", blob[off:off + 8])
                size = 8 + 8 * kk * dd + 8 * kk
                s, n = _decode_partial(blob[off:off + size])
                sums += s
                counts += n
                off += size
        nz = counts > 0
        centroids[nz] = (sums[nz] / counts[nz, None]).astype(np.float32)
    return centroids, report


# --------------------------- JAX twin ---------------------------------------

def kmeans_step_jax(points: jax.Array, centroids: jax.Array,
                    mesh: Mesh | None = None, axis: str = "data"):
    """One k-means step. points [N, D] (sharded over axis when mesh given),
    centroids [K, D] replicated. Returns (new_centroids, inertia)."""

    def local(pts, c):
        d2 = (jnp.sum(pts**2, 1)[:, None] - 2 * pts @ c.T
              + jnp.sum(c**2, 1)[None])
        a = jnp.argmin(d2, 1)
        oh = jax.nn.one_hot(a, c.shape[0], dtype=pts.dtype)
        sums = oh.T @ pts
        counts = oh.sum(0)
        inertia = jnp.take_along_axis(d2, a[:, None], 1).sum()
        return sums, counts, inertia

    if mesh is None:
        sums, counts, inertia = local(points, centroids)
    else:
        def body(pts, c):
            s, n, i = local(pts, c)
            return (lax.psum(s, axis), lax.psum(n, axis),
                    lax.psum(i, axis))
        fn = _shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                        out_specs=(P(), P(), P()))
        sums, counts, inertia = fn(points, centroids)
    new_c = jnp.where(counts[:, None] > 0,
                      sums / jnp.maximum(counts[:, None], 1), centroids)
    return new_c, inertia
