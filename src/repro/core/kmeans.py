"""Distributed k-means as a chain of Sphere jobs (paper §5.3, Table 2).

Angle's per-pcap clustering: aggregate packet data by source entity, compute
feature points, cluster with k-means. Each iteration is one two-stage
Sphere job:

  stage "assign" (UDF, runs where the chunks live): assign each local point
      to the nearest centroid; emit ONE per-centroid (sums ++ counts)
      partial record per task;
  shuffle: partials all go to bucket 0 (``reduce_partitioner`` — the array
      path computes ids/hist directly, no per-record host loop);
  stage "fold" (UDF on the bucket-0 worker): fold the partial records into
      one (sums ++ counts) record; the host turns it into new centroids.

Iterations run through one :class:`SphereSession`: the Sector lookup,
replica placement and fetched chunks are reused, and both stage UDFs are
**mask-aware reductions** — the executor pads each task to a fixed block
shape and passes a validity mask plus the stage's current ``params`` (the
centroids) as dynamic jit arguments, so each stage traces exactly once for
the whole chain (``SphereReport.udf_traces == 1``) instead of once per
chunk shape per iteration.  ``session=False`` keeps the old re-plan +
re-trace-every-iteration path as the benchmark comparison baseline.

The device-level twin (``kmeans_step_jax``) is the same computation as a
shard_map over the mesh; the Pallas kernel in ``repro.kernels.kmeans_assign``
accelerates the assignment hot loop on TPU
(``kmeans_assign_partials`` picks kernel vs jnp oracle by backend).
"""
from __future__ import annotations

import struct
from typing import List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.engine import SphereEngine, SphereReport, SphereSession
from repro.core.job import SphereJob, SphereStage
from repro.core.records import RecordBatch
from repro.core.shuffle import reduce_partitioner
from repro.core.trace import NULL_TRACER
from repro.kernels.kmeans_assign import kmeans_assign_partials


# --------------------------- record codecs ---------------------------------

def encode_points(pts: np.ndarray) -> bytes:
    """float32 points [N, D] -> fixed-size records."""
    return pts.astype("<f4").tobytes()


def decode_points(blob: bytes, dim: int) -> np.ndarray:
    return np.frombuffer(blob, "<f4").reshape(-1, dim)


def _encode_partial(sums: np.ndarray, counts: np.ndarray) -> bytes:
    k, d = sums.shape
    return struct.pack("<II", k, d) + sums.astype("<f8").tobytes() + \
        counts.astype("<i8").tobytes()


def _decode_partial(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    k, d = struct.unpack("<II", blob[:8])
    off = 8
    sums = np.frombuffer(blob[off:off + 8 * k * d], "<f8").reshape(k, d)
    off += 8 * k * d
    counts = np.frombuffer(blob[off:off + 8 * k], "<i8")
    return sums.copy(), counts.copy()


# --------------------------- Sphere stages ---------------------------------
# Array-backend partial record: ONE row of 4*k*(dim+1) bytes holding
# float32 [k, dim+1] = per-centroid sums ++ counts.

def _partial_width(k: int, dim: int) -> int:
    return 4 * k * (dim + 1)


def _f32_rows(batch: RecordBatch) -> jax.Array:
    """Reinterpret a batch's rows as little-endian float32."""
    return jax.lax.bitcast_convert_type(
        batch.data.reshape(batch.num_records, -1, 4), jnp.float32)


def _f32_record(row: jax.Array) -> RecordBatch:
    """float32 [1, m] -> a one-record batch of 4*m bytes."""
    raw = jax.lax.bitcast_convert_type(row, jnp.uint8)
    return RecordBatch(raw.reshape(1, -1))


def make_kmeans_stages(dim: int, k: int, backend: str) -> List[SphereStage]:
    """The assign+fold stage pair, built ONCE per chain.  Feed each
    iteration's centroids through ``stages[0].params`` (array: a jnp
    [k, dim] array; bytes: a numpy array read by the closure) — the
    traced UDFs treat params as a dynamic argument, so updating them
    never retraces."""
    if backend == "array":
        def assign_masked(batch: RecordBatch, mask, c) -> RecordBatch:
            pts = _f32_rows(batch)                       # [n, dim]
            sums, counts = kmeans_assign_partials(pts, c, mask)
            row = jnp.concatenate([sums, counts[:, None]],
                                  axis=1).reshape(1, -1)
            return _f32_record(row)

        def fold_masked(batch: RecordBatch, mask, _params) -> RecordBatch:
            arr = _f32_rows(batch)                       # [n, k*(dim+1)]
            arr = arr * mask.astype(jnp.float32)[:, None]
            return _f32_record(arr.sum(0, keepdims=True))

        return [
            SphereStage("assign", masked_udf=assign_masked,
                        partitioner=reduce_partitioner()),
            SphereStage("fold", masked_udf=fold_masked),
        ]

    assign = SphereStage("assign", partitioner=reduce_partitioner())

    def assign_udf(records: List[bytes]) -> List[bytes]:
        c = np.asarray(assign.params)
        out = []
        for blob in records:
            pts = decode_points(blob, dim)
            d2 = ((pts[:, None, :] - c[None]) ** 2).sum(-1)
            a = d2.argmin(1)
            sums = np.zeros((k, dim))
            counts = np.zeros(k, np.int64)
            np.add.at(sums, a, pts)
            np.add.at(counts, a, 1)
            out.append(_encode_partial(sums, counts))
        return out

    def fold_udf(records: List[bytes]) -> List[bytes]:
        sums = np.zeros((k, dim))
        counts = np.zeros(k, np.int64)
        for r in records:
            s, n = _decode_partial(r)
            sums += s
            counts += n
        return [_encode_partial(sums, counts)]

    assign.udf = assign_udf
    return [assign, SphereStage("fold", fold_udf)]


def _fold_outputs(outputs: List[bytes], dim: int, k: int, backend: str
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(sums, counts) from a job's final blobs (normally one fold record;
    summing tolerates degenerate multi-bucket outputs)."""
    sums = np.zeros((k, dim))
    counts = np.zeros(k, np.float64)
    for blob in outputs:
        if backend == "array":
            arr = np.frombuffer(blob, "<f4").reshape(-1, k, dim + 1)
            sums += arr[..., :dim].sum(0)
            counts += arr[..., dim].sum(0)
        else:
            off = 0
            while off < len(blob):
                kk, dd = struct.unpack("<II", blob[off:off + 8])
                size = 8 + 8 * kk * dd + 8 * kk
                s, n = _decode_partial(blob[off:off + size])
                sums += s
                counts += n
                off += size
    return sums, counts


# --------------------------- driver ----------------------------------------

def kmeans_sphere(engine: SphereEngine, file: str, dim: int, k: int,
                  iters: int, seed: int = 0, backend: str = "bytes",
                  session: Union[bool, SphereSession, None] = True,
                  iter_seconds: Optional[List[float]] = None,
                  init: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, SphereReport]:
    """Run k-means over a Sector file of float32 points via Sphere.

    ``session=True`` (default) chains the iterations through one
    :class:`SphereSession` — one lookup, one stage-0 plan, chunks decoded
    once, each stage UDF traced once for the whole run; pass an existing
    session to share it.  ``session=False`` re-plans and re-traces every
    iteration through ``engine.run`` (the pre-session behaviour, kept as
    the benchmark comparison baseline).  ``iter_seconds``, when given a
    list, collects real per-iteration wall clock.  ``init`` warm-starts
    the centroids (overriding the seeded random init) — streaming
    windows warm-start from the previous window's model.
    """
    if init is not None:
        centroids = np.array(init, dtype=np.float32, copy=True)
        if centroids.shape != (k, dim):
            raise ValueError(f"init shape {centroids.shape} != {(k, dim)}")
    else:
        rng = np.random.default_rng(seed)
        centroids = rng.normal(size=(k, dim)).astype(np.float32)
    report = SphereReport()
    record_size = 4 * dim if backend == "array" else 0

    sess: Optional[SphereSession] = None
    own_session = False
    if isinstance(session, SphereSession):
        sess = session
    elif session:
        sess = engine.session(file, record_size=record_size, backend=backend)
        own_session = True  # close (unsubscribe) our throwaway session
    if sess is not None:
        stages = make_kmeans_stages(dim, k, backend)
        job = SphereJob("kmeans", file, stages, record_size=record_size,
                        backend=backend)

    try:
        tracer = getattr(engine, "tracer", None) or NULL_TRACER
        for it in range(iters):
            with tracer.span("kmeans-iter", track="control",
                             attrs={"iter": it, "k": k}) as sp:
                if sess is None:
                    # re-plan + re-trace path: fresh stages, fresh job,
                    # fresh planner/executor on every iteration
                    stages = make_kmeans_stages(dim, k, backend)
                    job = SphereJob("kmeans", file, stages,
                                    record_size=record_size, backend=backend)
                stages[0].params = (jnp.asarray(centroids)
                                    if backend == "array"
                                    else centroids.copy())
                if sess is not None:
                    outputs, report = sess.run(job, report)
                else:
                    outputs, report = engine.run(job, report)
                sums, counts = _fold_outputs(outputs, dim, k, backend)
                nz = counts > 0
                centroids[nz] = (sums[nz]
                                 / counts[nz, None]).astype(np.float32)
            if iter_seconds is not None:
                iter_seconds.append(sp.wall_seconds)
    finally:
        if own_session:
            sess.close()
    return centroids, report


# --------------------------- streaming driver -------------------------------

class StreamingKMeans:
    """Warm-started k-means over a :class:`SphereStream`'s window sequence
    (the continuous Angle workload: cluster every window of TCP-flow
    feature files as it forms).

    One stage pair and one :class:`SphereJob` serve every window: the
    centroids ride in ``stages[0].params`` as a dynamic jit argument, so
    the whole stream traces each stage exactly once
    (``report.udf_traces == 1``) no matter how many windows or
    iterations run.  Each window warm-starts from the previous window's
    centroids — consecutive windows share most of their traffic, so warm
    starts converge in fewer iterations than a cold random init, and the
    model sequence itself is the temporal signal Angle's anomaly
    detector consumes.

    Typical wiring (fit runs synchronously as each window forms)::

        stream = engine.stream("angle/window_", window=WindowPolicy.sliding(4),
                               record_size=4 * dim, backend="array")
        skm = StreamingKMeans(stream, dim, k, iters=4)
        stream.on_window(lambda s, i, files: models.append(skm.fit_window()))
    """

    def __init__(self, stream, dim: int, k: int, *, iters: int = 4,
                 seed: int = 0):
        self.stream = stream
        self.dim = dim
        self.k = k
        self.iters = iters
        self.seed = seed
        self.backend = stream.backend
        self.stages = make_kmeans_stages(dim, k, self.backend)
        self.job = SphereJob("kmeans-stream", stream.job_input_name,
                             self.stages, record_size=stream.record_size,
                             backend=self.backend)
        self.centroids: Optional[np.ndarray] = None
        self.report = SphereReport()
        self.windows_fit = 0

    def fit_window(self, iters: Optional[int] = None) -> np.ndarray:
        """Fit the stream's *current* window, warm-starting from the
        previous window's centroids (cold seeded init on the first call).
        Returns a copy of the fitted centroids; cumulative counters
        accrue in ``self.report``."""
        if self.centroids is None:
            rng = np.random.default_rng(self.seed)
            self.centroids = rng.normal(size=(self.k, self.dim)) \
                .astype(np.float32)
        for _ in range(self.iters if iters is None else iters):
            self.stages[0].params = (jnp.asarray(self.centroids)
                                     if self.backend == "array"
                                     else self.centroids.copy())
            outs, self.report = self.stream.run(self.job, self.report)
            sums, counts = _fold_outputs(outs, self.dim, self.k,
                                         self.backend)
            nz = counts > 0
            self.centroids[nz] = (sums[nz] / counts[nz, None]) \
                .astype(np.float32)
        self.windows_fit += 1
        return self.centroids.copy()


# --------------------------- JAX twin ---------------------------------------

def kmeans_step_jax(points: jax.Array, centroids: jax.Array,
                    mesh: Mesh | None = None, axis: str = "data"):
    """One k-means step. points [N, D] (sharded over axis when mesh given),
    centroids [K, D] replicated. Returns (new_centroids, inertia)."""

    def local(pts, c):
        d2 = (jnp.sum(pts**2, 1)[:, None] - 2 * pts @ c.T
              + jnp.sum(c**2, 1)[None])
        a = jnp.argmin(d2, 1)
        oh = jax.nn.one_hot(a, c.shape[0], dtype=pts.dtype)
        sums = oh.T @ pts
        counts = oh.sum(0)
        inertia = jnp.take_along_axis(d2, a[:, None], 1).sum()
        return sums, counts, inertia

    if mesh is None:
        sums, counts, inertia = local(points, centroids)
    else:
        def body(pts, c):
            s, n, i = local(pts, c)
            return (lax.psum(s, axis), lax.psum(n, axis),
                    lax.psum(i, axis))
        fn = _shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                        out_specs=(P(), P(), P()))
        sums, counts, inertia = fn(points, centroids)
    new_c = jnp.where(counts[:, None] > 0,
                      sums / jnp.maximum(counts[:, None], 1), centroids)
    return new_c, inertia
