"""Sector-backed token dataset with locality-aware chunk assignment.

The paper's storage/compute co-design applied to the input pipeline: token
chunks are *already placed* by Sector's consistent-hash ring; each
data-parallel rank is pinned to a site and reads, wherever possible, chunks
whose replicas live at its own site ("the data waits for the task", §1).
Cross-site reads fall back to the nearest replica over UDT and are accounted
in the client transfer log — benchmarks report the locality fraction.

Deterministic resume: iteration order is a seeded permutation of chunk ids;
the cursor (epoch, index) is part of the training checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster


@dataclass
class Cursor:
    epoch: int = 0
    index: int = 0  # chunk position within the epoch permutation
    batch: int = 0  # next batch within that chunk

    def as_dict(self) -> dict:
        return {"epoch": self.epoch, "index": self.index,
                "batch": self.batch}

    @staticmethod
    def from_dict(d: dict) -> "Cursor":
        return Cursor(int(d["epoch"]), int(d["index"]),
                      int(d.get("batch", 0)))


class SectorTokenDataset:
    def __init__(self, master: SectorMaster, client: SectorClient,
                 file: str, seq_len: int, seed: int = 0,
                 rank: int = 0, world: int = 1,
                 rank_site: Optional[str] = None):
        self.master = master
        self.client = client
        self.file = file
        self.seq_len = seq_len
        self.seed = seed
        self.rank = rank
        self.world = world
        self.rank_site = rank_site or client.site
        self.metas = master.lookup(file, client.user, self.rank_site)
        self.local_reads = 0
        self.remote_reads = 0

    # ----------------------------------------------------------- assignment
    def _epoch_order(self, epoch: int) -> List[int]:
        rng = np.random.default_rng(self.seed + epoch)
        return list(rng.permutation(len(self.metas)))

    def _my_chunks(self, epoch: int) -> List[int]:
        """Locality-aware rank assignment: ranks claim chunks whose nearest
        replica is closest to their site, round-robin for balance."""
        order = self._epoch_order(epoch)
        scored = []
        for ci in order:
            meta = self.metas[ci]
            best = min(
                (self.master.topology.distance(
                    self.rank_site, self.master.servers[s].site)
                 for s in meta.locations if s in self.master.servers),
                default=1e9)
            scored.append((ci, best))
        # stable partition: chunk i goes to rank (position % world), but
        # within each distance class nearer chunks are claimed first
        mine = [ci for pos, (ci, _) in enumerate(scored)
                if pos % self.world == self.rank]
        return mine

    # -------------------------------------------------------------- batches
    def batches(self, batch: int, cursor: Cursor
                ) -> Iterator[Tuple[Dict[str, np.ndarray], Cursor]]:
        """Yields ({inputs, labels}, next_cursor); infinite over epochs.

        Batches never straddle chunks (each chunk's sub-``need`` tail is
        dropped), so the (epoch, chunk, batch) cursor makes resume exactly
        deterministic: a crash+restore run replays the identical stream."""
        need = batch * (self.seq_len + 1)
        epoch, idx, bstart = cursor.epoch, cursor.index, cursor.batch
        while True:
            mine = self._my_chunks(epoch)
            while idx < len(mine):
                meta = self.metas[mine[idx]]
                site_of = {s: self.master.servers[s].site
                           for s in meta.locations
                           if s in self.master.servers}
                blob = self.client.read_chunk(meta.chunk_id)
                if any(st == self.rank_site for st in site_of.values()):
                    self.local_reads += 1
                else:
                    self.remote_reads += 1
                toks = np.frombuffer(blob, np.uint32)
                nb = len(toks) // need
                for j in range(bstart, nb):
                    take = toks[j * need:(j + 1) * need] \
                        .reshape(batch, self.seq_len + 1)
                    nxt = Cursor(epoch, idx, j + 1) if j + 1 < nb \
                        else Cursor(epoch, idx + 1, 0)
                    yield ({"inputs": take[:, :-1].astype(np.int32),
                            "labels": take[:, 1:].astype(np.int32)}, nxt)
                bstart = 0
                idx += 1
            epoch, idx = epoch + 1, 0

    @property
    def locality_fraction(self) -> float:
        tot = self.local_reads + self.remote_reads
        return self.local_reads / tot if tot else 1.0
