"""Synthetic token corpora written into Sector.

Deterministic zipfian token streams with planted n-gram structure (so a
~100M-param model trained for a few hundred steps shows a real loss drop,
not just noise).
"""
from __future__ import annotations

import numpy as np

from repro.sector.client import SectorClient


def synthetic_tokens(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # zipfian unigrams
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=n_tokens, p=probs).astype(np.uint32)
    # plant deterministic bigram structure: after token t comes (t*7+3)%vocab
    # with 50% probability — gives the model something learnable.
    follow = (np.arange(vocab, dtype=np.uint64) * 7 + 3) % vocab
    mask = rng.random(n_tokens) < 0.5
    toks[1:][mask[1:]] = follow[toks[:-1][mask[1:]]].astype(np.uint32)
    return toks


def write_synthetic_corpus(client: SectorClient, name: str, n_tokens: int,
                           vocab: int, seed: int = 0,
                           replication: int = 2) -> int:
    toks = synthetic_tokens(n_tokens, vocab, seed)
    client.upload(name, toks.tobytes(), replication=replication)
    return n_tokens
