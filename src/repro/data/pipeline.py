"""Device feed: batches -> sharded jax arrays, with simple lookahead.

On a real multi-host job each host feeds its local shard
(``jax.make_array_from_process_local_data``); on this single-process harness
we place the global batch with the mesh sharding directly. Prefetch depth 2
overlaps host-side chunk reads with device steps.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.data.dataset import Cursor, SectorTokenDataset
from repro.parallel.sharding import ParallelConfig, batch_spec


class DataPipeline:
    def __init__(self, dataset: SectorTokenDataset, batch: int,
                 pcfg: ParallelConfig, prefetch: int = 2):
        self.dataset = dataset
        self.batch = batch
        self.pcfg = pcfg
        self.prefetch = prefetch
        self.cursor = Cursor()

    def _place(self, host_batch: dict) -> dict:
        if self.pcfg.mesh is None:
            return {k: jnp.asarray(v) for k, v in host_batch.items()}
        sh = NamedSharding(self.pcfg.mesh,
                           batch_spec(self.pcfg, None))
        return {k: jax.device_put(v, sh) for k, v in host_batch.items()}

    def __iter__(self) -> Iterator[dict]:
        gen = self.dataset.batches(self.batch, self.cursor)
        queue: deque = deque()
        while True:
            while len(queue) < self.prefetch:
                host, cur = next(gen)
                queue.append((self._place(host), cur))
            placed, cur = queue.popleft()
            self.cursor = cur
            yield placed

    # resume support
    def state_dict(self) -> dict:
        return self.cursor.as_dict()

    def load_state_dict(self, d: dict) -> None:
        self.cursor = Cursor.from_dict(d)
