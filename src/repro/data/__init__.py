from repro.data.dataset import SectorTokenDataset  # noqa: F401
from repro.data.pipeline import DataPipeline  # noqa: F401
from repro.data.synth import write_synthetic_corpus  # noqa: F401
