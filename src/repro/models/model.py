"""Unified model API over all architecture families.

    param_shapes(cfg)               -> ShapeDtypeStruct tree
    init_params(cfg, rng)           -> concrete params
    loss_fn(params, batch, ...)     -> (loss, metrics)     [training]
    prefill(params, batch, ...)     -> (last_logits, cache)
    decode_step(params, cache, token, pos, ...) -> (logits, cache)
    init_cache(cfg, batch, seq)     -> zeroed decode cache
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, transformer
from repro.models.losses import cross_entropy
from repro.parallel.sharding import ParallelConfig, NO_PARALLEL


def param_shapes(cfg: ModelConfig):
    return transformer.shapes(cfg)


def init_params(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(transformer.shapes(cfg), rng)


def cache_shapes(cfg: ModelConfig, batch: int, seq: int, *, cross_len: int = 0):
    return transformer.cache_shapes(cfg, batch, seq, cross_len=cross_len)


def init_cache(cfg: ModelConfig, batch: int, seq: int, *, cross_len: int = 0):
    return transformer.init_cache(cfg, batch, seq, cross_len=cross_len)


def _encode(params, frames, *, cfg, pcfg):
    x = transformer.project_frames(params, frames, cfg=cfg, pcfg=pcfg)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    enc = params["encoder"]
    n_enc_groups = cfg.n_enc_layers // cfg.pattern_len
    x, _, _ = transformer.stack_apply(
        enc["blocks"], x, cfg=cfg, pcfg=pcfg, positions=pos, mode="encode",
        n_groups=n_enc_groups)
    return common.rms_norm(x, enc["final_norm"]["scale"], cfg.norm_eps)


def _backbone(params, batch: dict, *, cfg: ModelConfig,
              pcfg: ParallelConfig, mode: str):
    """Embed + frontends + stack. Returns (pre-head hiddens, aux)."""
    tokens = batch["inputs"]
    x = transformer.embed(params, tokens, cfg=cfg, pcfg=pcfg)
    if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
        x = transformer.splice_patches(params, x, batch["patch_embeds"],
                                       batch["patch_pos"], cfg=cfg, pcfg=pcfg)
    memory = None
    if cfg.is_encoder_decoder:
        memory = _encode(params, batch["enc_frames"], cfg=cfg, pcfg=pcfg)
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
    x, _, aux = transformer.stack_apply(
        params["blocks"], x, cfg=cfg, pcfg=pcfg, positions=pos, mode=mode,
        memory=memory)
    return x, aux


def forward(params, batch: dict, *, cfg: ModelConfig,
            pcfg: ParallelConfig = NO_PARALLEL, mode: str = "train"):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x, aux = _backbone(params, batch, cfg=cfg, pcfg=pcfg, mode=mode)
    logits = transformer.lm_logits(params, x, cfg=cfg, pcfg=pcfg)
    return logits, aux


def loss_fn(params, batch: dict, *, cfg: ModelConfig,
            pcfg: ParallelConfig = NO_PARALLEL):
    if pcfg.fused_head and not cfg.logit_softcap:
        from repro.models import common
        from repro.models.losses import fused_cross_entropy
        x, aux = _backbone(params, batch, cfg=cfg, pcfg=pcfg, mode="train")
        x = common.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        tied = cfg.tie_embeddings
        w = params["embed"]["w"] if tied else params["lm_head"]["w"]
        loss, metrics = fused_cross_entropy(
            x, w, batch["labels"], real_vocab=cfg.vocab_size,
            transpose_w=tied, chunk=pcfg.head_chunk,
            unroll=pcfg.unroll_scans)
    else:
        logits, aux = forward(params, batch, cfg=cfg, pcfg=pcfg,
                              mode="train")
        loss, metrics = cross_entropy(logits, batch["labels"],
                                      real_vocab=cfg.vocab_size)
    metrics["aux_loss"] = aux
    return loss + aux, metrics


def prefill(params, batch: dict, *, cfg: ModelConfig,
            pcfg: ParallelConfig = NO_PARALLEL, max_len: int = 0):
    """Run the prompt, build the decode cache (capacity ``max_len``).

    Returns (last_logits, cache)."""
    tokens = batch["inputs"]
    B, S = tokens.shape
    max_len = max_len or S
    x = transformer.embed(params, tokens, cfg=cfg, pcfg=pcfg)
    if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
        x = transformer.splice_patches(params, x, batch["patch_embeds"],
                                       batch["patch_pos"], cfg=cfg, pcfg=pcfg)
    memory = None
    if cfg.is_encoder_decoder:
        memory = _encode(params, batch["enc_frames"], cfg=cfg, pcfg=pcfg)
    pos = jnp.broadcast_to(jnp.arange(S)[None], tokens.shape)
    x, new_caches, _ = transformer.stack_apply(
        params["blocks"], x, cfg=cfg, pcfg=pcfg, positions=pos,
        mode="prefill", memory=memory, max_len=max_len)
    logits = transformer.lm_logits(params, x[:, -1:, :], cfg=cfg, pcfg=pcfg)
    return logits[:, 0], new_caches


def decode_step(params, cache, token, pos, *, cfg: ModelConfig,
                pcfg: ParallelConfig = NO_PARALLEL):
    """One decode step. token: [B,1] int32; pos: [B] int32.

    Returns (logits [B, Vp], new_cache).
    """
    x = transformer.embed(params, token, cfg=cfg, pcfg=pcfg)
    positions = pos[:, None]
    x, new_caches, _ = transformer.stack_apply(
        params["blocks"], x, cfg=cfg, pcfg=pcfg, positions=positions,
        mode="decode", caches=cache)
    logits = transformer.lm_logits(params, x, cfg=cfg, pcfg=pcfg)
    return logits[:, 0], new_caches
