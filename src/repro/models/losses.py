"""Cross-entropy (fp32 softmax, vocab-padding masked) + z-loss.

Two formulations:

  * ``cross_entropy`` — takes materialised logits [B,T,Vp]. Simple, but the
    fp32 softmax state makes the logits tensor the single largest activation
    of a training step (e.g. gemma3 train_4k: 1M x 262k).
  * ``fused_cross_entropy`` — takes the final hidden states and the head
    weights, computing logits chunk-by-chunk over tokens inside a
    checkpointed loop; backward recomputes each chunk's logits. Peak memory
    drops from O(T*V) to O(chunk*V) (a §Perf memory-term iteration).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array, *, real_vocab: int,
                  z_loss_coef: float = 1e-4):
    """logits: [B,T,Vp]; labels: [B,T] int32 (-1 = ignore).

    Returns (loss, metrics dict). Softmax in fp32; padded vocab rows masked.
    """
    vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if real_vocab < vp:
        pad_mask = jnp.arange(vp) >= real_vocab
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)  # [B,T]
    gold = jnp.take_along_axis(
        lf, jnp.clip(labels, 0, real_vocab - 1)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    valid = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1.0)
    loss = (nll * valid).sum() / denom
    z = ((lse**2) * valid).sum() / denom
    total = loss + z_loss_coef * z
    acc = ((jnp.argmax(lf, -1) == labels).astype(jnp.float32) * valid
           ).sum() / denom
    return total, {"nll": loss, "z_loss": z, "accuracy": acc,
                   "tokens": valid.sum()}


def _chunk_stats(x_c, labels_c, w, b_or_none, *, real_vocab: int,
                 transpose_w: bool):
    """Per-chunk (nll_sum, z_sum, acc_sum, valid_sum). x_c: [B,c,D]."""
    logits = jnp.einsum("bcd,vd->bcv", x_c, w) if transpose_w \
        else jnp.einsum("bcd,dv->bcv", x_c, w)
    lf = logits.astype(jnp.float32)
    vp = lf.shape[-1]
    if real_vocab < vp:
        lf = jnp.where(jnp.arange(vp) >= real_vocab, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.clip(labels_c, 0, real_vocab - 1)[..., None], axis=-1)[..., 0]
    valid = (labels_c >= 0).astype(jnp.float32)
    nll = ((lse - gold) * valid).sum()
    z = ((lse**2) * valid).sum()
    acc = ((jnp.argmax(lf, -1) == labels_c).astype(jnp.float32)
           * valid).sum()
    return nll, z, acc, valid.sum()


def fused_cross_entropy(x, w, labels, *, real_vocab: int,
                        transpose_w: bool, chunk: int = 512,
                        z_loss_coef: float = 1e-4, unroll: bool = False):
    """x: [B,T,D] final hiddens; w: head weights ([D,Vp] or [Vp,D] when
    ``transpose_w``, i.e. tied embeddings); labels: [B,T]."""
    B, T, D = x.shape
    c = min(chunk, T)
    while T % c:
        c //= 2
    nc = T // c
    stats_fn = jax.checkpoint(
        partial(_chunk_stats, real_vocab=real_vocab,
                transpose_w=transpose_w))

    if unroll:
        parts = [stats_fn(x[:, i * c:(i + 1) * c],
                          labels[:, i * c:(i + 1) * c], w, None)
                 for i in range(nc)]
        nll, z, acc, n = (sum(p[i] for p in parts) for i in range(4))
    else:
        xr = x.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
        lr = labels.reshape(B, nc, c).transpose(1, 0, 2)

        def body(carry, xs):
            x_c, l_c = xs
            out = stats_fn(x_c, l_c, w, None)
            return tuple(a + b for a, b in zip(carry, out)), None

        zero = jnp.zeros((), jnp.float32)
        (nll, z, acc, n), _ = jax.lax.scan(body, (zero,) * 4, (xr, lr))

    denom = jnp.maximum(n, 1.0)
    loss = nll / denom
    zl = z / denom
    return loss + z_loss_coef * zl, {"nll": loss, "z_loss": zl,
                                     "accuracy": acc / denom, "tokens": n}
