"""Griffin/RecurrentGemma recurrent block: conv1d + RG-LRU with gated branch.

RG-LRU (Real-Gated Linear Recurrent Unit):

    r_t = sigmoid(gate_a(x_t))            recurrence gate (block-diag linear)
    i_t = sigmoid(gate_x(x_t))            input gate
    log a_t = -c * softplus(a_param) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear and diagonal, so training parallelises over T with
``jax.lax.associative_scan`` ((a, b) pair composition); decode is an O(1)
step with carried state. The full residual block is Griffin's:

    y = W_out( RG-LRU(conv1d(W_x x)) * gelu(W_g x) )
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import block_diag_apply, block_diag_shapes, sds

RGLRU_C = 8.0
N_GATE_BLOCKS = 8


def shapes(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "in_x": sds((d, w), pd),
        "in_g": sds((d, w), pd),
        "conv_w": sds((cfg.conv1d_width, w), pd),
        "gate_a": block_diag_shapes(N_GATE_BLOCKS, w, w // N_GATE_BLOCKS, pd),
        "gate_x": block_diag_shapes(N_GATE_BLOCKS, w, w // N_GATE_BLOCKS, pd),
        "a_param": sds((w,), jnp.float32),
        "out": sds((w, d), pd),
    }


def state_shapes(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": sds((batch, w), jnp.float32),
        "conv": sds((batch, cfg.conv1d_width - 1, w), cfg.compute_dtype),
    }


def _combine(lhs, rhs):
    a1, b1 = lhs
    a2, b2 = rhs
    return a1 * a2, a2 * b1 + b2


def _assoc_segment(a, b, h0):
    """Associative scan over one segment, seeded with h0. Returns (h, h_T)."""
    a_full = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_full = jnp.concatenate([h0[:, None], b], axis=1)
    _, h = lax.associative_scan(_combine, (a_full, b_full), axis=1)
    return h[:, 1:], h[:, -1]


def _lru(p, x, h0, *, chunk: int = 0, unroll: bool = False):
    """x: [B,T,W] (post-conv); h0: [B,W] fp32. Returns (y [B,T,W], h_T).

    ``chunk > 0`` bounds the associative scan's O(T log T) fp32 intermediate
    tree to O(chunk log chunk) by scanning chunk-to-chunk with a carried
    state (a §Perf memory-term iteration); the math is exact either way.
    """
    B, T, W = x.shape
    r = jax.nn.sigmoid(block_diag_apply(p["gate_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(block_diag_apply(p["gate_x"], x).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["a_param"]) * r   # [B,T,W]
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if not chunk or T <= chunk:
        h, h_t = _assoc_segment(a, b, h0)
        return h.astype(x.dtype), h_t

    L = chunk
    while T % L:
        L //= 2
    nc = T // L
    ar = a.reshape(B, nc, L, W).transpose(1, 0, 2, 3)
    br = b.reshape(B, nc, L, W).transpose(1, 0, 2, 3)

    if unroll:
        hs, h_c = [], h0
        for ci in range(nc):
            h, h_c = _assoc_segment(ar[ci], br[ci], h_c)
            hs.append(h)
        h = jnp.stack(hs, 0)
    else:
        def body(h_c, ab):
            h, h_c = _assoc_segment(ab[0], ab[1], h_c)
            return h_c, h

        h_c, h = lax.scan(body, h0, (ar, br))
    h = h.transpose(1, 0, 2, 3).reshape(B, T, W)
    return h.astype(x.dtype), h_c


def apply(p, x, *, cfg: ModelConfig, state=None, chunk: int = 0,
          unroll: bool = False):
    """Full Griffin recurrent block. x: [B,T,d] -> (out, new_state | None)."""
    B, T, d = x.shape
    w = cfg.lru_width or d
    branch = x @ p["in_x"]
    gate = jax.nn.gelu((x @ p["in_g"]).astype(jnp.float32),
                       approximate=True).astype(x.dtype)
    if state is None:
        xc = common.causal_conv1d(branch, p["conv_w"])
        h0 = jnp.zeros((B, w), jnp.float32)
        y, _ = _lru(p, xc, h0, chunk=chunk, unroll=unroll)
        return (y * gate) @ p["out"], None
    xc, new_conv = common.causal_conv1d(branch, p["conv_w"], state["conv"])
    y, h_t = _lru(p, xc, state["h"], chunk=chunk, unroll=unroll)
    out = (y * gate) @ p["out"]
    return out, {"h": h_t, "conv": new_conv}
