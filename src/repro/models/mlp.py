"""Gated FFN (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.common import activation, sds
from repro.parallel.sharding import ParallelConfig, batch_spec, constrain



def shapes(cfg: ModelConfig, width: int | None = None) -> dict:
    pd = cfg.param_dtype
    f = width or cfg.d_ff
    return {
        "wi": sds((cfg.d_model, f), pd),
        "wg": sds((cfg.d_model, f), pd),
        "wo": sds((f, cfg.d_model), pd),
    }


def apply(params: dict, x: jax.Array, *, cfg: ModelConfig,
          pcfg: ParallelConfig) -> jax.Array:
    act = activation(cfg.act)
    h = act(x @ params["wg"]) * (x @ params["wi"])
    h = constrain(h, pcfg, batch_spec(pcfg, None, "model"))
    return h @ params["wo"]
