"""Shared model primitives: RMSNorm, RoPE, activations, param materialization.

Every sub-module exposes ``shapes(cfg) -> nested dict of ShapeDtypeStruct``;
``materialize(shapes, rng)`` turns that into real arrays (fan-in scaled normal
init) and is the ONLY place parameters are allocated, so abstract (dry-run)
and concrete (smoke/train) paths share one source of truth.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import tree_flatten_with_paths


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Parameter materialization
# ---------------------------------------------------------------------------

def _init_leaf(path: str, spec: jax.ShapeDtypeStruct, rng: jax.Array) -> jax.Array:
    """Fan-in-scaled normal init; norms/scales init to 1, biases/gates to 0."""
    name = path.rsplit("/", 1)[-1]
    shape, dtype = spec.shape, spec.dtype
    if name in ("scale",) or name.endswith("_norm"):
        return jnp.ones(shape, dtype)
    if name.startswith("b") or name in ("bias",) or name.endswith("_bias"):
        return jnp.zeros(shape, dtype)
    if name == "a_param":  # RG-LRU recurrence parameter (see rglru.py)
        # initialised so that a = exp(-8*sigmoid(a_param)) spans ~(0.9, 0.999)
        u = jax.random.uniform(rng, shape, jnp.float32, 0.9, 0.999)
        inner = jnp.clip(-jnp.log(u) / 8.0, 1e-6, 1 - 1e-6)
        return jnp.log(inner / (1 - inner)).astype(dtype)
    if len(shape) == 0:
        return jnp.zeros(shape, dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def materialize(shape_tree, rng: jax.Array):
    """Instantiate a tree of ShapeDtypeStructs into arrays.

    The per-leaf rng folds in a *stable* hash of the leaf path (crc32 —
    Python's ``hash`` is process-salted and would make init
    non-reproducible across restarts/hosts)."""
    import zlib

    flat = tree_flatten_with_paths(shape_tree)
    leaves = []
    for path, spec in flat:
        key = jax.random.fold_in(rng, zlib.crc32(path.encode()) % (2**31))
        leaves.append(_init_leaf(path, spec, key))
    treedef = jax.tree.structure(shape_tree)
    return jax.tree.unflatten(treedef, leaves)


def abstract(shape_tree):
    """Identity — shapes ARE the abstract params (ShapeDtypeStructs)."""
    return shape_tree


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def activation(name: str):
    if name in ("silu", "swish"):
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def soft_cap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    exponent = np.arange(0, d_head, 2, dtype=np.float32) / d_head
    return jnp.asarray(1.0 / (theta**exponent))  # [d_head/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, d/2]
    angles = angles[..., None, :]  # [..., T, 1, d/2] broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (recurrent blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: [B, T, C]; w: [W, C].

    When ``state`` ([B, W-1, C], trailing context) is given, runs in streaming
    mode and returns (y, new_state); otherwise zero-pads on the left.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (width - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)  # [B, T+W-1, C]
    y = sum(
        xp[..., i : i + x.shape[-2], :] * w[i][None, None, :] for i in range(width)
    )
    if state is None:
        return y.astype(x.dtype)
    new_state = xp[..., -(width - 1) :, :] if width > 1 else state
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Block-diagonal linear (xLSTM qkv, RG-LRU gates)
# ---------------------------------------------------------------------------

def block_diag_shapes(n_blocks: int, dim: int, out_per_block: int, dtype) -> Dict:
    assert dim % n_blocks == 0, (dim, n_blocks)
    return {"w": sds((n_blocks, dim // n_blocks, out_per_block), dtype)}


def block_diag_apply(params, x: jax.Array) -> jax.Array:
    """x: [..., dim] -> [..., n_blocks * out_per_block]."""
    nb, ib, ob = params["w"].shape
    xs = x.reshape(x.shape[:-1] + (nb, ib))
    y = jnp.einsum("...ni,nio->...no", xs, params["w"])
    return y.reshape(x.shape[:-1] + (nb * ob,))
