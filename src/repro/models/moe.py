"""Mixture-of-Experts FFN with two dispatch formulations.

The MoE layer is where the paper's technique lives *inside* the model: the
token->expert dispatch/combine is exactly a Sphere shuffle (data moves to the
UDF's home node, is processed, and is shuffled back). Expert parallelism maps
experts onto the ``model`` mesh axis — never across ``pod`` — so the shuffle
stays on intra-pod ICI, honouring the wide-area design rule.

Two dispatch modes (``ParallelConfig.moe_dispatch``):

  * ``einsum`` — GShard-style dense one-hot dispatch/combine einsums with a
    capacity factor. Paper-faithful baseline: the shuffle is a literal dense
    "transport matrix". Costs ~2*E*C*d extra MACs per token.
  * ``gather`` — index-based dispatch (gather) + scatter-add combine. Same
    routing and capacity semantics, no one-hot FLOPs (a §Perf iteration).

Both share routing: top-k softmax gates, position-in-expert via cumsum,
tokens past capacity dropped (gate renormalised over surviving slots).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import activation, sds
from repro.parallel.sharding import ParallelConfig, constrain
from repro.utils.jax_compat import shard_map_partial

CAPACITY_FACTOR = 1.25
GROUP_SIZE = 4096  # tokens per dispatch group (GShard-style)


def shapes(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "router": sds((d, e), jnp.float32),
        "wi": sds((e, d, f), pd),
        "wg": sds((e, d, f), pd),
        "wo": sds((e, f, d), pd),
    }


def capacity(group: int, cfg: ModelConfig) -> int:
    c = int(group * cfg.top_k / cfg.n_experts * CAPACITY_FACTOR)
    return max(8, ((c + 7) // 8) * 8)


def _positions_by_sort(flat: jax.Array) -> jax.Array:
    """Rank of each slot within its expert's run (first-come order).

    flat: [G, n] expert ids. O(n log n) via stable sort — crucially no
    [n, E] one-hot tensor: the cumsum formulation materialises
    G x (k*S) x E int32 (terabytes at production scale) and dominated the
    baseline MoE collective/memory terms."""
    G, n = flat.shape
    order = jnp.argsort(flat, axis=-1, stable=True)      # groups by expert
    se = jnp.take_along_axis(flat, order, -1)
    idx = jnp.broadcast_to(jnp.arange(n)[None], (G, n))
    newrun = jnp.concatenate(
        [jnp.ones((G, 1), bool), se[:, 1:] != se[:, :-1]], axis=-1)
    run_start = jax.lax.cummax(jnp.where(newrun, idx, 0), axis=1)
    rank = idx - run_start                               # pos within run
    pos = jnp.zeros_like(rank)
    pos = pos.at[jnp.arange(G)[:, None], order].set(rank)
    return pos


def _route(params, xg, cfg: ModelConfig):
    """xg: [G, S, d] -> gates [G,S,k], eids [G,S,k], pos-in-expert [G,S,k],
    aux load-balance loss."""
    logits = (xg.astype(jnp.float32) @ params["router"])  # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.top_k)  # [G,S,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # position-in-expert over slots in priority order (all k=0 slots first)
    G, S, k = eids.shape
    E = cfg.n_experts
    flat = eids.transpose(0, 2, 1).reshape(G, k * S)
    pos_flat = _positions_by_sort(flat)
    pos = pos_flat.reshape(G, k, S).transpose(0, 2, 1)  # [G,S,k]

    # aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    top1 = jax.nn.one_hot(eids[..., 0], E, dtype=jnp.float32)
    frac_tokens = top1.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef
    return gates, eids, pos, aux


def _ep_spec(pcfg: ParallelConfig) -> P:
    """[G, E, C, d] layout for expert compute: groups over the non-model
    batch axes, experts over ``model``. Moving the model-shard of G into E
    is exactly the Sphere shuffle (an all-to-all on ICI)."""
    b = tuple(a for a in pcfg.data_axes if a != "model")
    b_entry = b if len(b) > 1 else (b[0] if b else None)
    return P(b_entry, "model", None, None)


def _expert_ffn(params, xe, cfg: ModelConfig, pcfg: ParallelConfig):
    """xe: [G, E, C, d] -> [G, E, C, d]; experts sharded over ``model``."""
    act = activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", xe, params["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xe, params["wi"])
    h = constrain(h, pcfg, _ep_spec(pcfg))
    return jnp.einsum("gecf,efd->gecd", h, params["wo"])


def apply(params: dict, x: jax.Array, *, cfg: ModelConfig,
          pcfg: ParallelConfig):
    """x: [B, T, d] -> (out [B, T, d], aux_loss scalar)."""
    if pcfg.moe_dispatch == "a2a":
        if pcfg.mesh is not None and pcfg.layout == "fsdp" \
                and pcfg.model_size > 1 and cfg.n_experts % pcfg.model_size \
                == 0:
            return _apply_a2a(params, x, cfg=cfg, pcfg=pcfg)
        pcfg = pcfg.with_(moe_dispatch="gather")  # meshless/TP fallback
    B, T, d = x.shape
    total = B * T
    group = min(GROUP_SIZE, total)
    while total % group:
        group //= 2
    G = total // group
    xg = x.reshape(G, group, d)
    gates, eids, pos, aux = _route(params, xg, cfg)
    C = capacity(group, cfg)
    keep = pos < C  # overflow tokens dropped
    gates = jnp.where(keep, gates, 0.0)
    # renormalise over surviving slots
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if pcfg.moe_dispatch == "einsum":
        out = _apply_einsum(params, xg, gates, eids, pos, C, cfg, pcfg)
    elif pcfg.moe_dispatch == "gather":
        out = _apply_gather(params, xg, gates, eids, pos, keep, C, cfg, pcfg)
    else:
        raise ValueError(pcfg.moe_dispatch)
    return out.reshape(B, T, d).astype(x.dtype), aux


def _apply_a2a(params, x, *, cfg: ModelConfig, pcfg: ParallelConfig):
    """Explicit Sphere-shuffle dispatch: shard_map + lax.all_to_all.

    Each device routes its LOCAL tokens, packs per-(peer, local-expert)
    fixed-capacity slot buffers, exchanges them with one all_to_all over the
    ``model`` axis (experts live on model shards; expert weights are
    FSDP-gathered over the data axes at region entry), computes the expert
    FFN locally, reverses the all_to_all and combines locally. The only
    cross-device traffic is 2 x [M, E_loc, cap, d] per layer — the
    hand-written equivalent of the paper's UDT shuffle, ~50x less traffic
    than what the SPMD partitioner derives for the gather/einsum
    formulations at this scale (see EXPERIMENTS.md §Perf).

    Requires tokens sharded over data axes + model (layout="fsdp").
    Returns (out [B,T,d], aux).
    """
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    M = pcfg.model_size
    E_loc = E // M
    axes = pcfg.data_axes  # includes "model" under fsdp
    n_total = B * T
    n_shards = 1
    for a in axes:
        n_shards *= pcfg.axis_sizes.get(a, 1)
    n_loc = n_total // n_shards
    cap = max(8, -(-int(n_loc * k * CAPACITY_FACTOR / E) // 8) * 8)
    act = activation(cfg.act)

    def body(router, wg, wi, wo, x_loc):
        x_loc = x_loc.reshape(n_loc, d)
        logits = x_loc.astype(jnp.float32) @ router            # [n, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_e = eids.reshape(1, n_loc * k)
        pos = _positions_by_sort(flat_e)[0]                    # [n*k]
        flat_e = flat_e[0]
        keep = pos < cap
        tok = jnp.repeat(jnp.arange(n_loc), k)
        dest_m = flat_e // E_loc
        dest_e = flat_e % E_loc
        p_clip = jnp.where(keep, pos, cap)                     # OOB drops

        send = jnp.zeros((M, E_loc, cap, d), x_loc.dtype)
        send = send.at[dest_m, dest_e, p_clip].set(
            x_loc[tok], mode="drop")
        recv = lax.all_to_all(send, "model", 0, 0, tiled=True)

        xe = recv.transpose(1, 0, 2, 3).reshape(E_loc, M * cap, d)
        h = act(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wi)
        ye = jnp.einsum("ecf,efd->ecd", h, wo)

        back = ye.reshape(E_loc, M, cap, d).transpose(1, 0, 2, 3)
        ret = lax.all_to_all(back, "model", 0, 0, tiled=True)

        w = (gates.reshape(n_loc * k) * keep).astype(ret.dtype)
        contrib = ret[dest_m, dest_e, p_clip] * w[:, None]
        out = jnp.zeros((n_loc, d), ret.dtype)
        out = out.at[tok].add(jnp.where(keep[:, None], contrib, 0))

        # aux loss partials (summed over shards outside)
        top1 = jax.nn.one_hot(eids[..., 0], E, dtype=jnp.float32)
        aux_part = jnp.stack([top1.sum(0), probs.sum(0)])      # [2, E]
        return out.reshape(1, n_loc, d), aux_part[None]

    manual = frozenset(a for a in axes if a in pcfg.axis_sizes) | {"model"}
    b_entry = axes if len(axes) > 1 else axes[0]
    fn = shard_map_partial(
        body, mesh=pcfg.mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(b_entry, None)),
        out_specs=(P(b_entry, None, None), P(b_entry, None, None)),
        manual_axes=manual)
    xt = x.reshape(n_total, d)
    out, aux_parts = fn(params["router"], params["wg"], params["wi"],
                        params["wo"], xt)
    out = out.reshape(B, T, d).astype(x.dtype)
    totals = aux_parts.sum(0)                                  # [2, E]
    frac_tok = totals[0] / jnp.maximum(totals[0].sum(), 1.0)
    mean_prob = totals[1] / jnp.maximum(n_total, 1)
    aux = E * jnp.sum(frac_tok * mean_prob) * cfg.router_aux_coef
    return out, aux


def _apply_einsum(params, xg, gates, eids, pos, C, cfg, pcfg):
    """GShard dense one-hot dispatch/combine (faithful baseline)."""
    E = cfg.n_experts
    # combine tensor [G,S,E,C] = gate on (expert, slot) pairs
    eh = jax.nn.one_hot(eids, E, dtype=xg.dtype)           # [G,S,k,E]
    ph = jax.nn.one_hot(pos, C, dtype=xg.dtype)            # [G,S,k,C]
    combine = jnp.einsum("gske,gskc,gsk->gsec", eh, ph,
                         gates.astype(xg.dtype))           # [G,S,E,C]
    dispatch = (combine > 0).astype(xg.dtype)
    xe = jnp.einsum("gsd,gsec->gecd", xg, dispatch)        # the shuffle out
    xe = constrain(xe, pcfg, _ep_spec(pcfg))
    ye = _expert_ffn(params, xe, cfg, pcfg)
    out = jnp.einsum("gecd,gsec->gsd", ye, combine)        # the shuffle back
    return out


def _apply_gather(params, xg, gates, eids, pos, keep, C, cfg, pcfg):
    """Index-based dispatch: gather tokens into [G,E,C,d], scatter-add back.

    Comm pattern matches the einsum mode (dispatch local on the model axis,
    combine = local scatter-add + all-reduce over ``model``) but spends no
    FLOPs on one-hot transport matrices.
    """
    G, S, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    tok = jnp.broadcast_to(jnp.arange(S)[None, :, None], eids.shape)  # [G,S,k]

    flat_e = eids.reshape(G, S * k)
    flat_p = jnp.where(keep, pos, C).reshape(G, S * k)  # dropped -> OOB slot
    flat_t = tok.reshape(G, S * k)
    flat_keep = keep.reshape(G, S * k)
    gidx = jnp.arange(G)[:, None]

    # dispatch table [G,E,C]: source token index for slot (e,c); OOB writes drop
    table = jnp.zeros((G, E, C), jnp.int32)
    table = table.at[gidx, flat_e, flat_p].set(flat_t, mode="drop")
    filled = jnp.zeros((G, E, C), jnp.bool_)
    filled = filled.at[gidx, flat_e, flat_p].set(flat_keep, mode="drop")
    # per-slot combine weight, laid out expert-major [G,E,C]
    w_table = jnp.zeros((G, E, C), jnp.float32)
    w_table = w_table.at[gidx, flat_e, flat_p].set(
        gates.reshape(G, S * k), mode="drop")
    w_table = jnp.where(filled, w_table, 0.0)

    xe = xg[gidx[..., None], table]                        # gather [G,E,C,d]
    xe = jnp.where(filled[..., None], xe, 0)
    xe = constrain(xe, pcfg, _ep_spec(pcfg))
    ye = _expert_ffn(params, xe, cfg, pcfg)

    # combine: scatter-add weighted expert outputs back onto tokens
    upd = ye * w_table[..., None].astype(ye.dtype)         # [G,E,C,d]
    out = jnp.zeros((G, S, d), ye.dtype)
    out = out.at[gidx[..., None], table].add(
        jnp.where(filled[..., None], upd, 0), mode="drop")
    return out
