"""GQA attention: chunked online-softmax (train/prefill) + cached decode.

Three implementations share one numerics contract (tested against each other):

  * ``scan``       -- lax.scan over (q-chunk x kv-chunk) with causal masking.
                      Compact HLO; computes the full rectangle (2x causal
                      waste). The paper-faithful baseline.
  * ``triangular`` -- statically unrolled lower-triangular chunk pairs; only
                      the diagonal chunk is masked. Halves prefill/train
                      attention FLOPs (a §Perf iteration).
  * ``pallas``     -- the flash-attention TPU kernel in repro/kernels
                      (real-TPU path; validated in interpret mode).

Local (sliding-window) layers slice a [window + q_chunk] KV strip per q-chunk
with a dynamic start, so windowed attention costs O(T * window) instead of
O(T^2) in every implementation.

Decode attends a single query against a **full cache** ([B, S, K, D],
positions implicit) or a **ring cache** ([B, W, K, D] plus an explicit
``kpos`` slot-position array) for windowed layers — the ring bound is what
makes ``long_500k`` decodable for gemma3 / recurrentgemma.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import sds, soft_cap
from repro.parallel.sharding import ParallelConfig, constrain, heads_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def shapes(cfg: ModelConfig, *, cross: bool = False) -> dict:
    pd = cfg.param_dtype
    d = cfg.d_model
    out = {
        "wq": sds((d, cfg.q_dim), pd),
        "wk": sds((d, cfg.kv_dim), pd),
        "wv": sds((d, cfg.kv_dim), pd),
        "wo": sds((cfg.q_dim, d), pd),
    }
    if cfg.qkv_bias:
        out["bq"] = sds((cfg.q_dim,), pd)
        out["bk"] = sds((cfg.kv_dim,), pd)
        out["bv"] = sds((cfg.kv_dim,), pd)
    if cfg.qk_norm:
        out["q_norm"] = sds((cfg.d_head,), pd)
        out["k_norm"] = sds((cfg.d_head,), pd)
    return out


def _project_q(p, x, cfg: ModelConfig):
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(x.shape[:-1] + (cfg.n_heads, cfg.d_head))
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def _project_kv(p, x, cfg: ModelConfig):
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(x.shape[:-1] + (cfg.n_kv_heads, cfg.d_head))
    v = v.reshape(x.shape[:-1] + (cfg.n_kv_heads, cfg.d_head))
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# Core chunked attention (q: [B,T,K,G,D], k/v: [B,S,K,D])
# ---------------------------------------------------------------------------

def _block(qc, kc, vc, qpos, kpos, *, causal, window, scale, softcap, extra_mask=None):
    """One (q-chunk, kv-chunk) online-softmax block.

    Returns (scores_exp_numerator p, row_max m, None) pieces folded by caller.
    qc: [B,Tq,K,G,D]; kc/vc: [B,Sk,K,D]; qpos: [Tq] or [B,Tq]; kpos: [Sk] or [B,Sk].
    """
    s = jnp.einsum("btkgd,bskd->bkgts", qc, kc,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = soft_cap(s, softcap)
    mask = None
    if causal:
        q_b = qpos[..., :, None]
        k_b = kpos[..., None, :]
        mask = k_b <= q_b
        if window:
            mask = mask & (q_b - k_b < window)
    if extra_mask is not None:
        mask = extra_mask if mask is None else (mask & extra_mask)
    if mask is not None:
        while mask.ndim < s.ndim:  # [.. ,t,s] -> broadcast over B,K,G
            mask = mask[..., None, :, :] if mask.ndim >= 2 else mask
        # mask now [*,1?,t,s]; rely on broadcasting from [t,s] or [B,1,1,t,s]
        s = jnp.where(mask, s, NEG_INF)
    return s


def _fold(carry, s, vc):
    m, lsum, acc = carry
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    lsum = lsum * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bkgts,bskd->bkgtd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc = acc * alpha[..., None] + pv
    return m_new, lsum, acc


def _finish(m, lsum, acc, B, Tq, K, G, D, dtype):
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    # [B,K,G,T,D] -> [B,T,K*G,D]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, K * G, D)
    return out.astype(dtype)


def chunked_attention(
    q: jax.Array,  # [B, T, H, D]
    k: jax.Array,  # [B, S, K, D]
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    impl: str = "scan",
    softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    """Memory-efficient attention; never materializes [T, S] in full."""
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    q = q.reshape(B, T, K, G, D)

    qc_sz = min(q_chunk, T)
    while T % qc_sz:
        qc_sz //= 2
    kc_sz = min(kv_chunk, S)
    while S % kc_sz:
        kc_sz //= 2
    nq, ns = T // qc_sz, S // kc_sz

    if window and causal and window + qc_sz < S:
        return _windowed(q, k, v, B, T, S, K, G, D, qc_sz, window, scale,
                         softcap, q_offset,
                         unroll=impl in ("rect", "triangular"))

    if impl == "triangular" and causal:
        return _unrolled(q, k, v, B, T, S, K, G, D, qc_sz, kc_sz, window,
                         scale, softcap, q_offset, causal=True,
                         skip_future=True)
    if impl == "rect":
        # statically unrolled FULL rectangle (masked): numerically identical
        # to "scan" and costs the same FLOPs, but visible to cost_analysis
        # (XLA counts a while-loop body once). Measurement twin of "scan".
        return _unrolled(q, k, v, B, T, S, K, G, D, qc_sz, kc_sz, window,
                         scale, softcap, q_offset, causal=causal,
                         skip_future=False)

    # --- scan impl: outer scan over q chunks, inner scan over kv chunks -----
    q_r = q.reshape(B, nq, qc_sz, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    k_r = k.reshape(B, ns, kc_sz, K, D).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(B, ns, kc_sz, K, D).transpose(1, 0, 2, 3, 4)

    def per_q(_, qi_qc):
        qi, qc = qi_qc
        qpos = q_offset + qi * qc_sz + jnp.arange(qc_sz)

        def per_kv(carry, ki_kc):
            ki, kc, vc = ki_kc
            kpos = ki * kc_sz + jnp.arange(kc_sz)
            s = _block(qc, kc, vc, qpos, kpos, causal=causal, window=window,
                       scale=scale, softcap=softcap)
            return _fold(carry, s, vc), None

        m0 = jnp.full((B, K, G, qc_sz), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc_sz), jnp.float32)
        a0 = jnp.zeros((B, K, G, qc_sz, D), jnp.float32)
        (m, lsum, acc), _ = lax.scan(per_kv, (m0, l0, a0),
                                  (jnp.arange(ns), k_r, v_r))
        return None, _finish(m, lsum, acc, B, qc_sz, K, G, D, q.dtype)

    _, outs = lax.scan(per_q, None, (jnp.arange(nq), q_r))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D)


def _unrolled(q, k, v, B, T, S, K, G, D, qc_sz, kc_sz, window, scale,
              softcap, q_offset, *, causal, skip_future):
    """Statically unrolled chunk pairs.

    ``skip_future=True`` is the triangular optimisation (strictly-future and
    strictly-out-of-window chunks never touch the MXU; interior chunks skip
    the mask). ``skip_future=False`` computes the full masked rectangle —
    numerically identical to the ``scan`` impl with identical FLOPs, used
    for measurement (cost_analysis counts a while-loop body only once)."""
    nq, ns = T // qc_sz, S // kc_sz
    outs = []
    for qi in range(nq):
        qc = q[:, qi * qc_sz:(qi + 1) * qc_sz]
        q_start = q_offset + qi * qc_sz
        q_end = q_start + qc_sz
        qpos = q_start + jnp.arange(qc_sz)
        m = jnp.full((B, K, G, qc_sz), NEG_INF, jnp.float32)
        lsum = jnp.zeros((B, K, G, qc_sz), jnp.float32)
        acc = jnp.zeros((B, K, G, qc_sz, D), jnp.float32)
        for ki in range(ns):
            k_start = ki * kc_sz
            k_end = k_start + kc_sz
            if skip_future and causal:
                if k_start >= q_end:
                    break  # strictly future chunk
                if window and k_end - 1 < q_start - window + 1:
                    continue  # strictly out of the sliding window
            if skip_future:
                # only the diagonal straddler (or any chunk, when windowed)
                # needs masking
                needs_mask = (k_end > q_start) or bool(window)
            else:
                needs_mask = causal
            s = _block(qc, kc := k[:, k_start:k_end], vc := v[:, k_start:k_end],
                       qpos, k_start + jnp.arange(kc_sz),
                       causal=needs_mask, window=window if needs_mask else 0,
                       scale=scale, softcap=softcap)
            m, lsum, acc = _fold((m, lsum, acc), s, vc)
        outs.append(_finish(m, lsum, acc, B, qc_sz, K, G, D, q.dtype))
    return jnp.concatenate(outs, axis=1).reshape(B, T, K * G, D)


def _windowed(q, k, v, B, T, S, K, G, D, qc_sz, window, scale, softcap,
              q_offset, *, unroll=False):
    """Sliding-window attention: slice [window + qc] KV strip per q chunk.

    ``unroll=True`` replaces the q-chunk scan with a static python loop so
    cost_analysis sees every chunk (measurement mode)."""
    strip = min(common.round_up(window + qc_sz, 128), S)
    nq = T // qc_sz

    def one_q(qi, qc):
        q_start = q_offset + qi * qc_sz
        start = jnp.clip(q_start + qc_sz - strip, 0, S - strip)
        kc = lax.dynamic_slice_in_dim(k, start, strip, axis=1)
        vc = lax.dynamic_slice_in_dim(v, start, strip, axis=1)
        qpos = q_start + jnp.arange(qc_sz)
        kpos = start + jnp.arange(strip)
        s = _block(qc, kc, vc, qpos, kpos, causal=True, window=window,
                   scale=scale, softcap=softcap)
        m = jnp.full((B, K, G, qc_sz), NEG_INF, jnp.float32)
        lsum = jnp.zeros((B, K, G, qc_sz), jnp.float32)
        acc = jnp.zeros((B, K, G, qc_sz, D), jnp.float32)
        m, lsum, acc = _fold((m, lsum, acc), s, vc)
        return _finish(m, lsum, acc, B, qc_sz, K, G, D, q.dtype)

    if unroll:
        outs = [one_q(qi, q[:, qi * qc_sz:(qi + 1) * qc_sz])
                for qi in range(nq)]
        return jnp.concatenate(outs, axis=1).reshape(B, T, K * G, D)

    q_r = q.reshape(B, nq, qc_sz, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    _, outs = lax.scan(lambda _, xs: (None, one_q(xs[0], xs[1])),
                       None, (jnp.arange(nq), q_r))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, K * G, D)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, batch: int, seq: int, *, ring: bool,
                 window: int = 0) -> dict:
    """Decode cache for one attention layer (compute dtype)."""
    ct = cfg.compute_dtype
    slots = min(window, seq) if ring and window else seq
    out = {
        "k": sds((batch, slots, cfg.n_kv_heads, cfg.d_head), ct),
        "v": sds((batch, slots, cfg.n_kv_heads, cfg.d_head), ct),
    }
    if ring and window and window < seq:
        out["kpos"] = sds((batch, slots), jnp.int32)
    return out


def init_cache(cfg, batch, seq, *, ring, window=0):
    tree = cache_shapes(cfg, batch, seq, ring=ring, window=window)
    def zero(s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(zero, tree)


def _masked_write(buf, new, slot):
    """buf: [B,S,...], new: [B,1,...], slot: [B] int32 — shardable update
    (elementwise select; works with the sequence dim sharded, at the cost
    of rewriting the whole cache: ~3x cache HBM traffic per step)."""
    onehot = jnp.arange(buf.shape[1])[None, :] == slot[:, None]  # [B,S]
    oh = onehot.reshape(onehot.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new.astype(buf.dtype), buf)


def _scatter_write(buf, new, slot):
    """In-place one-slot update via per-sample dynamic_update_slice:
    touches only the written slot (1x traffic) but XLA reshards when the
    sequence dim is partitioned — use when S is unsharded."""
    def one(b, n, s):
        idx = (s,) + (0,) * (b.ndim - 1)  # b: per-sample [S, ...]
        return lax.dynamic_update_slice(b, n.astype(b.dtype), idx)
    return jax.vmap(one)(buf, new, slot)


def update_cache(cache: dict, k_new, v_new, pos, mode: str = "masked"):
    """Append one token (k/v: [B,1,K,D]) at ``pos`` ([B] int32)."""
    write = _scatter_write if mode == "scatter" else _masked_write
    is_ring = "kpos" in cache
    slots = cache["k"].shape[1]
    slot = (pos % slots) if is_ring else pos
    out = dict(cache)
    out["k"] = write(cache["k"], k_new, slot)
    out["v"] = write(cache["v"], v_new, slot)
    if is_ring:
        out["kpos"] = write(cache["kpos"][..., None],
                            pos[:, None, None], slot)[..., 0]
    return out


def decode_attention(q, cache: dict, pos, *, window: int = 0,
                     softcap: float = 0.0):
    """q: [B,1,H,D] against cache; returns [B,1,H,D]."""
    B, _, H, D = q.shape
    k, v = cache["k"], cache["v"]
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, K, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = soft_cap(s, softcap)
    if "kpos" in cache:
        kpos = cache["kpos"]  # [B,S] true positions, -1 = empty
        valid = (kpos >= 0) & (kpos <= pos[:, None])
        if window:
            valid &= pos[:, None] - kpos < window
    else:
        kpos = jnp.arange(S)[None, :]
        valid = kpos <= pos[:, None]
        if window:
            valid &= pos[:, None] - kpos < window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Layer-level apply
# ---------------------------------------------------------------------------

def apply(
    params: dict,
    x: jax.Array,                      # [B, T, d_model]
    *,
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    layer_sym: str,                    # "A" | "L"
    positions: jax.Array,              # [B, T] (or [B] for decode)
    mode: str,                         # "train" | "prefill" | "decode"
    cache: Optional[dict] = None,
    memory_kv: Optional[tuple] = None, # cross-attention (k, v) from encoder
    max_len: int = 0,                  # prefill: decode-cache capacity
):
    """Returns (out [B,T,d_model], new_cache)."""
    is_local = layer_sym == "L"
    window = cfg.local_window if is_local else 0
    theta = cfg.rope_theta
    if is_local and getattr(cfg, "rope_theta_local", 0):
        theta = cfg.rope_theta_local
    cross = memory_kv is not None

    q = _project_q(params, x, cfg)
    if not cross:
        q = common.apply_rope(q, positions, theta)
    q = constrain(q, pcfg, heads_spec(pcfg, cfg.n_heads, batch_dims=2))

    if mode == "decode":
        if cross:
            k, v = memory_kv
            out = decode_attention(q, {"k": k, "v": v},
                                   jnp.full((x.shape[0],), k.shape[1] - 1,
                                            jnp.int32),
                                   softcap=cfg.attn_softcap)
            new_cache = cache
        else:
            k_new, v_new = _project_kv(params, x, cfg)
            k_new = common.apply_rope(k_new, positions, theta)
            new_cache = update_cache(cache, k_new, v_new, positions[:, 0],
                                     mode=pcfg.cache_write)
            out = decode_attention(q, new_cache, positions[:, 0],
                                   window=window, softcap=cfg.attn_softcap)
    else:
        if cross:
            k, v = memory_kv
            out = chunked_attention(q, k, v, causal=False,
                                    q_chunk=pcfg.q_chunk,
                                    kv_chunk=pcfg.kv_chunk,
                                    impl="scan", softcap=cfg.attn_softcap)
            new_cache = None
        else:
            k, v = _project_kv(params, x, cfg)
            k = common.apply_rope(k, positions, theta)
            causal = not (cfg.is_encoder_decoder and mode == "encode")
            out = chunked_attention(
                q, k, v, causal=causal, window=window,
                q_chunk=pcfg.q_chunk, kv_chunk=pcfg.kv_chunk,
                impl=pcfg.attn_impl if causal else "scan",
                softcap=cfg.attn_softcap)
            new_cache = None
            if mode == "prefill":
                new_cache = _prefill_cache(k, v, positions, window=window,
                                           max_len=max_len or k.shape[1])

    B, T = x.shape[0], x.shape[1]
    out = out.reshape(B, T, cfg.q_dim)
    return out @ params["wo"], new_cache


def _prefill_cache(k, v, positions, *, window, max_len):
    """Build the decode cache from prefill K/V.

    Full-attention layers get a [B, max_len, K, D] cache (prompt K/V in the
    first S slots); local layers get a ring of ``window`` slots.
    """
    S = k.shape[1]
    if window and window < max_len:
        # keep the last ``window`` positions, laid out ring-consistently:
        # true position p lives at slot p % window.
        last_k = k[:, -window:]
        last_v = v[:, -window:]
        last_pos = positions[:, -window:]
        slot = last_pos % window  # [B, W]
        def ring_scatter(buf):
            B = buf.shape[0]
            out = jnp.zeros((B, window) + buf.shape[2:], buf.dtype)
            bidx = jnp.arange(B)[:, None]
            return out.at[bidx, slot].set(buf)
        cache = {"k": ring_scatter(last_k), "v": ring_scatter(last_v)}
        B = k.shape[0]
        kp = jnp.full((B, window), -1, jnp.int32)
        cache["kpos"] = kp.at[jnp.arange(B)[:, None], slot].set(last_pos)
        return cache
    if max_len > S:
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return {"k": k, "v": v}
