"""The layer stack: scan-over-groups transformer covering all six families.

The stack is ``n_groups`` repetitions of the config's ``block_pattern`` unit.
Parameters (and decode caches / recurrent states) for the unit are stacked
with a leading group dim and the stack lowers as one ``lax.scan`` — for 512
device compiles this keeps the HLO proportional to the *pattern unit*, not
the layer count, and lets the remat policy apply uniformly.

Cache pytree mirrors the param pytree: ``{"layer<i>": {...}}`` per unit
position, leaves stacked over groups. Attention layers hold KV (full or
ring) caches; recurrent layers hold their O(1) state — which is precisely
why the hybrid/ssm archs run ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention, mlp, moe, rglru, xlstm
from repro.models.common import rms_norm, sds, soft_cap
from repro.parallel.sharding import ParallelConfig, batch_spec, constrain


# ---------------------------------------------------------------------------
# Parameter shapes
# ---------------------------------------------------------------------------

def _unit_shapes(cfg: ModelConfig, *, decoder_cross: bool) -> dict:
    pd = cfg.param_dtype
    d = cfg.d_model
    unit = {}
    for i, sym in enumerate(cfg.block_pattern):
        if sym in ("A", "L"):
            layer = {
                "norm1": {"scale": sds((d,), pd)},
                "attn": attention.shapes(cfg),
                "norm2": {"scale": sds((d,), pd)},
            }
            if decoder_cross:
                layer["norm_x"] = {"scale": sds((d,), pd)}
                layer["xattn"] = attention.shapes(cfg, cross=True)
            if cfg.family == "moe":
                layer["moe"] = moe.shapes(cfg)
            else:
                layer["mlp"] = mlp.shapes(cfg)
        elif sym == "R":
            layer = {
                "norm1": {"scale": sds((d,), pd)},
                "rglru": rglru.shapes(cfg),
                "norm2": {"scale": sds((d,), pd)},
                "mlp": mlp.shapes(cfg),
            }
        elif sym == "m":
            layer = {"norm1": {"scale": sds((d,), pd)},
                     "mlstm": xlstm.mlstm_shapes(cfg)}
        elif sym == "s":
            layer = {"norm1": {"scale": sds((d,), pd)},
                     "slstm": xlstm.slstm_shapes(cfg)}
        else:
            raise ValueError(sym)
        unit[f"layer{i}"] = layer
    return unit


def _stack_groups(unit_tree, n_groups: int):
    return jax.tree.map(
        lambda s: sds((n_groups,) + s.shape, s.dtype), unit_tree)


def shapes(cfg: ModelConfig) -> dict:
    """Full parameter tree (as ShapeDtypeStructs)."""
    pd = cfg.param_dtype
    d, vp = cfg.d_model, cfg.padded_vocab
    out = {
        "embed": {"w": sds((vp, d), pd)},
        "blocks": _stack_groups(
            _unit_shapes(cfg, decoder_cross=cfg.is_encoder_decoder),
            cfg.n_groups),
        "final_norm": {"scale": sds((d,), pd)},
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = {"w": sds((d, vp), pd)}
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same dims per assignment
        out["encoder"] = {
            "blocks": _stack_groups(_unit_shapes(cfg, decoder_cross=False),
                                    cfg.n_enc_layers // cfg.pattern_len),
            "final_norm": {"scale": sds((d,), pd)},
        }
    if cfg.frontend == "vision_patches":
        out["frontend"] = {"w1": sds((d, d), pd), "w2": sds((d, d), pd)}
    elif cfg.frontend == "audio_frames":
        out["frontend"] = {"w1": sds((d, d), pd)}
    return out


# ---------------------------------------------------------------------------
# Decode cache / recurrent state shapes
# ---------------------------------------------------------------------------

def _unit_cache_shapes(cfg: ModelConfig, batch: int, seq: int,
                       *, cross_len: int = 0) -> dict:
    unit = {}
    for i, sym in enumerate(cfg.block_pattern):
        if sym in ("A", "L"):
            ring = sym == "L" and cfg.local_window and cfg.local_window < seq
            layer = {"attn": attention.cache_shapes(
                cfg, batch, seq, ring=ring, window=cfg.local_window)}
            if cfg.is_encoder_decoder and cross_len:
                ct = cfg.compute_dtype
                layer["xk"] = sds((batch, cross_len, cfg.n_kv_heads,
                                   cfg.d_head), ct)
                layer["xv"] = sds((batch, cross_len, cfg.n_kv_heads,
                                   cfg.d_head), ct)
        elif sym == "R":
            layer = {"rec": rglru.state_shapes(cfg, batch)}
        elif sym == "m":
            layer = {"rec": xlstm.mlstm_state_shapes(cfg, batch)}
        elif sym == "s":
            layer = {"rec": xlstm.slstm_state_shapes(cfg, batch)}
        unit[f"layer{i}"] = layer
    return unit


def cache_shapes(cfg: ModelConfig, batch: int, seq: int,
                 *, cross_len: int = 0) -> dict:
    return _stack_groups(
        _unit_cache_shapes(cfg, batch, seq, cross_len=cross_len),
        cfg.n_groups)


def init_cache(cfg: ModelConfig, batch: int, seq: int, *, cross_len: int = 0):
    tree = cache_shapes(cfg, batch, seq, cross_len=cross_len)
    return _zero_state(tree)


# ---------------------------------------------------------------------------
# Unit application
# ---------------------------------------------------------------------------

def _zero_state(shape_tree):
    from repro.utils.pytree import tree_map_with_path

    def init(path, s):
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, s.dtype)
        if path.split("/")[-1] == "m":  # log-space stabilisers: -inf-ish
            return jnp.full(s.shape, -1e30, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return tree_map_with_path(init, shape_tree)


def _unit_apply(unit_params, x, *, cfg: ModelConfig, pcfg: ParallelConfig,
                positions, mode: str, unit_cache=None, memory=None,
                max_len: int = 0):
    """Apply one pattern unit. Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    B = x.shape[0]
    aux = jnp.zeros((), jnp.float32)
    collect = mode == "prefill" or unit_cache is not None
    new_cache = {} if collect else None

    def rec_state(i, sym):
        if unit_cache is not None:
            return unit_cache[f"layer{i}"]["rec"]
        if mode != "prefill":
            return None
        maker = {"R": rglru.state_shapes, "m": xlstm.mlstm_state_shapes,
                 "s": xlstm.slstm_state_shapes}[sym]
        return _zero_state(maker(cfg, B))

    for i, sym in enumerate(cfg.block_pattern):
        lp = unit_params[f"layer{i}"]
        lc = unit_cache[f"layer{i}"] if unit_cache is not None else None
        if sym in ("A", "L"):
            h = rms_norm(x, lp["norm1"]["scale"], eps)
            out, attn_cache = attention.apply(
                lp["attn"], h, cfg=cfg, pcfg=pcfg, layer_sym=sym,
                positions=positions, mode=mode, max_len=max_len,
                cache=lc["attn"] if lc is not None else None)
            x = x + out
            if cfg.is_encoder_decoder and mode != "encode" and (
                    memory is not None or (lc is not None and "xk" in lc)):
                hx = rms_norm(x, lp["norm_x"]["scale"], eps)
                if memory is not None:  # train / prefill: project fresh
                    mem_kv = attention._project_kv(lp["xattn"], memory, cfg)
                else:                   # decode: cached cross K/V
                    mem_kv = (lc["xk"], lc["xv"])
                xout, _ = attention.apply(
                    lp["xattn"], hx, cfg=cfg, pcfg=pcfg, layer_sym="A",
                    positions=positions, mode=mode, memory_kv=mem_kv)
                x = x + xout
            h = rms_norm(x, lp["norm2"]["scale"], eps)
            if cfg.family == "moe":
                ffn, aux_i = moe.apply(lp["moe"], h, cfg=cfg, pcfg=pcfg)
                aux = aux + aux_i
            else:
                ffn = mlp.apply(lp["mlp"], h, cfg=cfg, pcfg=pcfg)
            x = x + ffn
            if new_cache is not None:
                layer_new = {"attn": attn_cache if attn_cache is not None
                             else lc["attn"]}
                if cfg.is_encoder_decoder:
                    if memory is not None:  # prefill: store projected cross KV
                        layer_new["xk"], layer_new["xv"] = mem_kv
                    elif lc is not None and "xk" in lc:
                        layer_new["xk"], layer_new["xv"] = lc["xk"], lc["xv"]
                new_cache[f"layer{i}"] = layer_new
        elif sym == "R":
            h = rms_norm(x, lp["norm1"]["scale"], eps)
            out, st = rglru.apply(lp["rglru"], h, cfg=cfg,
                                  state=rec_state(i, sym),
                                  chunk=pcfg.lru_chunk,
                                  unroll=pcfg.unroll_scans)
            x = x + out
            h = rms_norm(x, lp["norm2"]["scale"], eps)
            x = x + mlp.apply(lp["mlp"], h, cfg=cfg, pcfg=pcfg)
            if new_cache is not None:
                new_cache[f"layer{i}"] = {"rec": st}
        elif sym == "m":
            h = rms_norm(x, lp["norm1"]["scale"], eps)
            out, st = xlstm.mlstm_apply(lp["mlstm"], h, cfg=cfg,
                                        state=rec_state(i, sym),
                                        unroll=pcfg.unroll_scans)
            x = x + out
            if new_cache is not None:
                new_cache[f"layer{i}"] = {"rec": st}
        elif sym == "s":
            h = rms_norm(x, lp["norm1"]["scale"], eps)
            out, st = xlstm.slstm_apply(lp["slstm"], h, cfg=cfg,
                                        state=rec_state(i, sym))
            x = x + out
            if new_cache is not None:
                new_cache[f"layer{i}"] = {"rec": st}
        x = constrain(x, pcfg, batch_spec(pcfg, None, None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack application (scan over groups)
# ---------------------------------------------------------------------------

def _remat_wrap(fn, pcfg: ParallelConfig, mode: str):
    # jax.checkpoint only affects differentiated code, so wrapping every mode
    # is safe; it matters for "train" (and "encode" under the train loss).
    if pcfg.remat == "none":
        return fn
    if pcfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    elif pcfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        raise ValueError(pcfg.remat)
    return jax.checkpoint(fn, policy=policy)


def stack_apply(blocks_params, x, *, cfg: ModelConfig, pcfg: ParallelConfig,
                positions, mode: str, caches=None, memory=None,
                n_groups: Optional[int] = None, max_len: int = 0):
    """Run the full stack. Returns (x, new_caches, aux).

    ``caches`` is required for decode, ignored for train/encode, and unused
    for prefill (prefill builds fresh caches of capacity ``max_len``).
    """
    n_groups = n_groups or cfg.n_groups
    emit_cache = mode == "prefill" or caches is not None

    def body(carry, xs):
        h, aux = carry
        if caches is None:
            unit_params, unit_cache = xs, None
        else:
            unit_params, unit_cache = xs
        h, new_cache, aux_i = _unit_apply(unit_params, h, cfg=cfg, pcfg=pcfg,
                                          positions=positions, mode=mode,
                                          unit_cache=unit_cache, memory=memory,
                                          max_len=max_len)
        return (h, aux + aux_i), new_cache

    body = _remat_wrap(body, pcfg, mode)
    xs = blocks_params if caches is None else (blocks_params, caches)
    if pcfg.scan_layers:
        (x, aux), new_caches = lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for g in range(n_groups):
            unit = jax.tree.map(lambda a: a[g], xs)
            carry, nc = body(carry, unit)
            outs.append(nc)
        x, aux = carry
        new_caches = None
        if emit_cache:
            new_caches = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
    return x, (new_caches if emit_cache else None), aux


# ---------------------------------------------------------------------------
# Embedding / head / frontends
# ---------------------------------------------------------------------------

def _vocab_parallel_embed(params, tokens, *, cfg: ModelConfig,
                          pcfg: ParallelConfig):
    """Megatron-style vocab-parallel lookup: each model shard gathers its
    vocab slice with a masked local take, then one psum over ``model``
    combines. Avoids XLA's 'involuntary full rematerialization' of the
    [B,T,D] gather when the table is vocab-sharded (a §Perf memory/
    collective iteration)."""
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.utils.jax_compat import shard_map_partial

    w = params["embed"]["w"]
    vp = w.shape[0]
    msz = pcfg.model_size
    vshard = vp // msz

    def body(w_local, toks):
        idx = lax.axis_index("model")
        rel = toks - idx * vshard
        ok = (rel >= 0) & (rel < vshard)
        out = jnp.take(w_local, jnp.clip(rel, 0, vshard - 1), axis=0)
        out = jnp.where(ok[..., None], out, 0).astype(cfg.compute_dtype)
        return lax.psum(out, "model")

    fn = shard_map_partial(body, mesh=pcfg.mesh,
                           in_specs=(P("model", None), P()),
                           out_specs=P(), manual_axes={"model"})
    return fn(w, tokens)


def embed(params, tokens, *, cfg: ModelConfig, pcfg: ParallelConfig):
    if pcfg.embed_mode == "vocab_parallel" and pcfg.mesh is not None \
            and pcfg.model_size > 1:
        x = _vocab_parallel_embed(params, tokens, cfg=cfg, pcfg=pcfg)
    else:
        w = params["embed"]["w"]
        x = jnp.take(w, tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    return constrain(x, pcfg, batch_spec(pcfg, None, None))


def splice_patches(params, x, patch_embeds, patch_pos, *, cfg, pcfg):
    """Splice projected vision-patch embeddings into the token stream.

    Formulated as a small int32 scatter ([B,S] inverse-index map) followed
    by a gather + select: scattering the [B,S,D] hidden tensor directly
    makes the SPMD partitioner replicate it across the mesh (same pathology
    as masked KV writes); this form keeps everything batch-local."""
    fp = params["frontend"]
    proj = jax.nn.gelu(patch_embeds.astype(cfg.compute_dtype) @ fp["w1"],
                       approximate=True) @ fp["w2"]
    if cfg.embed_scale:
        proj = proj * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    B, S, _ = x.shape
    P_ = patch_pos.shape[1]
    b_idx = jnp.arange(B)[:, None]
    inv = jnp.full((B, S), -1, jnp.int32)
    inv = inv.at[b_idx, patch_pos].set(
        jnp.broadcast_to(jnp.arange(P_, dtype=jnp.int32)[None], (B, P_)))
    picked = jnp.take_along_axis(
        proj.astype(x.dtype),
        jnp.clip(inv, 0, P_ - 1)[..., None].astype(jnp.int32), axis=1)
    return jnp.where((inv >= 0)[..., None], picked, x)


def project_frames(params, frames, *, cfg, pcfg):
    """Audio frontend stub: one linear projection over frame embeddings."""
    return constrain(
        frames.astype(cfg.compute_dtype) @ params["frontend"]["w1"],
        pcfg, batch_spec(pcfg, None, None))


def lm_logits(params, x, *, cfg: ModelConfig, pcfg: ParallelConfig):
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["w"]
        logits = jnp.einsum("btd,vd->btv", x, w)
    else:
        logits = x @ params["lm_head"]["w"]
    logits = soft_cap(logits, cfg.logit_softcap)
    return constrain(logits, pcfg, batch_spec(pcfg, None, "model"))
