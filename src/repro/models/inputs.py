"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns the exact batch pytree each step function
consumes — weak-type-correct and shardable, with no device allocation. The
modality frontends are stubs per the assignment: VLM cells carry precomputed
anyres patch embeddings; audio cells carry precomputed frame embeddings.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import sds


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "inputs": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = sds((b, cfg.frontend_positions, cfg.d_model),
                                  cfg.compute_dtype)
        out["patch_pos"] = sds((b, cfg.frontend_positions), jnp.int32)
    if cfg.is_encoder_decoder:
        # encoder consumes precomputed frames at the same sequence length
        out["enc_frames"] = sds((b, s, cfg.d_model), cfg.compute_dtype)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"inputs": sds((b, s), jnp.int32)}
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = sds((b, cfg.frontend_positions, cfg.d_model),
                                  cfg.compute_dtype)
        out["patch_pos"] = sds((b, cfg.frontend_positions), jnp.int32)
    if cfg.is_encoder_decoder:
        out["enc_frames"] = sds((b, s, cfg.d_model), cfg.compute_dtype)
    return out


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "token": sds((b, 1), jnp.int32),
        "pos": sds((b,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_batch_specs(cfg, shape)
    raise ValueError(shape.kind)
