"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM (matrix-memory LSTM) is a gated linear-attention RNN:

    C_t = exp(logsig f_t) C_{t-1} + exp(i_t) k_t v_t^T
    n_t = exp(logsig f_t) n_{t-1} + exp(i_t) k_t
    h_t = (q_t C_t) / max(|q_t n_t|, exp(-m_t))

with a log-space stabiliser m_t. Training uses the **chunkwise-parallel**
form (intra-chunk attention matrix + inter-chunk state scan) — TPU-friendly:
the MXU sees [L, L] and [L, d] matmuls instead of a length-T sequential
dependency. Decode uses the O(1) recurrent step. Both are validated against
each other in tests (the sequential form is the oracle).

sLSTM has a true nonlinear recurrence (h feeds back through the gates) so it
cannot be parallelised over time; it runs as a lax.scan with block-diagonal
recurrent weights (one block per head), exactly as published.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import block_diag_apply, block_diag_shapes, sds

CHUNK = 256  # mLSTM chunk length for the chunkwise-parallel form


def _inner(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_shapes(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    d, inner = cfg.d_model, _inner(cfg)
    bs = cfg.mlstm_qkv_blocksize
    h = cfg.n_heads
    return {
        "up": sds((d, 2 * inner), pd),
        "conv_w": sds((cfg.conv1d_width, inner), pd),
        "q": block_diag_shapes(inner // bs, inner, bs, pd),
        "k": block_diag_shapes(inner // bs, inner, bs, pd),
        "v": block_diag_shapes(inner // bs, inner, bs, pd),
        "igate": {"w": sds((3 * inner, h), jnp.float32),
                  "b": sds((h,), jnp.float32)},
        "fgate": {"w": sds((3 * inner, h), jnp.float32),
                  "b": sds((h,), jnp.float32)},
        "out_norm": sds((inner,), pd),
        "down": sds((inner, d), pd),
    }


def mlstm_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    inner = _inner(cfg)
    h = cfg.n_heads
    dh = inner // h
    return {
        "C": sds((batch, h, dh, dh), jnp.float32),
        "n": sds((batch, h, dh), jnp.float32),
        "m": sds((batch, h), jnp.float32),
        "conv": sds((batch, cfg.conv1d_width - 1, inner), cfg.compute_dtype),
    }


def _mlstm_qkv_gates(p, x, cfg: ModelConfig, conv_state=None):
    """x: [B,T,d] -> q,k,v [B,T,H,dh], i/f raw gates [B,T,H], z [B,T,inner]."""
    inner = _inner(cfg)
    h = cfg.n_heads
    up = x @ p["up"]
    xm, z = jnp.split(up, 2, axis=-1)
    if conv_state is None:
        xc = common.causal_conv1d(xm, p["conv_w"])
        new_conv = None
    else:
        xc, new_conv = common.causal_conv1d(xm, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    q = block_diag_apply(p["q"], xc)
    k = block_diag_apply(p["k"], xc) / math.sqrt(inner // h)
    v = block_diag_apply(p["v"], xm)
    qkv = jnp.concatenate([q, k, v], axis=-1).astype(jnp.float32)
    ig = qkv @ p["igate"]["w"] + p["igate"]["b"]  # [B,T,H]
    fg = qkv @ p["fgate"]["w"] + p["fgate"]["b"]
    dh = inner // h
    shp = x.shape[:-1] + (h, dh)
    return q.reshape(shp), k.reshape(shp), v.reshape(shp), ig, fg, z, new_conv


def _mlstm_chunk(carry, qkvif):
    """One chunk of the chunkwise-parallel mLSTM. Shapes: q,k,v [B,L,H,dh];
    ig,fg [B,L,H]. Carry: C [B,H,dk,dv], n [B,H,dk], m [B,H]."""
    C, n, m = carry
    q, k, v, ig, fg = qkvif
    B, L, H, dh = q.shape
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))     # [B,L,H]
    b = jnp.cumsum(logf, axis=1)                           # inclusive cumsum
    i32 = ig.astype(jnp.float32)
    g = lax.cummax(i32 - b, axis=1)                        # running max of i-b
    m_t = b + jnp.maximum(m[:, None], g)                   # [B,L,H]
    b_last, m_last = b[:, -1], m_t[:, -1]

    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)       # [B,H,L,dh]
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)

    # intra-chunk: D[t,s] = exp(b_t - b_s + i_s - m_t) for s <= t
    bt = b.transpose(0, 2, 1)                              # [B,H,L]
    mt = m_t.transpose(0, 2, 1)
    it = i32.transpose(0, 2, 1)
    logD = bt[..., :, None] - bt[..., None, :] + it[..., None, :] \
        - mt[..., :, None]
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(tri, jnp.exp(logD), 0.0)                 # [B,H,L,L]
    scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * D
    h_intra = jnp.einsum("bhts,bhsd->bhtd", scores, vf)
    den_intra = scores.sum(-1)                             # [B,H,L]

    # inter-chunk: contribution of carried state
    decay_in = jnp.exp(m[:, None] + b - m_t).transpose(0, 2, 1)  # [B,H,L]
    h_inter = jnp.einsum("bhtd,bhde->bhte", qf, C) * decay_in[..., None]
    den_inter = jnp.einsum("bhtd,bhd->bht", qf, n) * decay_in

    den = den_intra + den_inter
    h = (h_intra + h_inter) / jnp.maximum(
        jnp.abs(den), jnp.exp(-mt))[..., None]

    # chunk-end state
    m_new = m_t[:, -1]                                     # [B,H]
    decay_state = jnp.exp(m + b_last - m_new)              # [B,H]
    w_s = jnp.exp(b_last[:, None] - b + i32 - m_new[:, None]) \
        .transpose(0, 2, 1)                                # [B,H,L]
    C_new = C * decay_state[..., None, None] + jnp.einsum(
        "bhtd,bhte->bhde", kf * w_s[..., None], vf)
    n_new = n * decay_state[..., None] + (kf * w_s[..., None]).sum(2)
    return (C_new, n_new, m_new), h.transpose(0, 2, 1, 3)  # [B,L,H,dh]


def mlstm_apply(p, x, *, cfg: ModelConfig, state=None, unroll: bool = False):
    """Full block. x: [B,T,d]. Returns (out [B,T,d], new_state | None)."""
    B, T, d = x.shape
    inner = _inner(cfg)
    H = cfg.n_heads
    dh = inner // H

    if state is not None and T == 1:
        return _mlstm_decode(p, x, cfg, state)

    conv_state = state["conv"] if state is not None else None
    q, k, v, ig, fg, z, new_conv = _mlstm_qkv_gates(p, x, cfg, conv_state)

    L = CHUNK
    while T % L:
        L //= 2
    nc = T // L
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def chunked(a):
        return a.reshape(B, nc, L, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))

    xs = tuple(chunked(a) for a in (q, k, v, ig, fg))
    if unroll:  # measurement mode: cost_analysis sees every chunk
        carry = (C0, n0, m0)
        hs = []
        for ci in range(nc):
            carry, h_c = _mlstm_chunk(carry, tuple(a[ci] for a in xs))
            hs.append(h_c)
        C, n, m = carry
        hs = jnp.stack(hs, 0)
    else:
        (C, n, m), hs = lax.scan(_mlstm_chunk, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, T, inner)

    h = common.rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["down"]
    new_state = None
    if state is not None:
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    return out, new_state


def _mlstm_decode(p, x, cfg: ModelConfig, state):
    """O(1) recurrent step. x: [B,1,d]."""
    B = x.shape[0]
    inner = _inner(cfg)
    H = cfg.n_heads
    dh = inner // H
    q, k, v, ig, fg, z, new_conv = _mlstm_qkv_gates(p, x, cfg, state["conv"])
    q, k, v = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # [B,H,dh]
    ig, fg = ig[:, 0].astype(jnp.float32), fg[:, 0].astype(jnp.float32)
    C, n, m = state["C"], state["n"], state["m"]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    fprime = jnp.exp(logf + m - m_new)[..., None]
    iprime = jnp.exp(ig - m_new)[..., None]
    C_new = C * fprime[..., None] + iprime[..., None] * (
        k[..., :, None] * v[..., None, :])
    n_new = n * fprime + iprime * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, inner)
    h = common.rms_norm(h.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["down"]
    return out, {"C": C_new, "n": n_new, "m": m_new, "conv": new_conv}


def mlstm_sequential_oracle(p, x, *, cfg: ModelConfig):
    """Step-by-step reference (test oracle for the chunkwise form)."""
    B, T, d = x.shape
    state = {k: jnp.zeros(s.shape, s.dtype) if k != "m" else
             jnp.full(s.shape, -1e30, s.dtype)
             for k, s in mlstm_state_shapes(cfg, B).items()}
    outs = []
    for t in range(T):
        o, state = _mlstm_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_ffn_width(cfg: ModelConfig) -> int:
    return common.round_up(int(cfg.d_model * cfg.slstm_proj_factor), 128)


def slstm_shapes(cfg: ModelConfig) -> dict:
    pd = cfg.param_dtype
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    out = {}
    for g in "ifzo":
        out[f"w_{g}"] = sds((d, d), pd)
        out[f"r_{g}"] = sds((h, hd, hd), pd)  # block-diagonal recurrence
        out[f"b_{g}"] = sds((d,), jnp.float32)
    f = slstm_ffn_width(cfg)
    out["ffn"] = {"wi": sds((d, f), pd), "wg": sds((d, f), pd),
                  "wo": sds((f, d), pd)}
    out["out_norm"] = sds((d,), pd)
    return out


def slstm_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": sds((batch, d), jnp.float32),
        "n": sds((batch, d), jnp.float32),
        "m": sds((batch, d), jnp.float32),
        "h": sds((batch, d), jnp.float32),
    }


def _slstm_step(p, cfg, carry, x_t):
    """x_t: [B,d] fp32 pre-activations W x (4 gates stacked)."""
    c, n, m, h = carry
    H = cfg.n_heads
    d = cfg.d_model
    hd = d // H

    def rec(name, hh):
        hb = hh.reshape(hh.shape[0], H, hd)
        return jnp.einsum("bhi,hio->bho", hb, p[f"r_{name}"].astype(jnp.float32)
                          ).reshape(hh.shape[0], d)

    xi, xf, xz, xo = jnp.split(x_t, 4, axis=-1)
    itilde = xi + rec("i", h) + p["b_i"]
    ftilde = xf + rec("f", h) + p["b_f"]
    z = jnp.tanh(xz + rec("z", h) + p["b_z"])
    o = jax.nn.sigmoid(xo + rec("o", h) + p["b_o"])
    logf = jax.nn.log_sigmoid(ftilde)
    m_new = jnp.maximum(logf + m, itilde)
    iprime = jnp.exp(itilde - m_new)
    fprime = jnp.exp(logf + m - m_new)
    c_new = fprime * c + iprime * z
    n_new = fprime * n + iprime
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_apply(p, x, *, cfg: ModelConfig, state=None):
    """x: [B,T,d] -> (out, new_state | None). Sequential scan over T."""
    B, T, d = x.shape
    xf = x.astype(jnp.float32)
    pre = jnp.concatenate(
        [xf @ p[f"w_{g}"].astype(jnp.float32) for g in "ifzo"], axis=-1)
    if state is None:
        carry = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
                 jnp.full((B, d), -1e30, jnp.float32),
                 jnp.zeros((B, d), jnp.float32))
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])
    carry, hs = lax.scan(lambda cr, xt: _slstm_step(p, cfg, cr, xt),
                         carry, pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)  # [B,T,d]
    h = common.rms_norm(h, p["out_norm"], cfg.norm_eps)
    ffn = p["ffn"]
    out = (jax.nn.gelu(h @ ffn["wg"], approximate=True) * (h @ ffn["wi"])) \
        @ ffn["wo"]
    new_state = None
    if state is not None:
        c, n, m, hh = carry
        new_state = {"c": c, "n": n, "m": m, "h": hh}
    return out, new_state
