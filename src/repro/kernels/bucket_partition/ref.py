"""Oracles for the bucket partitioner and the device scatter.

Deliberately independent of the kernels' word-by-word compare: each
k-word row is folded into one arbitrary-precision Python int (big-endian
word order), then bucket id = #{bounds < key} via bisect — the same
strict rule the bytes-path partitioners implement.  The scatter oracle
adds numpy's stable argsort over those ids, which is the definition of
the kernel's stability guarantee (same-bucket records keep input order).
"""
from __future__ import annotations

from bisect import bisect_left

import numpy as np

import jax.numpy as jnp


def _row_ints(a: np.ndarray) -> list:
    if a.ndim == 1:
        a = a[:, None]
    k = a.shape[1]
    return [sum(int(row[w]) << (32 * (k - 1 - w)) for w in range(k))
            for row in a]


def bucket_partition_ref(keys, bounds, n_buckets: int):
    """(ids, hist) — the oracle for :func:`bucket_partition`."""
    bi = _row_ints(np.asarray(bounds))
    ids = np.array([bisect_left(bi, v) for v in _row_ints(np.asarray(keys))],
                   dtype=np.int32)
    hist = np.bincount(ids, minlength=n_buckets).astype(np.int32)
    return jnp.asarray(ids), jnp.asarray(hist)


def bucket_scatter_ref(data, keys, bounds, n_buckets: int):
    """(out, hist) — the oracle for :func:`bucket_scatter`.

    ``data [N, width]`` records reordered bucket-contiguously by a
    *stable* argsort of the oracle bucket ids (clamped to ``n_buckets -
    1`` like the kernel / the bytes reference's ``min(lo, n - 1)``).
    No shape padding here: callers compare against ``out[:N]`` of the
    kernel result with ``n_valid = N``.
    """
    data = np.asarray(data)
    bi = _row_ints(np.asarray(bounds))
    ids = np.array([min(bisect_left(bi, v), n_buckets - 1)
                    for v in _row_ints(np.asarray(keys))], dtype=np.int32)
    order = np.argsort(ids, kind="stable")
    hist = np.bincount(ids, minlength=n_buckets).astype(np.int32)
    return jnp.asarray(data[order]), jnp.asarray(hist)
