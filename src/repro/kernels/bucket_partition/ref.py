"""Oracle for the bucket partitioner.

Independent of the kernel's word-by-word compare: each k-word row is
folded into one arbitrary-precision Python int (big-endian word order),
then bucket id = #{bounds < key} via bisect — the same strict rule the
bytes-path partitioners implement.
"""
from __future__ import annotations

from bisect import bisect_left

import numpy as np

import jax.numpy as jnp


def _row_ints(a: np.ndarray) -> list:
    if a.ndim == 1:
        a = a[:, None]
    k = a.shape[1]
    return [sum(int(row[w]) << (32 * (k - 1 - w)) for w in range(k))
            for row in a]


def bucket_partition_ref(keys, bounds, n_buckets: int):
    bi = _row_ints(np.asarray(bounds))
    ids = np.array([bisect_left(bi, v) for v in _row_ints(np.asarray(keys))],
                   dtype=np.int32)
    hist = np.bincount(ids, minlength=n_buckets).astype(np.int32)
    return jnp.asarray(ids), jnp.asarray(hist)
