"""Oracle for the bucket partitioner."""
from __future__ import annotations

import jax.numpy as jnp


def bucket_partition_ref(keys, bounds, n_buckets: int):
    ids = jnp.searchsorted(bounds, keys, side="right").astype(jnp.int32)
    hist = jnp.bincount(ids, length=n_buckets).astype(jnp.int32)
    return ids, hist
