"""Bucket partition kernel — the TeraSort range-partitioner hot loop.

Given sorted boundaries (the sampled splitters), computes each key's bucket
id and a per-bucket histogram. Keys and boundaries are rows of k big-endian
uint32 words compared lexicographically — k = 1 is the classic single-word
case, 10-byte TeraSort keys use k = 3 — so arbitrary-length byte prefixes
partition on the kernel path. Bucket id = #boundaries < key, computed as a
word-by-word vectorised comparison against the boundary table pinned in
VMEM (k is static, the word loop unrolls at trace time); the histogram
accumulates in the output ref across the sequentially-executed grid (TPU
grid semantics), so no host-side reduction is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, bounds_ref, ids_ref, hist_ref, *, n_buckets: int,
            n_valid: int, bn: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    keys = keys_ref[...]                        # [bn, k] uint32
    bounds = bounds_ref[...]                    # [n_buckets-1, k]
    k = keys.shape[1]
    # lexicographic bounds[j] < keys[r]: scan words while prefixes tie
    lt = jnp.zeros((bn, n_buckets - 1), jnp.bool_)
    eq = jnp.ones((bn, n_buckets - 1), jnp.bool_)
    for w in range(k):
        kw = keys[:, w][:, None]                # [bn, 1]
        bw = bounds[:, w][None, :]              # [1, n_buckets-1]
        lt = lt | (eq & (bw < kw))
        eq = eq & (bw == kw)
    ids = jnp.sum(lt.astype(jnp.int32), axis=1)  # [bn]
    # mask padded tail keys into bucket 0 with zero histogram weight
    pos = i * bn + jax.lax.iota(jnp.int32, bn)
    valid = pos < n_valid
    ids = jnp.where(valid, ids, 0)
    ids_ref[...] = ids.astype(jnp.int32)
    onehot = (ids[:, None] == jax.lax.iota(jnp.int32, n_buckets)[None, :])
    counts = jnp.sum(jnp.where(valid[:, None], onehot, False)
                     .astype(jnp.int32), axis=0)
    hist_ref[...] = hist_ref[...] + counts


def bucket_partition_call(keys: jax.Array, bounds: jax.Array, *,
                          n_buckets: int, block_n: int = 2048,
                          interpret: bool = False):
    """keys: [N] or [N, k] uint32; bounds: [n_buckets-1] or [n_buckets-1, k]
    uint32 rows, sorted lexicographically.

    Returns (ids [N] int32, hist [n_buckets] int32)."""
    if keys.ndim == 1:
        keys = keys[:, None]
    if bounds.ndim == 1:
        bounds = bounds[:, None]
    if keys.shape[1] != bounds.shape[1]:
        raise ValueError(f"keys have {keys.shape[1]} words per row but "
                         f"bounds have {bounds.shape[1]}")
    N, k = keys.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    nb = keys.shape[0] // bn

    kern = functools.partial(_kernel, n_buckets=n_buckets, n_valid=N, bn=bn)
    ids, hist = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((n_buckets - 1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_buckets,), lambda i: (0,)),  # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, bounds)
    return ids[:N], hist
