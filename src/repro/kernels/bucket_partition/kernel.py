"""Bucket partition + device scatter kernels — the TeraSort shuffle hot loop.

Two Pallas entry points share one comparison contract:

* :func:`bucket_partition_call` — bucket ids + per-bucket histogram (the
  original analysis pass; ids are returned to the caller).
* :func:`bucket_scatter_call` — the device-resident shuffle: ids, per-block
  histograms and intra-block stable ranks in one kernel pass, then a pure
  device epilogue (exclusive scans + one scatter) that lands the records in
  bucket-contiguous order.  Bucket ids never reach the host; the only value
  a caller needs to sync is the final [n_buckets] histogram.

**Comparison contract (both kernels).**  Keys and boundaries are rows of
``k`` big-endian uint32 words compared lexicographically — ``k = 1`` is the
classic single-word case, 10-byte TeraSort keys use ``k = 3``.  ``k`` is
static, so the word loop unrolls at trace time into ``k`` vectorised
compares against the boundary table pinned in VMEM.  When boundary byte
lengths vary, callers append a trailing *length word* to both keys and
boundaries (see ``RecordBatch.key_words``): zero-padded words can tie where
the byte strings differ, and the length word reproduces Python's
shorter-prefix-sorts-first ``bytes`` ordering exactly.  The bucket rule is
strict: ``id = #{j : bounds[j] < key}``, clamped to ``n_out - 1`` when the
boundary table implies more buckets than the caller wants (mirroring the
bytes reference's ``min(lo, n - 1)``).

**Stability guarantee (scatter).**  Grid blocks execute in input order and
the intra-block rank is a prefix count over the block's rows, so two
records in the same bucket keep their input order in the scattered output
— exactly the bytes backend's append order.  Rows at positions >=
``n_valid`` (shape padding) are routed to a trash bucket *after* every
real bucket, so the first ``sum(hist)`` output rows are the real records.

**Block shapes / VMEM.**  A grid step holds ``[bn, k]`` uint32 keys, the
``[n_bounds, k]`` boundary table, the boolean compare state ``[bn,
n_bounds]``, and (scatter only) the one-hot running count ``[bn, n_out +
1]`` int32 — roughly ``bn * (4k + n_bounds + 4 * n_out)`` bytes live at
once.  On a real accelerator keep that under VMEM (~16 MB/core): ``bn =
2048`` with 3-word keys and <= 64 buckets uses well under 1 MB.  In
interpret mode (CPU CI) every grid step pays a Python interpreter pass, so
callers use ONE block (``bn = n``) — that is what the ``ops.py`` wrappers
default to per backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_ids(keys, bounds):
    """Strict lexicographic bucket ids: ``#{j : bounds[j] < keys[r]}``.

    ``keys [bn, k]`` vs ``bounds [n_bounds, k]`` word rows; scans words
    while prefixes tie (the loop is over static k, so it unrolls).
    """
    bn, k = keys.shape
    n_bounds = bounds.shape[0]
    lt = jnp.zeros((bn, n_bounds), jnp.bool_)
    eq = jnp.ones((bn, n_bounds), jnp.bool_)
    for w in range(k):
        kw = keys[:, w][:, None]                # [bn, 1]
        bw = bounds[:, w][None, :]              # [1, n_bounds]
        lt = lt | (eq & (bw < kw))
        eq = eq & (bw == kw)
    return jnp.sum(lt.astype(jnp.int32), axis=1)  # [bn]


def _kernel(keys_ref, bounds_ref, ids_ref, hist_ref, *, n_buckets: int,
            n_valid: int, bn: int):
    """Analysis pass: ids + one accumulated histogram.

    The histogram accumulates in the output ref across the sequentially-
    executed grid (TPU grid semantics), so no host-side reduction is
    needed.  Padded tail keys (positions >= n_valid) land in bucket 0
    with zero histogram weight.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ids = _compare_ids(keys_ref[...], bounds_ref[...])
    pos = i * bn + jax.lax.iota(jnp.int32, bn)
    valid = pos < n_valid
    ids = jnp.where(valid, ids, 0)
    ids_ref[...] = ids.astype(jnp.int32)
    onehot = (ids[:, None] == jax.lax.iota(jnp.int32, n_buckets)[None, :])
    counts = jnp.sum(jnp.where(valid[:, None], onehot, False)
                     .astype(jnp.int32), axis=0)
    hist_ref[...] = hist_ref[...] + counts


def bucket_partition_call(keys: jax.Array, bounds: jax.Array, *,
                          n_buckets: int, block_n: int = 2048,
                          interpret: bool = False):
    """keys: [N] or [N, k] uint32; bounds: [n_buckets-1] or [n_buckets-1, k]
    uint32 rows, sorted lexicographically.

    Returns (ids [N] int32, hist [n_buckets] int32)."""
    if keys.ndim == 1:
        keys = keys[:, None]
    if bounds.ndim == 1:
        bounds = bounds[:, None]
    if keys.shape[1] != bounds.shape[1]:
        raise ValueError(f"keys have {keys.shape[1]} words per row but "
                         f"bounds have {bounds.shape[1]}")
    N, k = keys.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    nb = keys.shape[0] // bn

    kern = functools.partial(_kernel, n_buckets=n_buckets, n_valid=N, bn=bn)
    ids, hist = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((n_buckets - 1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_buckets,), lambda i: (0,)),  # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, bounds)
    return ids[:N], hist


def _scatter_kernel(nvalid_ref, keys_ref, bounds_ref, ids_ref, rank_ref,
                    bhist_ref, *, n_out: int, bn: int):
    """Scatter pass: per-block ids, intra-block stable ranks, block hists.

    Unlike :func:`_kernel`, ``n_valid`` arrives as a *dynamic* scalar
    input, so one trace serves every record count at a fixed padded
    shape — the property that keeps the engine path compile-once.
    Padded rows (position >= n_valid) get id ``n_out`` (the trash bucket
    ordered after every real bucket); real ids are clamped to
    ``n_out - 1`` when the boundary table implies more buckets.

    The intra-block rank is a same-bucket prefix count: with ``csum`` the
    inclusive running one-hot count, ``rank[r] = csum[r, ids[r]] - 1``
    (computed as an elementwise masked sum — no gather inside the
    kernel).  ``bhist_ref`` gets this block's [1, n_out + 1] bucket
    counts; the epilogue turns block hists into global offsets.
    """
    i = pl.program_id(0)
    raw = _compare_ids(keys_ref[...], bounds_ref[...])
    ids = jnp.minimum(raw, n_out - 1)
    pos = i * bn + jax.lax.iota(jnp.int32, bn)
    ids = jnp.where(pos < nvalid_ref[0], ids, n_out)
    onehot = (ids[:, None]
              == jax.lax.iota(jnp.int32, n_out + 1)[None, :]).astype(jnp.int32)
    csum = jnp.cumsum(onehot, axis=0)           # inclusive running count
    ids_ref[...] = ids
    rank_ref[...] = jnp.sum(onehot * (csum - 1), axis=1)
    bhist_ref[...] = csum[-1:, :]


def bucket_scatter_call(data: jax.Array, keys: jax.Array, bounds: jax.Array,
                        n_valid, *, n_out: int, block_n: int = 2048,
                        interpret: bool = False):
    """Device-resident bucketed scatter (stable counting scatter).

    ``data``: [N, width] uint8 records; ``keys``: [N] or [N, k] uint32 key
    rows for the same records; ``bounds``: [n_bounds] or [n_bounds, k]
    sorted boundary rows; ``n_valid``: how many leading rows are real
    (the rest are shape padding and scatter to the tail).

    Returns ``(out [N, width] uint8, hist [n_out] int32)`` where
    ``out[:hist.sum()]`` holds the real records in bucket-contiguous,
    input-stable order — bucket ``b`` occupies rows
    ``[sum(hist[:b]), sum(hist[:b+1]))``.  Everything stays on device;
    the caller decides when (if ever) to sync ``hist``.

    The destination index of record ``r`` in block ``i`` with bucket
    ``b`` is ``bucket_start[b] + count of b in blocks < i +
    intra-block rank`` — the classic three-level exclusive-scan scatter,
    with the two outer scans (over buckets and over blocks) done by the
    XLA epilogue on the kernel's per-block histograms.
    """
    if keys.ndim == 1:
        keys = keys[:, None]
    if bounds.ndim == 1:
        bounds = bounds[:, None]
    if keys.shape[1] != bounds.shape[1]:
        raise ValueError(f"keys have {keys.shape[1]} words per row but "
                         f"bounds have {bounds.shape[1]}")
    if data.shape[0] != keys.shape[0]:
        raise ValueError(f"data has {data.shape[0]} rows but keys have "
                         f"{keys.shape[0]}")
    N, k = keys.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:  # rows past n_valid are trash-bucketed, so padding is benign
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0)))
    Np = keys.shape[0]
    nb = Np // bn
    nv = jnp.asarray(n_valid, jnp.int32).reshape((1,))

    kern = functools.partial(_scatter_kernel, n_out=n_out, bn=bn)
    ids, rank, bhist = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bounds.shape[0], k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, n_out + 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((nb, n_out + 1), jnp.int32),
        ],
        interpret=interpret,
    )(nv, keys, bounds)

    # device epilogue: two exclusive scans -> destination index -> move.
    # The move inverts the destination permutation with a cheap [Np]
    # int32 scatter, then gathers the wide uint8 rows: XLA lowers the
    # row gather several times faster than the equivalent row scatter.
    total = jnp.sum(bhist, axis=0)              # [n_out + 1]
    starts = jnp.cumsum(total) - total          # exclusive bucket starts
    blk_excl = jnp.cumsum(bhist, axis=0) - bhist  # [nb, n_out + 1]
    block_of = jax.lax.iota(jnp.int32, Np) // bn
    dest = starts[ids] + blk_excl[block_of, ids] + rank
    perm = jnp.zeros((Np,), jnp.int32).at[dest].set(
        jax.lax.iota(jnp.int32, Np), unique_indices=True)
    out = jnp.take(data, perm, axis=0)
    return out[:N], total[:n_out]
