"""Bucket partition + device scatter kernels — the TeraSort shuffle hot loop.

Two Pallas entry points share one comparison contract:

* :func:`bucket_partition_call` — bucket ids + per-bucket histogram (the
  original analysis pass; ids are returned to the caller).
* :func:`bucket_scatter_call` — the device-resident shuffle: ids, per-block
  histograms and intra-block stable ranks in one kernel pass, then a pure
  device epilogue (exclusive scans + one scatter) that lands the records in
  bucket-contiguous order.  Bucket ids never reach the host; the only value
  a caller needs to sync is the final [n_buckets] histogram.

**Comparison contract (both kernels).**  Keys and boundaries are rows of
``k`` big-endian uint32 words compared lexicographically — ``k = 1`` is the
classic single-word case, 10-byte TeraSort keys use ``k = 3``.  ``k`` is
static, so the word loop unrolls at trace time into ``k`` vectorised
compares against the boundary table pinned in VMEM.  When boundary byte
lengths vary, callers append a trailing *length word* to both keys and
boundaries (see ``RecordBatch.key_words``): zero-padded words can tie where
the byte strings differ, and the length word reproduces Python's
shorter-prefix-sorts-first ``bytes`` ordering exactly.  The bucket rule is
strict: ``id = #{j : bounds[j] < key}``, clamped to ``n_out - 1`` when the
boundary table implies more buckets than the caller wants (mirroring the
bytes reference's ``min(lo, n - 1)``).

**Stability guarantee (scatter).**  Grid blocks execute in input order and
the intra-block rank is a prefix count over the block's rows, so two
records in the same bucket keep their input order in the scattered output
— exactly the bytes backend's append order.  Rows at positions >=
``n_valid`` (shape padding) are routed to a trash bucket *after* every
real bucket, so the first ``sum(hist)`` output rows are the real records.

**Block shapes / VMEM.**  A grid step holds ``[bn, k]`` uint32 keys, the
``[n_bounds, k]`` boundary table, the boolean compare state ``[bn,
n_bounds]``, and (scatter only) the one-hot running count ``[bn, n_out +
1]`` int32 — roughly ``bn * (4k + n_bounds + 4 * n_out)`` bytes live at
once.  On a real accelerator keep that under VMEM (~16 MB/core): ``bn =
2048`` with 3-word keys and <= 64 buckets uses well under 1 MB.  In
interpret mode (CPU CI) every grid step pays a Python interpreter pass, so
callers use ONE block (``bn = n``) — that is what the ``ops.py`` wrappers
default to per backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_ids(keys, bounds):
    """Strict lexicographic bucket ids: ``#{j : bounds[j] < keys[r]}``.

    ``keys [bn, k]`` vs ``bounds [n_bounds, k]`` word rows; scans words
    while prefixes tie (the loop is over static k, so it unrolls).
    """
    bn, k = keys.shape
    n_bounds = bounds.shape[0]
    lt = jnp.zeros((bn, n_bounds), jnp.bool_)
    eq = jnp.ones((bn, n_bounds), jnp.bool_)
    for w in range(k):
        kw = keys[:, w][:, None]                # [bn, 1]
        bw = bounds[:, w][None, :]              # [1, n_bounds]
        lt = lt | (eq & (bw < kw))
        eq = eq & (bw == kw)
    return jnp.sum(lt.astype(jnp.int32), axis=1)  # [bn]


def _kernel(keys_ref, bounds_ref, ids_ref, hist_ref, *, n_buckets: int,
            n_valid: int, bn: int):
    """Analysis pass: ids + one accumulated histogram.

    The histogram accumulates in the output ref across the sequentially-
    executed grid (TPU grid semantics), so no host-side reduction is
    needed.  Padded tail keys (positions >= n_valid) land in bucket 0
    with zero histogram weight.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ids = _compare_ids(keys_ref[...], bounds_ref[...])
    pos = i * bn + jax.lax.iota(jnp.int32, bn)
    valid = pos < n_valid
    ids = jnp.where(valid, ids, 0)
    ids_ref[...] = ids.astype(jnp.int32)
    onehot = (ids[:, None] == jax.lax.iota(jnp.int32, n_buckets)[None, :])
    counts = jnp.sum(jnp.where(valid[:, None], onehot, False)
                     .astype(jnp.int32), axis=0)
    hist_ref[...] = hist_ref[...] + counts


def bucket_partition_call(keys: jax.Array, bounds: jax.Array, *,
                          n_buckets: int, block_n: int = 2048,
                          interpret: bool = False):
    """keys: [N] or [N, k] uint32; bounds: [n_buckets-1] or [n_buckets-1, k]
    uint32 rows, sorted lexicographically.

    Returns (ids [N] int32, hist [n_buckets] int32)."""
    if keys.ndim == 1:
        keys = keys[:, None]
    if bounds.ndim == 1:
        bounds = bounds[:, None]
    if keys.shape[1] != bounds.shape[1]:
        raise ValueError(f"keys have {keys.shape[1]} words per row but "
                         f"bounds have {bounds.shape[1]}")
    N, k = keys.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    nb = keys.shape[0] // bn

    kern = functools.partial(_kernel, n_buckets=n_buckets, n_valid=N, bn=bn)
    ids, hist = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((n_buckets - 1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_buckets,), lambda i: (0,)),  # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((keys.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, bounds)
    return ids[:N], hist


def _scatter_kernel(valid_ref, keys_ref, bounds_ref, ids_ref, rank_ref,
                    bhist_ref, *, n_out: int, bn: int):
    """Scatter pass: per-block ids, intra-block stable ranks, block hists.

    Unlike :func:`_kernel`, validity arrives as a *dynamic* [bn] int32
    mask input, so one trace serves every record count (and any
    interleaving of padding — e.g. several resident pieces stacked with
    their junk tails in place) at a fixed padded shape — the property
    that keeps the engine path compile-once.  Masked rows get id
    ``n_out`` (the trash bucket ordered after every real bucket); real
    ids are clamped to ``n_out - 1`` when the boundary table implies
    more buckets.

    The intra-block rank is a same-bucket prefix count: with ``csum`` the
    inclusive running one-hot count, ``rank[r] = csum[r, ids[r]] - 1``
    (computed as an elementwise masked sum — no gather inside the
    kernel).  ``bhist_ref`` gets this block's [1, n_out + 1] bucket
    counts; the epilogue turns block hists into global offsets.
    """
    raw = _compare_ids(keys_ref[...], bounds_ref[...])
    ids = jnp.minimum(raw, n_out - 1)
    ids = jnp.where(valid_ref[...] != 0, ids, n_out)
    onehot = (ids[:, None]
              == jax.lax.iota(jnp.int32, n_out + 1)[None, :]).astype(jnp.int32)
    # inclusive running count — associative_scan's log-depth ladder beats
    # XLA's sequential cumsum lowering ~1.5x on the [bn, n_out + 1] shape
    csum = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    ids_ref[...] = ids
    rank_ref[...] = jnp.sum(onehot * (csum - 1), axis=1)
    bhist_ref[...] = csum[-1:, :]


def bucket_dest_call(keys: jax.Array, bounds: jax.Array, n_valid, *,
                     n_out: int, block_n: int = 2048,
                     interpret: bool = False):
    """Destination indices + histogram of the stable counting scatter.

    ``keys``: [N] or [N, k] uint32 key rows; ``bounds``: [n_bounds] or
    [n_bounds, k] sorted boundary rows; ``n_valid``: either a dynamic
    scalar (the leading ``n_valid`` rows are real, the rest shape
    padding) or a dynamic [N] int32/bool mask marking real rows
    anywhere in the batch (stacked resident pieces keep their junk
    tails in place) — masked-out rows go to the trash bucket after
    every real bucket either way.

    Returns ``(dest [Np] int32, hist [n_out] int32)`` where ``Np`` is
    ``N`` rounded up to a ``block_n`` multiple and ``dest[r]`` is the
    bucket-contiguous, input-stable output position of row ``r`` —
    ``dest`` is a permutation of ``[0, Np)`` with every valid row landing
    below ``hist.sum()``.  The destination of record ``r`` in block ``i``
    with bucket ``b`` is ``bucket_start[b] + count of b in blocks < i +
    intra-block rank`` — the classic three-level exclusive-scan scatter,
    with the two outer scans (over buckets and over blocks) done by the
    XLA epilogue on the kernel's per-block histograms.  This is the
    data-free half of :func:`bucket_scatter_call`; callers that can move
    the rows more cheaply themselves (e.g. a host-side permutation
    inversion on CPU) stop here.
    """
    if keys.ndim == 1:
        keys = keys[:, None]
    if bounds.ndim == 1:
        bounds = bounds[:, None]
    if keys.shape[1] != bounds.shape[1]:
        raise ValueError(f"keys have {keys.shape[1]} words per row but "
                         f"bounds have {bounds.shape[1]}")
    N, k = keys.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:  # masked-out rows are trash-bucketed, so padding is benign
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    Np = keys.shape[0]
    nb = Np // bn
    nv = jnp.asarray(n_valid)
    if nv.ndim == 0:       # scalar count -> prefix-validity mask
        valid = (jax.lax.iota(jnp.int32, Np)
                 < nv.astype(jnp.int32)).astype(jnp.int32)
    else:
        if nv.shape[0] != N:
            raise ValueError(f"validity mask has {nv.shape[0]} rows but "
                             f"keys have {N}")
        valid = nv.astype(jnp.int32)
        if pad:
            valid = jnp.pad(valid, (0, pad))

    kern = functools.partial(_scatter_kernel, n_out=n_out, bn=bn)
    ids, rank, bhist = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bounds.shape[0], k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, n_out + 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((nb, n_out + 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, keys, bounds)

    total = jnp.sum(bhist, axis=0)              # [n_out + 1]
    starts = jnp.cumsum(total) - total          # exclusive bucket starts
    if nb == 1:
        # single grid block (the CPU/interpret default): the inter-block
        # exclusive scan is identically zero, so skip its 2-D gather
        dest = starts[ids] + rank
    else:
        blk_excl = jnp.cumsum(bhist, axis=0) - bhist  # [nb, n_out + 1]
        block_of = jax.lax.iota(jnp.int32, Np) // bn
        dest = starts[ids] + blk_excl[block_of, ids] + rank
    return dest, total[:n_out]


def bucket_scatter_call(data: jax.Array, keys: jax.Array, bounds: jax.Array,
                        n_valid, *, n_out: int, block_n: int = 2048,
                        interpret: bool = False):
    """Device-resident bucketed scatter (stable counting scatter).

    ``data``: [N, width] uint8 records; ``keys``: [N] or [N, k] uint32 key
    rows for the same records; ``bounds``: [n_bounds] or [n_bounds, k]
    sorted boundary rows; ``n_valid``: how many leading rows are real
    (the rest are shape padding and scatter to the tail).

    Returns ``(out [N, width] uint8, hist [n_out] int32)`` where
    ``out[:hist.sum()]`` holds the real records in bucket-contiguous,
    input-stable order — bucket ``b`` occupies rows
    ``[sum(hist[:b]), sum(hist[:b+1]))``.  Everything stays on device;
    the caller decides when (if ever) to sync ``hist``.

    Destination indices come from :func:`bucket_dest_call`; the move
    here inverts the destination permutation with a [Np] int32 scatter,
    then gathers the wide uint8 rows (XLA lowers the row gather several
    times faster than the equivalent row scatter).
    """
    if keys.ndim == 1:
        keys = keys[:, None]
    if data.shape[0] != keys.shape[0]:
        raise ValueError(f"data has {data.shape[0]} rows but keys have "
                         f"{keys.shape[0]}")
    N = data.shape[0]
    dest, hist = bucket_dest_call(keys, bounds, n_valid, n_out=n_out,
                                  block_n=block_n, interpret=interpret)
    Np = dest.shape[0]
    if Np != N:
        data = jnp.pad(data, ((0, Np - N), (0, 0)))
    perm = jnp.zeros((Np,), jnp.int32).at[dest].set(
        jax.lax.iota(jnp.int32, Np), unique_indices=True)
    out = jnp.take(data, perm, axis=0)
    return out[:N], hist
