from __future__ import annotations

from functools import partial

import jax

from repro.kernels.bucket_partition.kernel import bucket_partition_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_buckets", "block_n", "interpret"))
def bucket_partition(keys, bounds, *, n_buckets: int, block_n: int = 2048,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return bucket_partition_call(keys, bounds, n_buckets=n_buckets,
                                 block_n=block_n, interpret=interpret)
