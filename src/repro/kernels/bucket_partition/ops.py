"""jit entry points for the bucket-partition kernels.

Both wrappers pick interpret mode by backend (compiled lowering on real
accelerators — TPU via Mosaic, GPU via Triton — interpret on CPU) and
choose a backend-appropriate block shape when the caller doesn't:

* **interpret (CPU CI)** — every grid step pays a Python interpreter
  pass, so the default is ONE block covering the whole batch; the
  vectorised jaxpr runs once.
* **real accelerator** — ``block_n = 2048`` keeps a grid step's live set
  (keys ``[bn, k]`` uint32, compare state ``[bn, n_bounds]`` bool, and
  for the scatter the one-hot running count ``[bn, n_out + 1]`` int32)
  comfortably inside VMEM for 3-word TeraSort keys and <= 64 buckets.

``bucket_scatter`` takes ``n_valid`` as a *dynamic* argument — callers
pad batches to a fixed shape (e.g. a power-of-two row count) and one
trace serves every record count at that shape.  That is what closes the
engine/kernel throughput gap: the engine's per-worker batch sizes vary
per job, and before this the shuffle re-traced per distinct size.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.bucket_partition.kernel import (bucket_dest_call,
                                                   bucket_partition_call,
                                                   bucket_scatter_call)

# VMEM-conscious default block rows for real-accelerator lowering (see
# module docstring); interpret mode uses one whole-batch block instead.
ACCEL_BLOCK_N = 2048


def _compiled_backend() -> bool:
    """True when the default backend gets the compiled Pallas lowering
    (TPU Mosaic, GPU Triton); CPU stays in interpret mode."""
    return jax.default_backend() in ("tpu", "gpu")


@partial(jax.jit, static_argnames=("n_buckets", "block_n", "interpret"))
def bucket_partition(keys, bounds, *, n_buckets: int, block_n: int = 2048,
                     interpret: bool | None = None):
    """(ids [N] int32, hist [n_buckets] int32) for uint32 key rows.

    See :func:`bucket_partition_call` for the comparison contract.
    """
    if interpret is None:
        interpret = not _compiled_backend()
    return bucket_partition_call(keys, bounds, n_buckets=n_buckets,
                                 block_n=block_n, interpret=interpret)


@partial(jax.jit, static_argnames=("n_buckets", "block_n", "interpret"))
def bucket_scatter(data, keys, bounds, n_valid, *, n_buckets: int,
                   block_n: int | None = None,
                   interpret: bool | None = None):
    """Device-resident stable scatter into bucket-contiguous order.

    ``data [N, width] uint8`` records with ``keys [N(, k)] uint32`` rows;
    rows at positions >= ``n_valid`` (dynamic) are shape padding and land
    after every real bucket.  Returns ``(out [N, width], hist
    [n_buckets])`` — see :func:`bucket_scatter_call`.  Bucket ids never
    exist host-side; sync ``hist`` once to learn the bucket boundaries.
    """
    if interpret is None:
        interpret = not _compiled_backend()
    if block_n is None:
        block_n = data.shape[0] if interpret else ACCEL_BLOCK_N
    return bucket_scatter_call(data, keys, bounds, n_valid,
                               n_out=n_buckets, block_n=block_n,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("n_buckets", "block_n", "interpret"))
def bucket_dest(keys, bounds, n_valid, *, n_buckets: int,
                block_n: int | None = None,
                interpret: bool | None = None):
    """Scatter destinations without moving any data.

    Returns ``(dest [Np] int32, hist [n_buckets] int32)`` — the stable
    bucket-contiguous output position of every key row, padded rows
    included (see :func:`bucket_dest_call`).  For callers that invert
    the permutation and move rows themselves — on CPU a host-side numpy
    inversion runs at memcpy speed where XLA's [Np] scatter crawls at
    ~40ns/element, which is why the CPU shuffle path stops here.
    """
    if interpret is None:
        interpret = not _compiled_backend()
    if block_n is None:
        block_n = keys.shape[0] if interpret else ACCEL_BLOCK_N
    return bucket_dest_call(keys, bounds, n_valid, n_out=n_buckets,
                            block_n=block_n, interpret=interpret)
