from repro.kernels.bucket_partition.ops import (bucket_dest,  # noqa: F401
                                                bucket_partition,
                                                bucket_scatter)
from repro.kernels.bucket_partition.ref import (bucket_partition_ref,  # noqa: F401
                                                bucket_scatter_ref)
