"""jit'd public wrapper: model layout [B,T,H,D] <-> kernel layout."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_hm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, T, H, D]; k, v: [B, S, K, D] (GQA: H = K * group).

    On non-TPU backends the kernel body runs in interpret mode (CPU
    validation); on TPU it lowers to Mosaic.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    out = flash_attention_hm(qh, kh, vh, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(B, H, T, D).transpose(0, 2, 1, 3)
