"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [BHq, T, d]; k, v: [BHk, S, d]; GQA by head-group repetition."""
    bhq, T, d = q.shape
    bhk, S, _ = k.shape
    g = bhq // bhk
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    tpos = jnp.arange(T)[:, None]
    spos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= spos <= tpos
    if window:
        mask &= tpos - spos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
