"""Flash attention TPU kernel (pl.pallas_call + explicit VMEM BlockSpecs).

TPU-native adaptation (DESIGN.md §2): instead of a CUDA warp-level design,
tiling is chosen for the MXU (128-aligned [bq, d] x [d, bk] matmuls) and the
VMEM hierarchy: each grid step holds one q tile, one kv tile and the fp32
softmax state (m, l, acc) in VMEM scratch that persists across the innermost
(kv) grid dimension — TPU grids execute sequentially over the last axis, so
the scratch implements the online-softmax recurrence without HBM traffic.

Grid: (batch*heads, T/bq, S/bk). Causal and sliding-window masks are applied
in-kernel; fully-masked kv tiles are skipped with pl.when (no MXU work).

Supports GQA natively: the kv head index map collapses the query-group dim,
so k/v tiles are fetched once per kv head, not per q head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = qi * bq            # first query position of this tile
    q_last = q_first + bq - 1
    k_first = ki * bk
    k_last = k_first + bk - 1

    live = True
    if causal:
        live = k_first <= q_last                   # not strictly future
    if window:
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * alpha + p.sum(axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_hm(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Head-major flash attention.

    q: [BHq, T, d]; k, v: [BHk, S, d] with BHq = BHk * group.
    """
    bhq, seq_q, d = q.shape
    bhk, seq_k, _ = k.shape
    assert bhq % bhk == 0
    group = bhq // bhk
    bq = min(block_q, seq_q)
    bk = min(block_k, seq_k)
    # pad sequences up to tile multiples (masked in-kernel via seq_k bound)
    pq = (-seq_q) % bq
    pk = (-seq_k) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = q.shape[1] // bq
    nk = k.shape[1] // bk

    kern = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        bq=bq, bk=bk, seq_q=seq_q, seq_k=seq_k)

    out = pl.pallas_call(
        kern,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, q.shape[1], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max  m
            pltpu.VMEM((bq,), jnp.float32),      # running sum  l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :seq_q]
    return out
