"""Oracle for the k-means assignment kernel."""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(x, c):
    d2 = (jnp.sum(x.astype(jnp.float32)**2, 1)[:, None]
          - 2 * x.astype(jnp.float32) @ c.astype(jnp.float32).T
          + jnp.sum(c.astype(jnp.float32)**2, 1)[None])
    return jnp.argmin(d2, 1).astype(jnp.int32), jnp.min(d2, 1)
