"""k-means assignment kernel (the Angle/Sphere hot loop, paper §5.3).

Computes nearest-centroid ids and distances for a block of points. The
centroid table [K, D] stays pinned in VMEM across the whole grid while point
tiles stream through; distances use the MXU via the -2*x@c^T expansion:

    d2(x, c) = |x|^2 - 2 x.c + |c|^2.

Grid: (N / bn,). Outputs per point: argmin id (int32) and min distance.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, ids_ref, d2_ref):
    x = x_ref[...].astype(jnp.float32)          # [bn, D]
    c = c_ref[...].astype(jnp.float32)          # [K, D]
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # [bn, 1]
    cc = jnp.sum(c * c, axis=1)[None, :]        # [1, K]
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = xx - 2.0 * xc + cc                     # [bn, K]
    ids_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.min(d2, axis=1)


def kmeans_assign_call(x: jax.Array, c: jax.Array, *, block_n: int = 1024,
                       interpret: bool = False):
    """x: [N, D]; c: [K, D]. Returns (ids [N] int32, d2 [N] fp32)."""
    N, D = x.shape
    K = c.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_blocks = x.shape[0] // bn

    ids, d2 = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((K, D), lambda i: (0, 0)),   # pinned centroids
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((x.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(x, c)
    return ids[:N], d2[:N]
