from __future__ import annotations

from functools import partial

import jax

from repro.kernels.kmeans_assign.kernel import kmeans_assign_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x, c, *, block_n: int = 1024,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return kmeans_assign_call(x, c, block_n=block_n, interpret=interpret)
