from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kernel import kmeans_assign_call
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x, c, *, block_n: int = 1024,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return kmeans_assign_call(x, c, block_n=block_n, interpret=interpret)


def kmeans_assign_partials(x, c, valid=None, *, block_n: int = 1024,
                           use_kernel: bool | None = None):
    """Per-centroid (sums, counts) partials for the Sphere assign stage.

    x: [N, D] points (possibly padded up to a fixed block shape);
    c: [K, D] centroids; valid: optional bool [N] mask (True = real
    point) so padding rows contribute nothing to the partials.

    Nearest-centroid ids come from the Pallas ``kmeans_assign`` kernel
    on TPU; elsewhere the jnp oracle does the same math without paying
    interpret-mode overhead.  Designed to be called inside a traced
    stage UDF: (x, c, valid) are all dynamic, so one trace serves every
    task shape and every new centroid value across chained jobs.
    Returns (sums [K, D] f32, counts [K] f32).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        ids, _ = kmeans_assign(x, c, block_n=block_n)
    else:
        ids, _ = kmeans_assign_ref(x, c)
    oh = jax.nn.one_hot(ids, c.shape[0], dtype=jnp.float32)
    if valid is not None:
        oh = oh * valid.astype(jnp.float32)[:, None]
    sums = oh.T @ x.astype(jnp.float32)
    counts = oh.sum(0)
    return sums, counts
