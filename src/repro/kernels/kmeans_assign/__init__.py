from repro.kernels.kmeans_assign.ops import (kmeans_assign,  # noqa: F401
                                             kmeans_assign_partials)
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref  # noqa: F401
