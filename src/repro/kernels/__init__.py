"""Pallas TPU kernels for the Sphere/LM compute hot-spots.

Each kernel ships as <name>/{kernel.py (pallas_call + BlockSpec), ops.py
(jit'd wrapper with backend dispatch), ref.py (pure-jnp oracle)} and is
swept against its oracle over shapes/dtypes in tests (interpret mode on
CPU; Mosaic on real TPU).
"""
from repro.kernels.bucket_partition import bucket_partition  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.kmeans_assign import kmeans_assign  # noqa: F401
from repro.kernels.rg_lru_scan import rg_lru_scan  # noqa: F401
