"""Oracle: the same diagonal recurrence via lax.scan."""
from __future__ import annotations

import jax
from jax import lax


def lru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array):
    def step(h, ab):
        at, bt = ab
        h = h * at + bt
        return h, h

    hlast, hs = lax.scan(step, h0, (a.transpose(1, 0, 2),
                                    b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), hlast
