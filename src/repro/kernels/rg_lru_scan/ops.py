"""jit'd wrapper with backend dispatch."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rg_lru_scan.kernel import lru_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_w", "interpret"))
def rg_lru_scan(a, b, h0, *, block_w: int = 512,
                interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return lru_scan(a, b, h0, block_w=block_w, interpret=interpret)
