from repro.kernels.rg_lru_scan.ops import rg_lru_scan  # noqa: F401
from repro.kernels.rg_lru_scan.ref import lru_scan_ref  # noqa: F401
