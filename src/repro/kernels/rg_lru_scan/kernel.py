"""RG-LRU diagonal linear-recurrence scan kernel.

h_t = a_t * h_{t-1} + b_t over [B, T, W] with per-channel diagonal decay.

TPU adaptation: the recurrence is bandwidth-bound, not MXU-bound — the
kernel's job is to keep the whole [T, bw] channel strip resident in VMEM and
run the time loop at register speed instead of bouncing h through HBM every
step (which the naive lax.scan formulation does). Grid: (B, W/bw); each grid
cell owns a channel strip, carrying h in a VMEM scratch vector. The time
loop is a fori_loop over T inside the kernel — sequential by the math, but
HBM sees exactly one read of (a, b) and one write of h per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, out_ref, hlast_ref, h_scr, *, T: int):
    h_scr[...] = h0_ref[0]

    def step(t, _):
        h = h_scr[...] * a_ref[0, t] + b_ref[0, t]
        h_scr[...] = h
        out_ref[0, t] = h
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    hlast_ref[0] = h_scr[...]


def lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
             block_w: int = 512, interpret: bool = False):
    """a, b: [B, T, W] fp32; h0: [B, W]. Returns (h [B,T,W], h_last [B,W])."""
    B, T, W = a.shape
    bw = min(block_w, W)
    while W % bw:
        bw //= 2
    nw = W // bw

    kern = functools.partial(_kernel, T=T)
    h, hlast = pl.pallas_call(
        kern,
        grid=(B, nw),
        in_specs=[
            pl.BlockSpec((1, T, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, T, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h, hlast
