"""Sector event bus: master-side control-plane notifications.

The paper's Sector master already *reacts* to cluster change (heartbeat
loss drops a server from the ring and enqueues re-replication); the
companion papers' Angle workload additionally needs downstream consumers
— Sphere sessions and streams — to react too: new feature files land
continuously and compute must follow the data.  ``EventBus`` is the
mechanism: :class:`repro.sector.master.SectorMaster` publishes

* ``file-created``      — a file's chunks are fully committed (``path``
  is the file name; the client notifies at the end of ``upload``);
* ``server-joined``     — a chunk server registered;
* ``server-died``       — a server deregistered (graceful leave or
  heartbeat-timeout failure);
* ``chunk-replicated``  — one replica of a chunk committed (uploads and
  repair both land here; ``detail["replicas"]`` is the new count);

and subscribers are plain synchronous callbacks driven by the simulated
clock — no threads, so tests and examples stay deterministic.

Ordering guarantees (the property streams rely on):

* ``publish`` assigns a monotonic global sequence number (``event.seq``)
  at publish time;
* events are delivered to subscribers in subscription order, events in
  seq order;
* a publish *from inside* a callback (e.g. a repair subscriber that
  re-registers a standby server when it sees ``server-died``) is queued
  and delivered after the current event finishes its delivery round —
  breadth-first, so delivery order always equals publish order even
  under re-entrancy, and a "simultaneous" join+death (same simulated
  time) is observed by every subscriber in the same order.
"""
from __future__ import annotations

import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional

FILE_CREATED = "file-created"
SERVER_JOINED = "server-joined"
SERVER_DIED = "server-died"
CHUNK_REPLICATED = "chunk-replicated"

EVENT_TYPES = (FILE_CREATED, SERVER_JOINED, SERVER_DIED, CHUNK_REPLICATED)


@dataclass(frozen=True)
class SectorEvent:
    """One bus event. ``path`` names the subject (file name, server id or
    chunk id); ``time`` is the simulated clock at publish."""
    seq: int
    type: str
    time: float
    path: str = ""
    detail: Dict[str, object] = field(default_factory=dict)


Callback = Callable[[SectorEvent], None]


@dataclass
class Subscription:
    """A registered callback with optional type / path-prefix filters.
    ``types=None`` matches every type; ``prefix=None`` every path."""
    callback: Callback
    types: Optional[frozenset]
    prefix: Optional[str]
    active: bool = True

    def matches(self, event: SectorEvent) -> bool:
        return (self.active
                and (self.types is None or event.type in self.types)
                and (self.prefix is None
                     or event.path.startswith(self.prefix)))


class EventBus:
    def __init__(self, history: int = 256):
        self._subs: List[Subscription] = []
        self._seq = 0
        self._queue: Deque[SectorEvent] = deque()
        self._delivering = False
        # bounded recent-event log: tests and doctors read it, nothing in
        # the data path depends on it
        self.history: Deque[SectorEvent] = deque(maxlen=history)

    # ------------------------------------------------------------ subscribe
    def subscribe(self, callback: Callback, *,
                  types: Optional[Iterable[str]] = None,
                  prefix: Optional[str] = None) -> Subscription:
        """Register ``callback`` for events matching the filters.  Types
        are validated against the protocol — a typo'd type would
        otherwise just never fire."""
        tset: Optional[frozenset] = None
        if types is not None:
            tset = frozenset(types)
            unknown = tset - set(EVENT_TYPES)
            if unknown:
                raise ValueError(f"unknown event types {sorted(unknown)}; "
                                 f"choose from {EVENT_TYPES}")
        sub = Subscription(callback, tset, prefix)
        self._subs.append(sub)
        return sub

    # ---------------------------------------------------------- introspection
    def replay(self, *, since_seq: int = -1,
               types: Optional[Iterable[str]] = None,
               prefix: Optional[str] = None) -> List[SectorEvent]:
        """Recent events from the bounded history ring, oldest first, in
        seq order — the late-joiner API: a subscriber attaching after
        the cloud was built (a tracer, a doctor) replays the recent
        control-plane past before subscribing for the future.  Filters
        match :meth:`subscribe`'s (``types`` validated the same way);
        ``since_seq`` returns only events with ``seq > since_seq``, so a
        consumer can resume from the last seq it saw.  Events older than
        the ring's bound are gone — the ring is a window, not a log."""
        tset: Optional[frozenset] = None
        if types is not None:
            tset = frozenset(types)
            unknown = tset - set(EVENT_TYPES)
            if unknown:
                raise ValueError(f"unknown event types {sorted(unknown)}; "
                                 f"choose from {EVENT_TYPES}")
        return [ev for ev in self.history
                if ev.seq > since_seq
                and (tset is None or ev.type in tset)
                and (prefix is None or ev.path.startswith(prefix))]

    def unsubscribe(self, sub: Subscription) -> None:
        sub.active = False
        try:
            self._subs.remove(sub)
        except ValueError:
            pass

    # -------------------------------------------------------------- publish
    def publish(self, type: str, *, time: float = 0.0, path: str = "",
                **detail) -> SectorEvent:
        """Publish one event and synchronously deliver it (and anything
        published re-entrantly from callbacks) in seq order."""
        if type not in EVENT_TYPES:
            raise ValueError(f"unknown event type {type!r}; "
                             f"choose from {EVENT_TYPES}")
        ev = SectorEvent(self._seq, type, time, path, detail)
        self._seq += 1
        self.history.append(ev)
        self._queue.append(ev)
        if self._delivering:
            return ev  # re-entrant: the outer delivery loop drains it
        # A raising subscriber must not corrupt the bus: the drain always
        # completes (remaining subscribers and queued re-entrant events
        # still see everything, in order — otherwise a stale event would
        # leak into the FRONT of the next unrelated publish), and the
        # first error re-raises to the publisher afterwards.
        self._delivering = True
        errors: List[BaseException] = []
        try:
            while self._queue:
                cur = self._queue.popleft()
                # snapshot: a callback may (un)subscribe mid-delivery
                for sub in list(self._subs):
                    if sub.matches(cur):
                        try:
                            sub.callback(cur)
                        except Exception as e:  # noqa: BLE001
                            errors.append(e)
        finally:
            self._delivering = False
            # normally the drain emptied the queue; after a BaseException
            # (KeyboardInterrupt through a long on_window callback) the
            # aborted remainder must not leak into the front of the next
            # unrelated publish — drop it
            self._queue.clear()
        if errors:
            raise errors[0]
        return ev


def weak_subscribe(bus: EventBus, owner, method_name: str, **filters
                   ) -> Subscription:
    """Subscribe ``owner.method_name`` through a weakref: the bus must
    never keep its subscribers (streams with their executor/chunk
    caches, replication daemons) alive.  An owner that was never
    explicitly closed gets garbage-collected normally, and its dead
    subscription self-unsubscribes on the next matching event."""
    ref = weakref.ref(owner)
    box = {}

    def callback(event):
        target = ref()
        if target is None:
            bus.unsubscribe(box["sub"])
            return
        getattr(target, method_name)(event)

    box["sub"] = bus.subscribe(callback, **filters)
    return box["sub"]
