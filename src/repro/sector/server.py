"""Chunk server: stores chunk bytes as real files under a local directory."""
from __future__ import annotations

import os
from pathlib import Path

from repro.sector.chunk import checksum


class ServerDown(ConnectionError):
    pass


class ChunkServer:
    def __init__(self, server_id: str, site: str, root: str | Path):
        self.server_id = server_id
        self.site = site
        self.root = Path(root) / server_id
        self.root.mkdir(parents=True, exist_ok=True)
        self.alive = True

    # -- fault injection ----------------------------------------------------
    def kill(self) -> None:
        self.alive = False

    def revive(self) -> None:
        self.alive = True

    def _check(self) -> None:
        if not self.alive:
            raise ServerDown(self.server_id)

    def _path(self, chunk_id: str) -> Path:
        return self.root / chunk_id.replace("/", "_").replace("#", "__")

    # -- chunk ops ----------------------------------------------------------
    def write_chunk(self, chunk_id: str, data: bytes) -> str:
        self._check()
        p = self._path(chunk_id)
        tmp = p.with_suffix(".tmp")
        tmp.write_bytes(data)
        os.replace(tmp, p)  # atomic publish
        return checksum(data)

    def read_chunk(self, chunk_id: str) -> bytes:
        self._check()
        p = self._path(chunk_id)
        if not p.exists():
            raise FileNotFoundError(chunk_id)
        return p.read_bytes()

    def has_chunk(self, chunk_id: str) -> bool:
        return self.alive and self._path(chunk_id).exists()

    def delete_chunk(self, chunk_id: str) -> None:
        self._check()
        p = self._path(chunk_id)
        if p.exists():
            p.unlink()

    def verify_chunk(self, chunk_id: str, digest: str) -> bool:
        try:
            return checksum(self.read_chunk(chunk_id)) == digest
        except (ServerDown, FileNotFoundError):
            return False

    def used_bytes(self) -> int:
        return sum(f.stat().st_size for f in self.root.iterdir()
                   if f.is_file())
