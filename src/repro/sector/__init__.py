from repro.sector.chunk import ChunkMeta, FileMeta  # noqa: F401
from repro.sector.client import SectorClient  # noqa: F401
from repro.sector.events import EventBus, SectorEvent  # noqa: F401
from repro.sector.master import SectorMaster  # noqa: F401
from repro.sector.server import ChunkServer  # noqa: F401
from repro.sector.topology import TERAFLOW_TESTBED, Topology  # noqa: F401
