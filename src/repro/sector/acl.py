"""Community access control (paper §3, Figure 3).

Sector semantics: anyone in the public can *read*; only community members on
the write ACL can *write*. Unlike GFS/Hadoop (organisation-scoped accounts)
or Globus (virtual-organisation GSI), Sector is community-scoped with open
reads.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Set


class AclError(PermissionError):
    pass


@dataclass
class CommunityACL:
    community: Set[str] = field(default_factory=set)
    writers: Set[str] = field(default_factory=set)
    public_read: bool = True
    read_restricted: Set[str] = field(default_factory=set)  # files

    def add_member(self, user: str) -> None:
        self.community.add(user)

    def grant_write(self, user: str) -> None:
        if user not in self.community:
            raise AclError(f"{user} is not a community member")
        self.writers.add(user)

    def check_write(self, user: str) -> None:
        if user not in self.writers:
            raise AclError(f"{user} lacks write access")

    def check_read(self, user: str, file: str) -> None:
        if file in self.read_restricted and user not in self.community:
            raise AclError(f"{file} is restricted to the community")
        if not self.public_read and user not in self.community:
            raise AclError("reads are community-only on this cloud")
