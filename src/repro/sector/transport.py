"""UDT vs TCP transport models — reproduces the paper's LLPR behaviour.

The paper's enabling protocol is UDT [Gu & Grossman 2007]: a rate-based,
application-level reliable transport that keeps long-fat links full where
TCP's AIMD collapses. We model both protocols faithfully enough to
reproduce Table 1:

* **TCP (Reno-style AIMD)** — steady-state throughput follows the Mathis
  bound  ``min(C, MSS / (RTT * sqrt(2p/3)))``, plus slow-start ramp. On a
  10 Gbps / 200 ms / lossy path this is catastrophically below link rate —
  the reason the paper built UDT.

* **UDT (rate-based)** — the sender probes to the fair share of link
  capacity with a fixed rate-control interval (SYN = 0.01 s), independent of
  RTT; random loss triggers a brief multiplicative back-off of 1/9 (per the
  UDT congestion-control paper) but recovery does not scale with RTT. We
  model efficiency as a function of loss and the protocol/framing overhead.

Both models are deterministic discrete-event simulations over segments, so
tests can assert exact invariants (monotonicity in loss/RTT, UDT >= TCP on
long fat networks, LLPR in the paper's 0.6-1.0 band).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.sector.topology import Link

MSS = 1500 * 8            # bits
UDT_SYN = 0.01            # UDT rate-control interval (s)
HEADER_OVERHEAD = 0.028   # IP+UDP/TCP+framing overhead fraction
HOST_RATE = 630e6         # end-host (disk/NIC/CPU) cap, bits/s — the
                          # paper's 2007 Opteron nodes peak at ~615 Mb/s
                          # locally (Table 1), so LLPR is measured against
                          # this host bottleneck, not the 10 Gb/s link.


@dataclass(frozen=True)
class TransferResult:
    seconds: float
    throughput_bps: float
    protocol: str


def tcp_throughput(link: Link, flows: int = 1) -> float:
    """Steady-state Reno throughput (Mathis) for ``flows`` parallel flows."""
    cap = min(link.bandwidth_bps * (1 - HEADER_OVERHEAD), HOST_RATE)
    if link.loss <= 0:
        return cap
    per_flow = MSS / (link.rtt_s * math.sqrt(2 * link.loss / 3))
    return min(cap, flows * per_flow)


def udt_throughput(link: Link) -> float:
    """UDT steady state: rate-based probing holds the path near the host
    rate. A loss event costs a transient 1/9 rate cut whose detection takes
    an RTT (NAK) and whose re-probe takes a few SYN intervals, so:

        eff = 1 / (1 + events_per_s * (rtt + 4*SYN) / 9)

    — efficiency falls with loss*RTT but never collapses the way AIMD does
    (the cut is 1/9 and recovery is rate-based, not window-halving).
    """
    cap = min(link.bandwidth_bps * (1 - HEADER_OVERHEAD), HOST_RATE)
    events_per_s = link.loss * cap / MSS
    penalty = events_per_s * (link.rtt_s + 4 * UDT_SYN) / 9.0
    eff = 1.0 / (1.0 + penalty)
    window_limit = (12 * 1024 * 1024 * 8) / link.rtt_s  # 12MB flow window
    return min(cap * eff, window_limit)


def simulate_transfer(nbytes: int, link: Link, protocol: str = "udt",
                      flows: int = 1, warm: bool = False) -> TransferResult:
    """Deterministic transfer-time model incl. startup ramp.

    ``warm=True`` models a persistent data connection (Sector reuses the UDT
    connection for every chunk of a session — §3 step 4), skipping the
    handshake/slow-start ramp."""
    bits = nbytes * 8
    if protocol == "tcp":
        rate = tcp_throughput(link, flows)
        # slow start: ~log2(W) RTTs to reach steady window
        bdp = rate * link.rtt_s
        ramp = 0.0 if warm else \
            link.rtt_s * max(1.0, math.log2(max(bdp / MSS, 2.0)))
        t = ramp + bits / rate
    elif protocol == "udt":
        rate = udt_throughput(link)
        ramp = 0.0 if warm else 2 * link.rtt_s + 4 * UDT_SYN
        t = ramp + bits / rate
    else:
        raise ValueError(protocol)
    return TransferResult(t, bits / t, protocol)


def llpr(nbytes: int, wan: Link, lan: Link, protocol: str = "udt") -> float:
    """Long-distance to Local Performance Ratio (paper §5.2)."""
    t_wan = simulate_transfer(nbytes, wan, protocol).seconds
    t_lan = simulate_transfer(nbytes, lan, protocol).seconds
    return t_lan / t_wan
