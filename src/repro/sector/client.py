"""Sector client: upload / download through the master + chunk servers.

Follows the paper's data-access session (§3):
  1. connect to a known server / master, request locations of a named entity;
  2. master resolves via the routing layer, returns locations;
  3. client opens a data connection to the best location;
  4. bulk transfer runs over UDT (simulated transport cost model).

The client accounts simulated wide-area transfer time for every movement, so
benchmarks can report LLPR and data-locality savings without real WANs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.sector.chunk import checksum
from repro.sector.master import SectorMaster
from repro.sector.server import ServerDown
from repro.sector.transport import simulate_transfer


@dataclass
class TransferLog:
    bytes_moved: int = 0
    sim_seconds: float = 0.0
    transfers: int = 0
    by_protocol: Dict[str, int] = field(default_factory=dict)

    def add(self, nbytes: int, seconds: float, protocol: str) -> None:
        self.bytes_moved += nbytes
        self.sim_seconds += seconds
        self.transfers += 1
        self.by_protocol[protocol] = self.by_protocol.get(protocol, 0) + 1


class SectorClient:
    def __init__(self, master: SectorMaster, user: str = "public",
                 site: str = "chicago", protocol: str = "udt"):
        self.master = master
        self.user = user
        self.site = site
        self.protocol = protocol
        self.log = TransferLog()
        self._warm: set = set()  # persistent data connections (§3 step 4)

    # ------------------------------------------------------------------ I/O
    def _move(self, nbytes: int, src_site: str, dst_site: str) -> float:
        link = self.master.topology.link(src_site, dst_site)
        pair = (src_site, dst_site)
        res = simulate_transfer(nbytes, link, self.protocol,
                                warm=pair in self._warm)
        self._warm.add(pair)
        self.log.add(nbytes, res.seconds, self.protocol)
        return res.seconds

    def upload(self, name: str, data: bytes,
               replication: Optional[int] = None,
               at: Optional[float] = None) -> None:
        """Write ``name`` through the chunk pipeline.  ``at`` is the
        simulated landing time forwarded to ``file_complete`` — timed
        stream windows bucket the file by it (omitted = the master's
        current clock).  The client's own site anchors LLPR-weighted
        placement when the master runs with that policy."""
        fm = self.master.create_file(name, len(data), self.user, replication)
        csz = self.master.chunk_size
        for i, cid in enumerate(fm.chunk_ids):
            blob = data[i * csz:(i + 1) * csz]
            targets = self.master.placement(cid, src_site=self.site)
            if not targets:
                raise RuntimeError("no live chunk servers")
            # pipeline: client -> first replica -> next replica (chain)
            prev_site = self.site
            for sid in targets:
                srv = self.master.servers[sid]
                self._move(len(blob), prev_site, srv.site)
                digest = srv.write_chunk(cid, blob)
                self.master.commit_chunk(cid, sid, len(blob), digest)
                prev_site = srv.site
        # every chunk committed: wake file-created subscribers (streams)
        self.master.file_complete(name, now=at)

    def download(self, name: str) -> bytes:
        metas = self.master.lookup(name, self.user, self.site)
        out = []
        for meta in metas:
            blob = None
            for sid in meta.locations:  # nearest replica first
                srv = self.master.servers.get(sid)
                if srv is None:
                    continue
                try:
                    blob = srv.read_chunk(meta.chunk_id)
                except (ServerDown, FileNotFoundError):
                    continue
                if checksum(blob) != meta.digest:  # corrupt replica
                    blob = None
                    continue
                self._move(len(blob), srv.site, self.site)
                break
            if blob is None:
                raise IOError(f"all replicas of {meta.chunk_id} unavailable")
            out.append(blob)
        return b"".join(out)

    def read_chunk(self, chunk_id: str) -> bytes:
        ck = self.master.chunks[chunk_id]
        metas = self.master.lookup(ck.file, self.user, self.site)
        meta = next(m for m in metas if m.chunk_id == chunk_id)
        for sid in meta.locations:
            srv = self.master.servers.get(sid)
            if srv is None:
                continue
            try:
                blob = srv.read_chunk(chunk_id)
            except (ServerDown, FileNotFoundError):
                continue
            self._move(len(blob), srv.site, self.site)
            return blob
        raise IOError(f"all replicas of {chunk_id} unavailable")

    # ----------------------------------------------------------- replication
    def run_repair(self) -> int:
        """Execute the master's re-replication plan. Returns #copies made."""
        n = 0
        for cid, src, dst in self.master.repair_plan():
            s_srv = self.master.servers[src]
            d_srv = self.master.servers[dst]
            try:
                blob = s_srv.read_chunk(cid)
            except (ServerDown, FileNotFoundError):
                continue
            self._move(len(blob), s_srv.site, d_srv.site)
            digest = d_srv.write_chunk(cid, blob)
            self.master.commit_chunk(cid, dst, len(blob), digest)
            n += 1
        return n
