"""Replication daemon: periodic scan + repair loop (paper §3).

In production this runs in the master's background thread; here it is a
synchronous step function driven by the simulated clock so tests and the
fault-tolerance examples can advance time deterministically.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.sector.client import SectorClient
from repro.sector.master import SectorMaster


@dataclass
class ReplicationDaemon:
    master: SectorMaster
    client: SectorClient
    scan_interval: float = 10.0
    _last_scan: float = 0.0

    def tick(self, now: float) -> dict:
        """Advance the daemon: detect failures, repair under-replication."""
        report = {"failed": [], "repaired": 0}
        report["failed"] = self.master.check_failures(now)
        if now - self._last_scan >= self.scan_interval:
            self._last_scan = now
            report["repaired"] = self.client.run_repair()
        return report

    def verify_all(self) -> dict:
        """Checksum-verify every replica (background scrubbing)."""
        ok, bad = 0, 0
        for ck in self.master.chunks.values():
            for sid in list(ck.locations):
                srv = self.master.servers.get(sid)
                if srv is None or not srv.verify_chunk(ck.chunk_id, ck.digest):
                    ck.locations.discard(sid)
                    if len(ck.locations) < self.master._repl(ck.file):
                        self.master.under_replicated.add(ck.chunk_id)
                    bad += 1
                else:
                    ok += 1
        return {"ok": ok, "bad": bad}
