"""Replication daemon: event-driven repair + periodic scan (paper §3).

In production this runs in the master's background thread; here it is a
synchronous step function driven by the simulated clock so tests and the
fault-tolerance examples can advance time deterministically.

Repair is primarily *event-driven*: the daemon subscribes to the
master's ``server-died`` bus events (graceful deregistration and
heartbeat-timeout failures alike) and runs repair the moment a death is
published — replicas are restored during the event delivery, not up to
``scan_interval`` simulated seconds later at the next poll.  The
periodic :meth:`tick` scan remains as the backstop for damage that emits
no event (silent corruption found by :meth:`verify_all`, repairs that
could not complete earlier for lack of live targets).

Where repaired replicas LAND is the master's policy, not the daemon's:
``run_repair`` executes ``master.repair_plan()`` verbatim, so a master
constructed with ``llpr_placement=True`` steers re-replication toward
sites with high effective bandwidth from the surviving copy
(LLPR-weighted rendezvous — see
:meth:`repro.sector.master.SectorMaster.place_llpr`) with no changes
here.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.sector.client import SectorClient
from repro.sector.events import SERVER_DIED, weak_subscribe
from repro.sector.master import SectorMaster


@dataclass
class ReplicationDaemon:
    master: SectorMaster
    client: SectorClient
    scan_interval: float = 10.0
    _last_scan: float = 0.0
    # subscribe to server-died and repair immediately (default); False
    # restores the pure polling daemon for A/B tests of repair latency
    event_driven: bool = True
    event_repairs: int = 0

    def __post_init__(self):
        if self.event_driven:
            self._sub = weak_subscribe(self.master.events, self,
                                       "_on_server_died",
                                       types=(SERVER_DIED,))

    def _on_server_died(self, event) -> None:
        tracer = self.master.tracer
        if tracer is None:
            self.event_repairs += self.client.run_repair()
            return
        with tracer.span("replication-repair", track="master",
                         attrs={"died": event.path}) as sp:
            repaired = self.client.run_repair()
            sp.set_attrs(repaired=repaired)
        self.event_repairs += repaired

    def tick(self, now: float) -> dict:
        """Advance the daemon: detect failures, repair under-replication.

        With ``event_driven`` the ``check_failures`` call publishes
        ``server-died`` for every newly detected timeout, so repair for
        those runs *inside* this call via the subscription (counted in
        ``event_repairs``); the interval scan then only catches leftover
        under-replication."""
        report = {"failed": [], "repaired": 0}
        report["failed"] = self.master.check_failures(now)
        if now - self._last_scan >= self.scan_interval:
            self._last_scan = now
            report["repaired"] = self.client.run_repair()
        return report

    def verify_all(self) -> dict:
        """Checksum-verify every replica (background scrubbing)."""
        ok, bad = 0, 0
        for ck in self.master.chunks.values():
            for sid in list(ck.locations):
                srv = self.master.servers.get(sid)
                if srv is None or not srv.verify_chunk(ck.chunk_id, ck.digest):
                    ck.locations.discard(sid)
                    if len(ck.locations) < self.master._repl(ck.file):
                        self.master.under_replicated.add(ck.chunk_id)
                    bad += 1
                else:
                    ok += 1
        return {"ok": ok, "bad": bad}
