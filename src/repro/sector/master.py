"""Sector master: metadata index + Chord-style consistent-hash placement.

The paper's routing layer locates the node holding metadata for a named
entity; Sector currently uses Chord [Stoica et al. 2001]. In a TPU job the
membership set is static-ish and a master can answer lookups in O(1), but we
keep the *consistent-hash ring* (with virtual nodes) for chunk->server
placement because it preserves Chord's key property we still need: **minimal
data movement under elastic membership change** — when a server joins or
dies, only ~1/n of chunk assignments move (tested).

Failure handling: servers heartbeat on a simulated clock; missing heartbeats
mark a server dead, drop it from the ring, and enqueue re-replication for
every chunk that lost a replica (paper §3: "Automatic services ensure that
after a failure drops a replica, an additional replica is created").
"""
from __future__ import annotations

import bisect
import hashlib
import math
from typing import Dict, List, Optional, Set, Tuple

from repro.sector.acl import CommunityACL
from repro.sector.chunk import CHUNK_SIZE, ChunkMeta, FileMeta
from repro.sector.events import (CHUNK_REPLICATED, FILE_CREATED,
                                 SERVER_DIED, SERVER_JOINED, EventBus)
from repro.sector.server import ChunkServer
from repro.sector.topology import TERAFLOW_TESTBED, Topology

V_NODES = 64  # virtual nodes per server


def _h(key: str) -> int:
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


class HashRing:
    """Consistent-hash ring with virtual nodes."""

    def __init__(self):
        self._points: List[int] = []
        self._owner: Dict[int, str] = {}

    def add(self, server_id: str) -> None:
        for v in range(V_NODES):
            p = _h(f"{server_id}@{v}")
            if p in self._owner:
                continue
            bisect.insort(self._points, p)
            self._owner[p] = server_id

    def remove(self, server_id: str) -> None:
        for v in range(V_NODES):
            p = _h(f"{server_id}@{v}")
            if self._owner.get(p) == server_id:
                del self._owner[p]
                i = bisect.bisect_left(self._points, p)
                if i < len(self._points) and self._points[i] == p:
                    self._points.pop(i)

    def servers(self) -> Set[str]:
        return set(self._owner.values())

    def place(self, key: str, n: int,
              site_of: Optional[Dict[str, str]] = None) -> List[str]:
        """Walk the ring clockwise from hash(key); prefer distinct sites
        (rack/DC-aware replica placement) then fill with distinct servers."""
        if not self._points:
            return []
        start = bisect.bisect_left(self._points, _h(key)) % len(self._points)
        chosen: List[str] = []
        sites_used: Set[str] = set()
        # pass 1: distinct sites
        for i in range(len(self._points)):
            s = self._owner[self._points[(start + i) % len(self._points)]]
            if s in chosen:
                continue
            site = site_of.get(s) if site_of else None
            if site is not None and site in sites_used:
                continue
            chosen.append(s)
            sites_used.add(site)
            if len(chosen) == n:
                return chosen
        # pass 2: any distinct server
        for i in range(len(self._points)):
            s = self._owner[self._points[(start + i) % len(self._points)]]
            if s not in chosen:
                chosen.append(s)
                if len(chosen) == n:
                    break
        return chosen


class SectorMaster:
    def __init__(self, topology: Topology = TERAFLOW_TESTBED,
                 default_replication: int = 3,
                 heartbeat_timeout: float = 30.0,
                 chunk_size: int = CHUNK_SIZE,
                 llpr_placement: bool = False):
        self.topology = topology
        self.default_replication = default_replication
        self.heartbeat_timeout = heartbeat_timeout
        self.chunk_size = chunk_size
        # llpr_placement: weight replica placement by each candidate
        # site's EFFECTIVE bandwidth from the writing site (the LLPR
        # model, Table 1) instead of pure hash order — replicas still
        # spread across distinct sites, but well-connected sites win
        # proportionally more of them.  Off by default: the paper's
        # baseline placement is topology-blind consistent hashing, and
        # the hash ring's minimal-movement guarantee is what most tests
        # pin down.
        self.llpr_placement = llpr_placement
        self.ring = HashRing()
        self.servers: Dict[str, ChunkServer] = {}
        self.files: Dict[str, FileMeta] = {}
        self.chunks: Dict[str, ChunkMeta] = {}
        self.acl = CommunityACL()
        self._heartbeat: Dict[str, float] = {}
        self.under_replicated: Set[str] = set()
        # control-plane notifications: Sphere sessions/streams subscribe
        # for membership invalidation and windowed file arrival
        self.events = EventBus()
        self.clock = 0.0  # last simulated time the master observed
        # observability: an engine built with a recording tracer assigns
        # it here (duck-typed — sector must not import core.trace, the
        # dependency runs the other way); None = no tracing
        self.tracer = None

    def _tick(self, now: Optional[float] = None) -> float:
        if now is not None:
            self.clock = max(self.clock, now)
        return self.clock

    # ------------------------------------------------------------ membership
    def register(self, server: ChunkServer, now: float = 0.0) -> None:
        self.servers[server.server_id] = server
        self.ring.add(server.server_id)
        self._heartbeat[server.server_id] = now
        self.events.publish(SERVER_JOINED, time=self._tick(now),
                            path=server.server_id, site=server.site)

    def deregister(self, server_id: str, now: Optional[float] = None) -> None:
        """Graceful leave (or confirmed failure): drop from ring, flag every
        chunk that lost a replica."""
        self.ring.remove(server_id)
        self._heartbeat.pop(server_id, None)
        lost = 0
        for ck in self.chunks.values():
            if server_id in ck.locations:
                ck.locations.discard(server_id)
                lost += 1
                if len(ck.locations) < self._repl(ck.file):
                    self.under_replicated.add(ck.chunk_id)
        self.events.publish(SERVER_DIED, time=self._tick(now),
                            path=server_id, replicas_lost=lost,
                            under_replicated=len(self.under_replicated))

    def heartbeat(self, server_id: str, now: float) -> None:
        self._tick(now)
        if server_id in self.servers:
            self._heartbeat[server_id] = now

    def check_failures(self, now: float) -> List[str]:
        """Mark servers with stale heartbeats dead. Returns the failed ids."""
        self._tick(now)
        dead = [s for s, t in self._heartbeat.items()
                if now - t > self.heartbeat_timeout]
        for s in dead:
            self.deregister(s, now)
        return dead

    def _site_of(self) -> Dict[str, str]:
        return {sid: srv.site for sid, srv in self.servers.items()
                if sid in self.ring.servers()}

    def _repl(self, file: str) -> int:
        fm = self.files.get(file)
        return fm.replication if fm else self.default_replication

    # ------------------------------------------------------------- metadata
    def create_file(self, name: str, size: int, owner: str,
                    replication: Optional[int] = None) -> FileMeta:
        self.acl.check_write(owner)
        if name in self.files:
            raise FileExistsError(name)
        repl = replication or self.default_replication
        n_chunks = max(1, -(-size // self.chunk_size))
        fm = FileMeta(name, size, n_chunks, owner, repl)
        for i in range(n_chunks):
            cid = ChunkMeta.make_id(name, i)
            fm.chunk_ids.append(cid)
            self.chunks[cid] = ChunkMeta(cid, name, i, 0, "")
        self.files[name] = fm
        return fm

    def place_llpr(self, key: str, n: int, src_site: str) -> List[str]:
        """LLPR-weighted rendezvous placement: ``n`` servers for ``key``,
        favouring sites with high effective bandwidth from ``src_site``.

        Weighted rendezvous hashing: every live server draws a
        deterministic pseudo-uniform ``u`` from ``hash(key, server)``
        and scores ``-w / ln(u)`` with ``w`` the LLPR effective
        bandwidth (:meth:`Topology.effective_bandwidth_bps`) from the
        writing site to the server's site; highest scores win.  This
        keeps consistent hashing's properties — per-key deterministic,
        minimal reshuffling when membership changes — while making a
        site's share of replicas proportional to its ``w`` (the
        exponential-race property of the score).  Like
        :meth:`HashRing.place`, distinct sites are preferred before
        servers double up within a site."""
        site_of = self._site_of()
        scored: List[Tuple[float, str]] = []
        for s in sorted(site_of):
            u = (_h(f"{key}|{s}") + 1) / float(2 ** 64 + 1)  # in (0, 1)
            w = self.topology.effective_bandwidth_bps(src_site, site_of[s])
            scored.append((-w / math.log(u), s))
        scored.sort(key=lambda t: (-t[0], t[1]))
        chosen: List[str] = []
        sites_used: Set[str] = set()
        for _, s in scored:  # pass 1: distinct sites, by score
            if site_of[s] in sites_used:
                continue
            chosen.append(s)
            sites_used.add(site_of[s])
            if len(chosen) == n:
                return chosen
        for _, s in scored:  # pass 2: any distinct server, by score
            if s not in chosen:
                chosen.append(s)
                if len(chosen) == n:
                    break
        return chosen

    def placement(self, chunk_id: str,
                  src_site: Optional[str] = None) -> List[str]:
        """Replica set for one chunk.  ``src_site`` (the writing
        client's site) only matters under ``llpr_placement``, where it
        anchors the effective-bandwidth weights; hash-ring placement
        ignores it, so existing callers are unaffected."""
        ck = self.chunks[chunk_id]
        n = self._repl(ck.file)
        if self.llpr_placement and src_site is not None:
            replicas = self.place_llpr(chunk_id, n, src_site)
        else:
            replicas = self.ring.place(chunk_id, n, self._site_of())
        if self.tracer is not None:
            self.tracer.instant(
                "master:placement", track="master", t=self.clock,
                clock="sim",
                attrs={"chunk": chunk_id,
                       "policy": ("llpr" if self.llpr_placement
                                  and src_site is not None else "ring"),
                       "replicas": len(replicas)})
        return replicas

    def commit_chunk(self, chunk_id: str, server_id: str, size: int,
                     digest: str) -> None:
        ck = self.chunks[chunk_id]
        ck.locations.add(server_id)
        ck.size = size
        ck.digest = digest
        if len(ck.locations) >= self._repl(ck.file):
            self.under_replicated.discard(chunk_id)
        self.events.publish(CHUNK_REPLICATED, time=self._tick(),
                            path=chunk_id, server=server_id,
                            replicas=len(ck.locations))

    def file_complete(self, name: str, now: Optional[float] = None) -> None:
        """Publish ``file-created``: every chunk of ``name`` is committed
        and readers may start.  The upload client calls this last, so the
        event always trails the file's ``chunk-replicated`` events —
        a stream woken by it can plan and read immediately.

        The event's ``time`` is the master's monotonic clock, which
        clamps a late-reported landing forward; the RAW landing time
        rides in ``detail["event_time"]`` so event-time consumers
        (timed stream windows) can see lateness the clock hides."""
        fm = self.files[name]
        t = self._tick(now)
        self.events.publish(FILE_CREATED, time=t, path=name,
                            size=fm.size, chunks=fm.n_chunks,
                            event_time=(float(now) if now is not None
                                        else t))

    # --------------------------------------------------------------- lookup
    def lookup(self, name: str, user: str = "public",
               client_site: Optional[str] = None) -> List[ChunkMeta]:
        """Paper §3 session, steps 1-2: resolve a name to chunk locations,
        nearest replica first."""
        self.acl.check_read(user, name)
        if name not in self.files:
            raise FileNotFoundError(name)
        out = []
        for cid in self.files[name].chunk_ids:
            ck = self.chunks[cid]
            locs = sorted(
                ck.locations,
                key=lambda s: self.topology.distance(
                    client_site or "", self.servers[s].site)
                if client_site else 0.0)
            meta = ChunkMeta(ck.chunk_id, ck.file, ck.index, ck.size,
                             ck.digest, ck.version, set(ck.locations))
            meta.locations = locs  # ordered for the client
            out.append(meta)
        return out

    # ---------------------------------------------------------- re-replicate
    def repair_plan(self) -> List[Tuple[str, str, str]]:
        """[(chunk_id, src_server, dst_server)] to restore replication.

        Destinations come from the active placement policy: hash-ring
        order normally, LLPR-weighted rendezvous (anchored at the
        surviving replica's site — that is where the repair bytes flow
        from) under ``llpr_placement``.  The :class:`ReplicationDaemon`
        executes this plan verbatim, so flipping the knob redirects
        re-replication toward well-connected sites with no daemon
        changes."""
        plan = []
        site_of = self._site_of()
        for cid in sorted(self.under_replicated):
            ck = self.chunks[cid]
            live = [s for s in ck.locations
                    if s in self.servers and self.servers[s].alive]
            if not live:
                continue  # data loss: nothing to copy from (tested)
            need = self._repl(ck.file) - len(live)
            if self.llpr_placement:
                ranked = self.place_llpr(cid, self._repl(ck.file) + need,
                                         self.servers[live[0]].site)
            else:
                ranked = self.ring.place(cid, self._repl(ck.file) + need,
                                         site_of)
            candidates = [s for s in ranked if s not in ck.locations]
            for dst in candidates[:need]:
                plan.append((cid, live[0], dst))
        if self.tracer is not None:
            self.tracer.instant(
                "master:repair-plan", track="master", t=self.clock,
                clock="sim",
                attrs={"moves": len(plan),
                       "under_replicated": len(self.under_replicated)})
        return plan

    def stats(self) -> dict:
        return {
            "servers": len(self.ring.servers()),
            "files": len(self.files),
            "chunks": len(self.chunks),
            "under_replicated": len(self.under_replicated),
            "bytes": sum(f.size for f in self.files.values()),
        }
