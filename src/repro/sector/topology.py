"""Network topology: sites, links, and the Teraflow-testbed instance.

The paper's testbed (§5.1): sites joined by 10 Gbps wide-area links with up
to 200 ms RTT; each site is a small Opteron cluster. ``Topology`` carries
per-site-pair (bandwidth, RTT, loss) and a distance function used for
nearest-replica reads and locality-aware compute placement.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Link:
    bandwidth_bps: float   # raw link bandwidth, bits/s
    rtt_s: float           # round-trip time, seconds
    loss: float            # packet loss probability


@dataclass
class Topology:
    sites: List[str]
    links: Dict[Tuple[str, str], Link] = field(default_factory=dict)
    local: Link = Link(10e9, 0.0002, 1e-7)  # intra-site LAN
    # Fallback for site pairs with no configured link — e.g. a server
    # joining from a site the testbed config predates.  Placement and
    # transfers must keep working when membership grows, so ``link``
    # falls back to this deliberately pessimistic commodity WAN path
    # (1 Gbps, 250 ms RTT, lossy — strictly worse than every provisioned
    # testbed route) instead of raising KeyError; the cost model then
    # naturally steers locality-aware scheduling and nearest-replica
    # reads away from the unprovisioned route.
    default_wan: Link = Link(1e9, 0.250, 5.1e-4)

    def link(self, a: str, b: str) -> Link:
        if a == b:
            return self.local
        got = self.links.get((a, b)) or self.links.get((b, a))
        return got if got is not None else self.default_wan

    def add(self, a: str, b: str, bandwidth_bps: float, rtt_s: float,
            loss: float) -> None:
        self.links[(a, b)] = Link(bandwidth_bps, rtt_s, loss)

    def distance(self, a: str, b: str) -> float:
        """Smaller is closer: RTT-dominated metric (paper reads choose the
        nearest replica)."""
        return self.link(a, b).rtt_s

    def neighbours(self, site: str) -> List[str]:
        return sorted(self.sites, key=lambda s: self.distance(site, s))


def _teraflow() -> Topology:
    """The paper's testbed: Chicago, Pasadena, McLean/Greenbelt, Tokyo,
    Daejeon on 10 Gbps links. RTTs approximate the published geography
    (furthest pair ~200 ms)."""
    t = Topology(sites=["chicago", "pasadena", "greenbelt", "mclean",
                        "tokyo", "daejeon"])
    wan = 10e9
    rtts = {
        ("chicago", "pasadena"): 0.055,
        ("chicago", "greenbelt"): 0.020,
        ("chicago", "mclean"): 0.022,
        ("chicago", "tokyo"): 0.130,
        ("chicago", "daejeon"): 0.165,
        ("pasadena", "greenbelt"): 0.070,
        ("pasadena", "mclean"): 0.072,
        ("pasadena", "tokyo"): 0.110,
        ("pasadena", "daejeon"): 0.145,
        ("greenbelt", "mclean"): 0.004,
        ("greenbelt", "tokyo"): 0.150,
        ("greenbelt", "daejeon"): 0.200,
        ("mclean", "tokyo"): 0.150,
        ("mclean", "daejeon"): 0.195,
        ("tokyo", "daejeon"): 0.035,
    }
    for (a, b), rtt in rtts.items():
        # long-haul paths see more residual loss than the LAN (~2e-3/s of
        # RTT matches the Table-1 efficiency ordering)
        loss = 1e-5 + rtt * 2e-3
        t.add(a, b, wan, rtt, loss)
    return t


TERAFLOW_TESTBED = _teraflow()
