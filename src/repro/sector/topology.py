"""Network topology: sites, links, capacity accounting, and the testbeds.

The paper's testbed (§5.1): sites joined by 10 Gbps wide-area links with up
to 200 ms RTT; each site is a small Opteron cluster.  ``Topology`` carries
per-site-pair (bandwidth, RTT, loss), a distance function used for
nearest-replica reads and locality-aware compute placement, and — since the
contention-aware planner landed — the *identity* of each physical path
(:meth:`Topology.link_key`) plus an LLPR-style achievable-rate query
(:meth:`Topology.effective_bandwidth_bps`), so schedulers can price what a
transfer will actually get on a shared long-fat link rather than the raw
provisioned rate.

Two concrete instances ship:

* :data:`TERAFLOW_TESTBED` — the paper's 6-site Teraflow cloud (Table 1);
* :data:`OPEN_CLOUD_TESTBED` — the 4-site Open Cloud Testbed successor
  (arXiv:0907.4810: Baltimore/JHU, Chicago/StarLight, Chicago/UIC, San
  Diego/Calit2 on 10 Gbps wide-area waves), the shape
  ``benchmarks/wan_scenario.py`` and ``examples/wan_terasort.py`` run on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple


@dataclass(frozen=True)
class Link:
    """One physical path between two sites.

    Contract:

    * ``bandwidth_bps`` — raw provisioned link rate in **bits/second**
      (10 Gbps testbed waves are ``10e9``).  This is the line rate, NOT
      what a transfer achieves: protocol behaviour under ``rtt_s``/``loss``
      decides that (see :func:`repro.sector.transport.udt_throughput` and
      :meth:`Topology.effective_bandwidth_bps`).
    * ``rtt_s`` — round-trip time in **seconds** (the paper's furthest
      pair is ~0.2 s).  Distance/nearest-replica ordering keys on this.
    * ``loss`` — per-packet loss probability in ``[0, 1)``; long-haul
      residual loss is what separates UDT from TCP on these paths.

    Instances are frozen and hashable so they can key caches; a ``Link``
    carries no utilisation state — occupancy lives in
    :class:`LinkSchedule`, keyed by :meth:`Topology.link_key`.
    """
    bandwidth_bps: float   # raw link bandwidth, bits/s
    rtt_s: float           # round-trip time, seconds
    loss: float            # packet loss probability


# Canonical identity of a physical path: an unordered site pair for WAN
# links, None for intra-site movement (the LAN is not a modelled shared
# bottleneck — the per-host rate cap in the transport model bounds it).
LinkKey = Optional[Tuple[str, str]]


class LinkSchedule:
    """Per-link capacity accounting on the simulated clock.

    The transport model prices a transfer *alone* on a link; the planner
    needs the cost of a transfer behind the other transfers already
    scheduled on the same physical path.  A ``LinkSchedule`` tracks, per
    :data:`LinkKey`, the simulated time at which the link next falls
    idle, and serialises reservations on it — the FIFO single-wave model
    (one flow at a time at full effective rate), which for equal-rate
    flows has the same total-completion time as a fair-share model but
    stays deterministic and O(1) per reservation.

    Invariants:

    * ``reserve(key, start, duration)`` returns ``(begin, finish)`` with
      ``begin >= start``, ``begin >= `` every earlier reservation's
      finish on ``key``, and ``finish == begin + duration``;
    * a ``None`` key is never queued: the transfer begins at ``start``
      (uncontended — intra-site, or contention tracking disabled);
    * ``peek`` is ``reserve`` without the state change (used by the
      planner's candidate scoring before it commits a placement);
    * schedules are cheap throwaway objects — one per planned stage (or
      per re-pricing pass), never shared across independent plans.
    """

    def __init__(self) -> None:
        self._free: Dict[Hashable, float] = {}

    def free_at(self, key: Hashable) -> float:
        """Simulated time at which ``key`` next falls idle (0.0 if the
        link has no reservations yet)."""
        return self._free.get(key, 0.0)

    def peek(self, key: LinkKey, start: float,
             duration: float) -> Tuple[float, float]:
        """``reserve`` without committing: what (begin, finish) *would*
        this transfer get right now?"""
        if key is None:
            return start, start + duration
        begin = max(start, self._free.get(key, 0.0))
        return begin, begin + duration

    def reserve(self, key: LinkKey, start: float,
                duration: float) -> Tuple[float, float]:
        """Occupy ``key`` for ``duration`` simulated seconds, no earlier
        than ``start``, behind every existing reservation.  Returns the
        granted ``(begin, finish)`` and advances the link's free time."""
        begin, finish = self.peek(key, start, duration)
        if key is not None:
            self._free[key] = finish
        return begin, finish


@dataclass
class Topology:
    sites: List[str]
    links: Dict[Tuple[str, str], Link] = field(default_factory=dict)
    local: Link = Link(10e9, 0.0002, 1e-7)  # intra-site LAN
    # Fallback for site pairs with no configured link — e.g. a server
    # joining from a site the testbed config predates.  Placement and
    # transfers must keep working when membership grows, so ``link``
    # falls back to this deliberately pessimistic commodity WAN path
    # (1 Gbps, 250 ms RTT, lossy — strictly worse than every provisioned
    # testbed route) instead of raising KeyError; the cost model then
    # naturally steers locality-aware scheduling and nearest-replica
    # reads away from the unprovisioned route.  Every query on this
    # class — ``link``, ``distance``, ``link_key``,
    # ``effective_bandwidth_bps`` — shares the one fallback, so no
    # topology query ever raises for an unknown site.
    default_wan: Link = Link(1e9, 0.250, 5.1e-4)

    def link(self, a: str, b: str) -> Link:
        """The physical path between sites ``a`` and ``b``.

        Symmetric (``link(a, b) is link(b, a)`` for provisioned pairs);
        ``a == b`` returns the intra-site LAN; unknown pairs return
        ``default_wan`` (never raises — see the field comment)."""
        if a == b:
            return self.local
        got = self.links.get((a, b)) or self.links.get((b, a))
        return got if got is not None else self.default_wan

    def link_key(self, a: str, b: str) -> LinkKey:
        """Canonical identity of the path between two sites — the key
        per-link capacity accounting (:class:`LinkSchedule`) queues on.

        ``None`` for ``a == b`` (intra-site movement is uncontended in
        the model: the end-host rate cap, not the LAN, is the local
        bottleneck).  Cross-site pairs map to the *unordered* pair, so
        ``a->b`` and ``b->a`` transfers contend for the same wave —
        matching :meth:`link`'s symmetric lookup.  Unknown pairs get
        their own key (each unprovisioned route is its own commodity
        path), consistent with :meth:`link`'s fallback."""
        if a == b:
            return None
        return (a, b) if a <= b else (b, a)

    def add(self, a: str, b: str, bandwidth_bps: float, rtt_s: float,
            loss: float) -> None:
        self.links[(a, b)] = Link(bandwidth_bps, rtt_s, loss)

    def distance(self, a: str, b: str) -> float:
        """Smaller is closer: RTT-dominated metric (paper reads choose
        the nearest replica).  Delegates to :meth:`link`, so unknown
        sites see the same ``default_wan`` fallback instead of raising —
        ``distance`` and ``link`` can never disagree about which path a
        site pair is on (regression-tested in ``tests/test_sector.py``).
        """
        return self.link(a, b).rtt_s

    def effective_bandwidth_bps(self, a: str, b: str,
                                protocol: str = "udt") -> float:
        """LLPR-style achievable rate between two sites, in **bits/s**.

        What one steady-state flow of ``protocol`` actually gets on
        ``link(a, b)`` — the raw wave derated by end-host capacity and
        the protocol's loss x RTT behaviour, i.e. the model behind the
        paper's Table 1 (``llpr = effective / local effective``).  This
        is the number bandwidth-aware decisions weight on:
        LLPR-weighted replica placement
        (:meth:`repro.sector.master.SectorMaster.place_llpr`) and the
        planner's transfer pricing both consume it rather than
        ``bandwidth_bps``.  Intra-site pairs return the local
        effective rate (the end-host cap), never ``inf``."""
        # deferred import: transport imports Link from this module
        from repro.sector.transport import tcp_throughput, udt_throughput
        fn = tcp_throughput if protocol == "tcp" else udt_throughput
        return fn(self.link(a, b))

    def neighbours(self, site: str) -> List[str]:
        return sorted(self.sites, key=lambda s: self.distance(site, s))


def _teraflow() -> Topology:
    """The paper's testbed: Chicago, Pasadena, McLean/Greenbelt, Tokyo,
    Daejeon on 10 Gbps links. RTTs approximate the published geography
    (furthest pair ~200 ms)."""
    t = Topology(sites=["chicago", "pasadena", "greenbelt", "mclean",
                        "tokyo", "daejeon"])
    wan = 10e9
    rtts = {
        ("chicago", "pasadena"): 0.055,
        ("chicago", "greenbelt"): 0.020,
        ("chicago", "mclean"): 0.022,
        ("chicago", "tokyo"): 0.130,
        ("chicago", "daejeon"): 0.165,
        ("pasadena", "greenbelt"): 0.070,
        ("pasadena", "mclean"): 0.072,
        ("pasadena", "tokyo"): 0.110,
        ("pasadena", "daejeon"): 0.145,
        ("greenbelt", "mclean"): 0.004,
        ("greenbelt", "tokyo"): 0.150,
        ("greenbelt", "daejeon"): 0.200,
        ("mclean", "tokyo"): 0.150,
        ("mclean", "daejeon"): 0.195,
        ("tokyo", "daejeon"): 0.035,
    }
    for (a, b), rtt in rtts.items():
        # long-haul paths see more residual loss than the LAN (~2e-3/s of
        # RTT matches the Table-1 efficiency ordering)
        loss = 1e-5 + rtt * 2e-3
        t.add(a, b, wan, rtt, loss)
    return t


TERAFLOW_TESTBED = _teraflow()


def _open_cloud() -> Topology:
    """The Open Cloud Testbed (arXiv:0907.4810): four racks — Johns
    Hopkins (Baltimore), StarLight (Chicago), UIC (Chicago), Calit2 (San
    Diego) — joined by dedicated 10 Gbps wide-area paths.  The two
    Chicago sites are a metro hop apart; Baltimore-San Diego is the
    long transcontinental pair."""
    t = Topology(sites=["baltimore", "starlight", "uic", "calit2"])
    wan = 10e9
    rtts = {
        ("starlight", "uic"): 0.002,       # Chicago metro
        ("baltimore", "starlight"): 0.022,
        ("baltimore", "uic"): 0.023,
        ("starlight", "calit2"): 0.060,
        ("uic", "calit2"): 0.061,
        ("baltimore", "calit2"): 0.075,
    }
    for (a, b), rtt in rtts.items():
        loss = 1e-5 + rtt * 2e-3           # same residual-loss model
        t.add(a, b, wan, rtt, loss)
    return t


OPEN_CLOUD_TESTBED = _open_cloud()
