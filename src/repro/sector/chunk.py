"""Chunk and file metadata."""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import List, Set


CHUNK_SIZE = 64 * 1024 * 1024  # 64 MB default (GFS-style)


def checksum(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


@dataclass
class ChunkMeta:
    chunk_id: str             # "<file>#<index>"
    file: str
    index: int
    size: int
    digest: str
    version: int = 0
    locations: Set[str] = field(default_factory=set)  # server ids

    @staticmethod
    def make_id(file: str, index: int) -> str:
        return f"{file}#{index}"


@dataclass
class FileMeta:
    name: str
    size: int
    n_chunks: int
    owner: str
    replication: int
    chunk_ids: List[str] = field(default_factory=list)
