"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: AxisType.Auto where it exists
    (jax >= 0.5), plain make_mesh otherwise."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod mesh, or 2x16x16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Same axis names over however many devices exist (CPU tests)."""
    n = jax.device_count()
    if multi_pod:
        return make_mesh_compat((1, n, 1), ("pod", "data", "model"))
    return make_mesh_compat((n, 1), ("data", "model"))


def make_flat_mesh(axis: str = "data"):
    """1-D mesh over all devices (Sphere SPMD jobs, sort benchmarks)."""
    return make_mesh_compat((jax.device_count(),), (axis,))
