"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod mesh, or 2x16x16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_debug_mesh(*, multi_pod: bool = False):
    """Same axis names over however many devices exist (CPU tests)."""
    n = jax.device_count()
    if multi_pod:
        return jax.make_mesh((1, n, 1), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))


def make_flat_mesh(axis: str = "data"):
    """1-D mesh over all devices (Sphere SPMD jobs, sort benchmarks)."""
    return jax.make_mesh((jax.device_count(),), (axis,), axis_types=_auto(1))
