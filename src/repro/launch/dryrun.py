import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init). 512 placeholder host devices let
# ``jax.make_mesh`` build the production meshes for lower+compile dry-runs.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params, optimizer state,
     batch and decode caches (no allocation),
  3. ``jit(step).lower(...).compile()`` with explicit in/out shardings,
  4. records ``memory_analysis()`` (proves the cell fits HBM),
     ``cost_analysis()`` (FLOPs / bytes for §Roofline) and the collective
     byte totals parsed from the compiled HLO (all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute), split into intra-pod
     (ICI) vs cross-pod (DCN) traffic,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.models.inputs import input_specs
from repro.parallel.sharding import ParallelConfig, param_specs_for
from repro.train import optim
from repro.train.step import (batch_specs_for, cache_specs_for,
                              make_prefill_step, make_serve_step,
                              make_train_step, opt_state_specs_for,
                              to_shardings)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# --- TPU v5e hardware constants (roofline denominators) --------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (intra-pod)
DCN_BW = 25e9                # bytes/s per chip share (cross-pod hop)

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+ = )?\(?([a-z0-9_\[\]{},/ ]*?)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' HLO shape string."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    sizes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * sizes.get(dt, 4)


def parse_collectives(hlo_text: str, pod_stride: int = 256,
                      loop_trip: int = 1) -> dict:
    """Sum result bytes of every collective op; split intra- vs cross-pod.

    Cross-pod detection: a replica group containing device ids that differ
    by >= pod_stride spans pods (mesh order is (pod, data, model)).

    Scan correction: collectives whose op_name metadata contains "/while/"
    execute once per scan iteration (the layer loop — the only
    collective-bearing loop in this framework), so their bytes are
    multiplied by ``loop_trip`` (= n_groups for the cell's arch). Raw
    (uncorrected) totals are kept under ``raw_total``.
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    cross = 0
    intra = 0
    raw_total = 0
    for line in hlo_text.splitlines():
        m = re.search(
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m or "-done" in line[:line.find("(")]:
            continue
        eq = line.find(" = ")
        if eq < 0:
            continue
        rhs = line[eq + 3:]
        shapes = re.findall(r"(?:f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|"
                            r"s8|u8|pred|f8e4m3fn|f8e5m2)\[[0-9,]*\]",
                            rhs[:rhs.find("(")] if "(" in rhs else rhs)
        nbytes = sum(_shape_bytes(s) for s in shapes)
        raw_total += nbytes
        mult = loop_trip if "/while/" in line else 1
        nbytes *= mult
        out[m.group(1)] += nbytes
        groups = re.search(r"replica_groups=\{?\{([0-9, ]+)\}", line)
        is_cross = False
        if groups:
            ids = [int(x) for x in groups.group(1).replace(" ", "").split(",")
                   if x]
            if ids and (max(ids) - min(ids)) >= pod_stride:
                is_cross = True
        if is_cross:
            cross += nbytes
        else:
            intra += nbytes
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    out["cross_pod"] = cross
    out["intra_pod"] = intra
    out["raw_total"] = raw_total
    out["loop_trip"] = loop_trip
    return out


def build_cell(cfg: ModelConfig, shape: ShapeConfig, pcfg: ParallelConfig,
               ocfg: optim.AdamWConfig):
    """Returns (fn, args_specs as ShapeDtypeStructs, in_shardings,
    out_shardings, donate)."""
    mesh = pcfg.mesh
    pshapes = model.param_shapes(cfg)
    pspecs = param_specs_for(pshapes, pcfg)
    batch_tree = input_specs(cfg, shape)

    if shape.kind == "train":
        ostate = optim.state_shapes(pshapes, ocfg)
        if ocfg.error_feedback and pcfg.multi_pod:
            npods = pcfg.axis_sizes.get("pod", 1)
            ostate["ef"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((npods,) + s.shape, s.dtype),
                pshapes)
        ospecs = opt_state_specs_for(pshapes, pcfg, ocfg)
        bspecs = batch_specs_for(batch_tree, pcfg)
        fn = make_train_step(cfg, pcfg, ocfg,
                             optim.warmup_cosine(3e-4, 1000, 100_000))
        args = (pshapes, ostate, batch_tree)
        in_specs = (pspecs, ospecs, bspecs)
        out_specs = (pspecs, ospecs,
                     None)  # metrics: let XLA choose (scalars)
        donate = (0, 1) if pcfg.donate else ()
        return fn, args, in_specs, out_specs, donate

    if shape.kind == "prefill":
        bspecs = batch_specs_for(batch_tree, pcfg)
        fn = make_prefill_step(cfg, pcfg, max_len=shape.seq_len)
        args = (pshapes, batch_tree)
        return fn, args, (pspecs, bspecs), None, ()

    # decode
    cross_len = shape.seq_len if cfg.is_encoder_decoder else 0
    cache_tree = model.cache_shapes(cfg, shape.global_batch, shape.seq_len,
                                    cross_len=cross_len)
    cspecs = cache_specs_for(cache_tree, pcfg)
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    bspec = batch_specs_for({"t": tok, "p": pos}, pcfg)
    fn = make_serve_step(cfg, pcfg)
    args = (pshapes, cache_tree, tok, pos)
    in_specs = (pspecs, cspecs, bspec["t"], bspec["p"])
    out_specs = (bspec["t"], cspecs)
    donate = (1,) if pcfg.donate else ()
    return fn, args, in_specs, out_specs, donate


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             knobs: dict | None = None, tag: str = "",
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    pcfg = ParallelConfig(mesh=mesh, multi_pod=multi_pod,
                          **(knobs or {}))
    ocfg = optim.AdamWConfig(
        error_feedback=(pcfg.compress_pod == "int8_ef"))
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "knobs": knobs or {}, "ok": False}
    try:
        fn, args, in_specs, out_specs, donate = build_cell(
            cfg, shape, pcfg, ocfg)
        in_sh = to_shardings(in_specs, mesh)
        out_sh = to_shardings(out_specs, mesh) if out_specs is not None \
            else None
        with jax.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(ma, "temp_size_in_bytes", 0))
                + int(getattr(ma, "argument_size_in_bytes", 0)),
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"error": str(e)}

        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))} if ca else {}

        hlo = compiled.as_text()
        trip = 1 if pcfg.unroll_scans else cfg.n_groups
        rec["collectives"] = parse_collectives(hlo, loop_trip=trip)
        rec["hlo_bytes"] = len(hlo)
        rec["timing"] = {"lower_s": t_lower, "compile_s": t_compile}
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = time.time() - t0

    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        (OUT_DIR / f"{cell_id}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every cell; both meshes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--knob", action="append", default=[],
                    help="k=v ParallelConfig overrides (repeatable)")
    args = ap.parse_args(argv)

    knobs = {}
    for kv in args.knob:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        knobs[k] = v

    todo = []
    if args.all:
        for arch, shape in cells():
            todo.append((arch, shape, False))
            todo.append((arch, shape, True))
    else:
        todo.append((args.arch, args.shape, args.multi_pod))

    n_ok = 0
    for arch, shape, mp in todo:
        mesh_name = "2x16x16" if mp else "16x16"
        cell_id = f"{arch}__{shape}__{mesh_name}" + \
            (f"__{args.tag}" if args.tag else "")
        if args.skip_existing and (OUT_DIR / f"{cell_id}.json").exists():
            prior = json.loads((OUT_DIR / f"{cell_id}.json").read_text())
            if prior.get("ok"):
                n_ok += 1
                print(f"[skip] {cell_id} (ok)")
                continue
        rec = run_cell(arch, shape, multi_pod=mp, knobs=knobs, tag=args.tag)
        status = "OK " if rec["ok"] else "FAIL"
        flops = rec.get("cost", {}).get("flops", 0)
        coll = rec.get("collectives", {}).get("total", 0)
        print(f"[{status}] {cell_id} wall={rec['wall_s']:.1f}s "
              f"flops/dev={flops:.3e} coll_bytes/dev={coll:.3e}"
              + ("" if rec["ok"] else f" err={rec.get('error')}"))
        n_ok += rec["ok"]
    print(f"{n_ok}/{len(todo)} cells OK")
    return 0 if n_ok == len(todo) else 1


if __name__ == "__main__":
    sys.exit(main())
