"""Serving launcher: continuous-batching engine over a slot pool.

    python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.parallel.sharding import ParallelConfig
from repro.serve import SamplerConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = None
        pcfg = ParallelConfig(mesh=None)
    else:
        mesh = make_production_mesh()
        pcfg = ParallelConfig(mesh=mesh)

    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, pcfg, max_batch=args.max_batch,
                      max_len=args.max_len,
                      scfg=SamplerConfig(temperature=args.temperature,
                                         top_k=40))
    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, min(32, args.max_len // 2)))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        reqs.append(eng.submit(prompt, max_new=args.max_new))
    eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs[:4]:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out[:8]}...")
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, continuous batching over "
          f"{args.max_batch} slots)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
