"""Training launcher.

    python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 50

``--smoke`` runs the reduced config on local devices (CPU-runnable); without
it the full config is used (real-TPU scale). The driver stands up a complete
wide-area deployment in-process: Sector servers at every testbed site, a
synthetic corpus uploaded through the cloud, locality-aware data pipeline,
Sector-replicated checkpoints, and the Sphere-staged train step.
"""
from __future__ import annotations

import argparse
import json
import tempfile


from repro.configs import get_config, list_archs
from repro.data import DataPipeline, SectorTokenDataset, write_synthetic_corpus
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.parallel.sharding import ParallelConfig
from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.train import SectorCheckpointer, Trainer, TrainerConfig


def build_cloud(chunk_size: int = 256 * 1024, n_servers: int = 6):
    tmp = tempfile.mkdtemp(prefix="sector_")
    master = SectorMaster(chunk_size=chunk_size)
    sites = master.topology.sites
    for i in range(n_servers):
        master.register(ChunkServer(f"s{i}", sites[i % len(sites)], tmp))
    master.acl.add_member("trainer")
    master.acl.grant_write("trainer")
    client = SectorClient(master, "trainer", "chicago")
    return master, client


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--tokens", type=int, default=2_000_000)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="pjit", choices=["pjit", "podwise"])
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_debug_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    pcfg = ParallelConfig(mesh=mesh, multi_pod=args.multi_pod,
                          mode=args.mode, compress_pod=args.compress,
                          remat="none" if args.smoke else "full")

    master, client = build_cloud()
    write_synthetic_corpus(client, "corpus/train.u32", args.tokens,
                           cfg.vocab_size)
    ds = SectorTokenDataset(master, client, "corpus/train.u32",
                            seq_len=args.seq)
    pipe = DataPipeline(ds, batch=args.batch, pcfg=pcfg)
    ckpt = SectorCheckpointer(client, f"{args.arch}-train")
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         log_every=max(args.steps // 10, 1), lr=args.lr)
    trainer = Trainer(cfg, pcfg, tcfg, pipe, ckpt)
    hist = trainer.run()
    for rec in hist:
        print(f"step {rec['step']:5d} loss={rec['loss']:.4f} "
              f"lr={rec['lr']:.2e} gnorm={rec['grad_norm']:.2f} "
              f"wall={rec['wall_s']:.1f}s")
    print(f"data locality: {ds.locality_fraction:.2f}; "
          f"sector stats: {master.stats()}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
