"""xlstm-1.3b — sLSTM + mLSTM blocks, attention-free.

[arXiv:2405.04517; unverified tier] 48L d_model=2048 4H vocab=50304, d_ff=0
(projection factors live inside the blocks). Public 1.3B xLSTM uses a
7:1 mLSTM:sLSTM ratio -> pattern unit (m,m,m,m,m,m,m,s) x 6 groups.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_head=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=1.3333,
    conv1d_width=4,
    act="gelu",
    source="arXiv:2405.04517 (xLSTM[7:1] 1.3B)",
    notes="Attention-free; O(1) decode state; long_500k natural fit. "
    "mLSTM trains via chunkwise-parallel scan, decodes recurrently.",
)
