"""llava-next-mistral-7b — VLM: Mistral-7B backbone + anyres patch frontend stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified tier]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed anyres patch embeddings (2880 positions =
24x24 base grid x 5 anyres tiles) which the backbone scatters into the
token-embedding stream at the given positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    block_pattern=("A",),
    act="silu",
    frontend="vision_patches",
    frontend_positions=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    notes="Backbone only; anyres vision tower stubbed to patch embeddings.",
)
