"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, fine-grained experts.

[hf:Qwen/Qwen3-30B-A3B; hf-verified] 48L d_model=2048 32H (GQA kv=4)
per-expert d_ff=768, vocab=151936, 128 experts top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=0,  # every FFN is MoE
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("A",),
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    act="silu",
    source="hf:Qwen/Qwen3-30B-A3B",
    notes="Fine-grained 128-expert MoE; q_dim=4096 from d_model=2048 "
    "(head_dim decoupled). Sphere-shuffle == MoE all_to_all dispatch.",
)
