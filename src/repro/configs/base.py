"""Base model/shape configuration for the Sector/Sphere LM framework.

Every assigned architecture is expressed as a single frozen ``ModelConfig``.
The config is deliberately rich enough to cover all six families in the
assignment pool:

  dense          -- llama/qwen-style decoder-only transformers (GQA, RoPE)
  moe            -- dense backbone with MoE FFN (top-k routing, EP sharding)
  vlm            -- dense LM backbone + vision-patch frontend stub
  audio-encdec   -- encoder-decoder transformer + audio-frame frontend stub
  xlstm          -- sLSTM + mLSTM recurrent blocks (attention-free)
  hybrid-rglru   -- RG-LRU recurrent blocks interleaved with local attention

The *shape* configs (train_4k / prefill_32k / decode_32k / long_500k) are the
assigned input-shape set shared by all LM-family architectures.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (a dry-run / roofline cell column)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """A complete architecture description.

    ``block_pattern`` describes one *pattern unit* of layers which is stacked
    ``n_layers / len(block_pattern)`` times and lowered as a ``lax.scan`` over
    the stacked groups (keeps the HLO compact for 512-device compiles).

    Pattern symbols:
      "A"  full (global) causal attention + FFN
      "L"  local sliding-window attention + FFN
      "R"  RG-LRU recurrent block + FFN         (recurrentgemma)
      "m"  mLSTM block                          (xlstm)
      "s"  sLSTM block                          (xlstm)
    """

    name: str
    family: str  # dense | moe | vlm | audio-encdec | xlstm | hybrid-rglru

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0   # per-layer-type theta (0 = same as global)
    local_window: int = 0           # sliding-window size for "L" layers
    block_pattern: Tuple[str, ...] = ("A",)
    logit_softcap: float = 0.0      # gemma-style final logit soft-capping
    attn_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert FFN width
    router_aux_coef: float = 0.001  # load-balancing loss coefficient

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_is_causal: bool = False

    # --- recurrent (xlstm / rglru) -------------------------------------------
    lru_width: int = 0              # RG-LRU recurrence width (rglru)
    conv1d_width: int = 4
    mlstm_proj_factor: float = 2.0  # mLSTM up-projection factor
    mlstm_qkv_blocksize: int = 4    # block-diagonal q/k/v projection blocks
    slstm_proj_factor: float = 1.3333

    # --- embeddings / norm / act ---------------------------------------------
    norm_eps: float = 1e-6
    act: str = "silu"               # silu | gelu | geglu handled in mlp.py
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma-style sqrt(d_model) embed scaling

    # --- modality frontend stub ----------------------------------------------
    frontend: str = ""              # "" | "vision_patches" | "audio_frames"
    frontend_positions: int = 0     # patch/frame embeddings provided per sample

    # --- dtype policy ---------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- documentation --------------------------------------------------------
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------ helpers
    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_len == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern of length {self.pattern_len}"
        )
        return self.n_layers // self.pattern_len

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 (MXU/TP alignment)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_attention_free(self) -> bool:
        return all(b in ("m", "s", "R") for b in self.block_pattern)

    @property
    def supports_long_context_decode(self) -> bool:
        """True when the arch decodes 500k context without a full-attention
        KV cache in every layer (sub-quadratic / windowed / stateful)."""
        full_attn_layers = sum(1 for b in self.block_pattern if b == "A")
        return full_attn_layers < self.pattern_len or self.is_attention_free

    def moe_layer(self, symbol: str) -> bool:
        return self.family == "moe" and symbol in ("A", "L")

    # -------------------------------------------------------------- param count
    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # token embedding
        if not self.tie_embeddings:
            total += v * d  # lm head

        def attn_params() -> int:
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                p += 2 * self.d_head
            return p

        def ffn_params(width: int) -> int:
            # gated (SwiGLU/GeGLU): gate + up + down
            return 3 * d * width

        def moe_params() -> int:
            return d * self.n_experts + self.n_experts * 3 * d * self.moe_d_ff

        def rglru_params() -> int:
            w = self.lru_width or d
            # in-proj (x,gate) + conv1d + lru gates (a,x per-channel input proj)
            return 2 * d * w + self.conv1d_width * w + 2 * (w * (w // 8) + w) + w * d

        def mlstm_params() -> int:
            inner = int(d * self.mlstm_proj_factor)
            bs = self.mlstm_qkv_blocksize
            # up-proj (x & z branches) + causal conv + block-diagonal qkv +
            # scalar i/f gates (Linear(3*inner -> n_heads)) + outnorm + down
            return (
                2 * d * inner
                + self.conv1d_width * inner
                + 3 * inner * bs
                + 2 * 3 * inner * self.n_heads
                + inner
                + inner * d
            )

        def slstm_params() -> int:
            # 4 gates (i,f,z,o): dense input proj + block-diag recurrent
            # (n_heads blocks) + bias; then gated FFN at slstm_proj_factor.
            hd = d // self.n_heads
            gates = 4 * (d * d + d * hd + d)
            ffn = 3 * d * int(d * self.slstm_proj_factor)
            return gates + ffn

        per_pattern = 0
        for sym in self.block_pattern:
            if sym in ("A", "L"):
                per_pattern += attn_params()
                if self.family == "moe":
                    per_pattern += moe_params()
                else:
                    per_pattern += ffn_params(self.d_ff)
                per_pattern += 2 * d  # 2 rmsnorms
            elif sym == "R":
                per_pattern += rglru_params() + ffn_params(self.d_ff) + 2 * d
            elif sym == "m":
                per_pattern += mlstm_params() + d
            elif sym == "s":
                per_pattern += slstm_params() + d
            else:
                raise ValueError(sym)

        total += per_pattern * self.n_groups
        total += d  # final norm
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder layers add cross-attn
            enc = (attn_params() + ffn_params(self.d_ff) + 2 * d) * self.n_enc_layers
            xattn = (attn_params() + d) * self.n_layers
            total += enc + xattn
        if self.frontend == "vision_patches":
            total += 2 * d * d  # 2-layer MLP projector (stub, but real params)
        if self.frontend == "audio_frames":
            total += d * d  # frame projector
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        expert_p = self.n_experts * 3 * self.d_model * self.moe_d_ff
        active_expert_p = self.top_k * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = self.n_layers
        return full - n_moe_layers * (expert_p - active_expert_p)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------- reduced config
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat = self.block_pattern
        return self.replace(
            name=self.name + "-smoke",
            n_layers=len(pat) * min(2, self.n_groups),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            n_enc_layers=2 if self.is_encoder_decoder else 0,
            lru_width=64 if self.lru_width else 0,
            local_window=min(self.local_window, 64) if self.local_window else 0,
            frontend_positions=min(self.frontend_positions, 8),
        )


def assert_valid(cfg: ModelConfig) -> None:
    assert cfg.n_layers % cfg.pattern_len == 0, cfg.name
    assert cfg.n_heads % max(cfg.n_kv_heads, 1) == 0, cfg.name
    if cfg.family == "moe":
        assert cfg.n_experts > 0 and cfg.top_k > 0 and cfg.moe_d_ff > 0
    if cfg.is_encoder_decoder:
        assert cfg.n_enc_layers > 0
