"""deepseek-7b — llama-architecture dense decoder-only.

[arXiv:2401.02954; hf-verified] 30L d_model=4096 32H (kv=32, i.e. MHA)
d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10000.0,
    block_pattern=("A",),
    act="silu",
    source="arXiv:2401.02954",
    notes="LLaMA architecture; full MHA (kv=32).",
)
