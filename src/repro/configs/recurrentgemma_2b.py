"""recurrentgemma-2b — RG-LRU recurrent blocks + local attention.

[arXiv:2402.19427 (Griffin); hf-verified] 26L d_model=2560 10H (MQA kv=1)
d_ff=7680 vocab=256000.

Griffin/RecurrentGemma interleaves two RG-LRU residual blocks with one
local-MQA block (recurrent:attention = 2:1) and ends the stack on recurrent
blocks. 26 layers do not factor into (R,R,A) units exactly, so we scan
2 groups of a 13-layer unit with 9 R + 4 A per unit (attention every third
block, recurrent tail) — 18 R : 8 A overall, preserving the published ~2:1
ratio and tail placement while keeping the HLO scan-compact.
"""
from repro.configs.base import ModelConfig

_UNIT = ("R", "R", "L", "R", "R", "L", "R", "R", "L", "R", "R", "L", "R")

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid-rglru",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    rope_theta=10000.0,
    local_window=2048,
    block_pattern=_UNIT,
    lru_width=2560,
    conv1d_width=4,
    act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2402.19427",
    notes="RG-LRU + local MQA (window 2048); bounded decode state -> "
    "long_500k runnable. 'L' layers are local sliding-window MQA.",
)
