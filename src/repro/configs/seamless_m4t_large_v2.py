"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio frontend stub).

[arXiv:2308.11596; hf-verified] 24L (decoder) d_model=1024 16H (kv=16, MHA)
d_ff=8192 vocab=256206; encoder is 24L as well.

The speech frontend (conformer feature extractor) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
[B, S_frames, d_model] fed straight to the text/unit encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio-encdec",
    n_layers=24,            # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10000.0,
    block_pattern=("A",),
    is_encoder_decoder=True,
    n_enc_layers=24,
    act="gelu",
    frontend="audio_frames",
    frontend_positions=0,   # the whole encoder input is frames
    source="arXiv:2308.11596 (SeamlessM4T v2)",
    notes="Enc-dec; decode uses self-attn KV cache + cross-attn cache over "
    "encoder memory. Audio frontend stubbed to frame embeddings.",
)
