"""Architecture registry: ``get_config("<arch-id>")`` and the shape table.

Arch ids match the assignment exactly (``--arch <id>`` on every launcher).
"""
from __future__ import annotations

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    assert_valid,
)

from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma

ARCHS = {
    c.name: c
    for c in (
        _qwen25,
        _deepseek,
        _gemma3,
        _qwen3,
        _qwen3moe,
        _dbrx,
        _llava,
        _seamless,
        _xlstm,
        _rgemma,
    )
}

for _c in ARCHS.values():
    assert_valid(_c)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def cells():
    """All (arch, shape) dry-run cells, honouring the long_500k skip rule.

    ``long_500k`` requires sub-quadratic attention: run only for archs whose
    decode state is bounded (windowed / recurrent); skip for pure
    full-attention stacks (recorded in DESIGN.md §Arch-applicability).
    """
    out = []
    for name, cfg in sorted(ARCHS.items()):
        for shape in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K):
            if shape.name == "long_500k" and not cfg.supports_long_context_decode:
                continue
            out.append((name, shape.name))
    return out


__all__ = [
    "ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_config",
    "list_archs",
    "cells",
]
