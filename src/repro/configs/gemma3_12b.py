"""gemma3-12b — dense with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family; unverified tier]
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Pattern unit: 5 local sliding-window layers then 1 global layer.
Gemma3 uses d_head=256 (not d_model/n_heads) per the public config.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    local_window=1024,
    block_pattern=("L", "L", "L", "L", "L", "A"),
    act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    source="hf:google/gemma-3-12b-pt (shape per assignment)",
    notes="5:1 local:global; qk-norm; GeGLU; tied + scaled embeddings; "
    "long_500k runnable (only 1/6 layers keep a full KV cache).",
)
