"""Per-arch smoke + cross-path consistency (forward vs prefill vs decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model
from repro.parallel.sharding import ParallelConfig

ALL = sorted(ARCHS)


def make_batch(cfg, B=2, S=12, seed=3, fp32=False):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"inputs": toks, "labels": toks}
    dt = jnp.float32 if fp32 else jnp.bfloat16
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jax.random.normal(
            rng, (B, S, cfg.d_model), dt)
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.frontend_positions, cfg.d_model), dt)
        batch["patch_pos"] = jnp.tile(
            jnp.arange(cfg.frontend_positions)[None], (B, 1))
    return batch


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_train_step(name):
    """Reduced config: one forward + one train step; shapes + no NaNs."""
    cfg = ARCHS[name].reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, aux = model.forward(params, batch, cfg=cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    from repro.train import optim
    from repro.train.step import make_train_step
    ocfg = optim.AdamWConfig(lr=1e-3)
    step = make_train_step(cfg, ParallelConfig(mesh=None, remat="full"),
                           ocfg, optim.warmup_cosine(1e-3, 2, 10))
    opt = optim.init_state(params, ocfg)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(o2["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("name", ALL)
def test_prefill_decode_consistency(name, monkeypatch):
    """fp32: decode continuation must match the full forward pass.

    MoE archs run with a no-drop capacity factor: capacity-based token
    dropping legitimately differs between batch compositions (the same token
    can overflow in a 12-token group but fit in a 1-token group), which is a
    property of the routing algorithm, not a decode bug."""
    if ARCHS[name].family == "moe":
        from repro.models import moe
        monkeypatch.setattr(moe, "CAPACITY_FACTOR", 8.0)
    cfg = ARCHS[name].reduced().replace(param_dtype="float32",
                                        compute_dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, fp32=True)
    logits_full, _ = model.forward(params, batch, cfg=cfg)

    pre = dict(batch)
    pre["inputs"] = batch["inputs"][:, :S - 1]
    last_logits, cache = model.prefill(params, pre, cfg=cfg, max_len=S + 4)
    a = np.asarray(logits_full[:, S - 2], np.float32)
    b = np.asarray(last_logits, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-9) < 0.02, name

    dec_logits, _ = model.decode_step(
        params, cache, batch["inputs"][:, S - 1:S],
        jnp.full((B,), S - 1, jnp.int32), cfg=cfg)
    a2 = np.asarray(logits_full[:, S - 1], np.float32)
    b2 = np.asarray(dec_logits, np.float32)
    assert np.abs(a2 - b2).max() / (np.abs(a2).max() + 1e-9) < 0.05, name


@pytest.mark.parametrize("name", ["qwen2.5-3b", "gemma3-12b",
                                  "recurrentgemma-2b", "xlstm-1.3b"])
def test_causality(name):
    """Changing future tokens must not change past logits."""
    cfg = ARCHS[name].reduced().replace(param_dtype="float32",
                                        compute_dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 10
    batch = make_batch(cfg, B, S, fp32=True)
    l1, _ = model.forward(params, batch, cfg=cfg)
    batch2 = dict(batch)
    batch2["inputs"] = batch["inputs"].at[:, -1].set(
        (batch["inputs"][:, -1] + 7) % cfg.vocab_size)
    l2, _ = model.forward(params, batch2, cfg=cfg)
    a = np.asarray(l1[:, :-1], np.float32)
    b = np.asarray(l2[:, :-1], np.float32)
    assert np.abs(a - b).max() < 1e-4


def test_multi_step_decode_matches_forward():
    """Greedy decode 4 steps == forward on the same (teacher-forced) tokens."""
    cfg = ARCHS["qwen3-8b"].reduced().replace(param_dtype="float32",
                                              compute_dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(4))
    B, S, n_new = 1, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + n_new), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"inputs": toks}, cfg=cfg)
    _, cache = model.prefill(params, {"inputs": toks[:, :S]}, cfg=cfg,
                             max_len=S + n_new + 2)
    for t in range(n_new):
        logits, cache = model.decode_step(
            params, cache, toks[:, S + t:S + t + 1],
            jnp.full((B,), S + t, jnp.int32), cfg=cfg)
        a = np.asarray(full[:, S + t], np.float32)
        b = np.asarray(logits, np.float32)
        assert np.abs(a - b).max() / (np.abs(a).max() + 1e-9) < 0.05
