"""Property-based tests: checkpoint serialisation over random pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.train.checkpoint import deserialize, serialize

_DTYPES = ["float32", "bfloat16", "int32", "uint32", "float16"]


@st.composite
def pytrees(draw):
    n = draw(st.integers(1, 5))
    tree = {}
    for i in range(n):
        shape = tuple(draw(st.lists(st.integers(1, 7), min_size=0,
                                    max_size=3)))
        dt = draw(st.sampled_from(_DTYPES))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        if dt in ("int32", "uint32"):
            arr = rng.integers(0, 1000, size=shape).astype(dt)
            leaf = jnp.asarray(arr)
        else:
            leaf = jnp.asarray(rng.normal(size=shape), dtype=dt)
        depth = draw(st.integers(0, 1))
        if depth:
            tree[f"g{i}"] = {"w": leaf}
        else:
            tree[f"l{i}"] = leaf
    return tree


@given(pytrees())
@settings(max_examples=25, deadline=None)
def test_serialize_roundtrip_exact(tree):
    payload, manifest = serialize(tree)
    back = deserialize(payload, manifest, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert a.shape == b.shape
        # bf16 round-trips exactly through fp32 storage
        assert bool(jnp.all(a == b)), (a.dtype, a.shape)


@given(pytrees(), st.integers(0, 200))
@settings(max_examples=15, deadline=None)
def test_corruption_always_detected(tree, flip_at):
    payload, manifest = serialize(tree)
    if not payload:
        return
    pos = flip_at % len(payload)
    corrupted = payload[:pos] + bytes([payload[pos] ^ 0xFF]) \
        + payload[pos + 1:]
    try:
        deserialize(corrupted, manifest, tree)
        assert False, "hash mismatch not raised"
    except IOError:
        pass
