"""Wide-area control plane: link identity/fallback regressions and
LLPR-weighted replica placement (paper §5 / Table 1 provenance).

Separate from ``test_sector.py`` so these run without the optional
``hypothesis`` dependency."""
import pytest

from repro.sector import ChunkServer, SectorClient, SectorMaster
from repro.sector.topology import TERAFLOW_TESTBED, Topology


def _degraded_topology():
    """Three sites where the routes from ``home`` differ sharply: a
    clean metro wave to ``near`` and a lossy transcontinental path to
    ``far`` whose UDT effective bandwidth is ~8x lower — the OCT routes
    are all end-host-capped to within 10%, so proportionality needs a
    topology with a genuinely degraded route."""
    t = Topology(sites=["home", "near", "far"])
    t.add("home", "near", 10e9, 0.002, 1e-7)
    t.add("home", "far", 10e9, 0.200, 5e-3)
    t.add("near", "far", 10e9, 0.200, 5e-3)
    return t


def test_llpr_placement_shares_track_effective_bandwidth(tmp_path):
    """Rendezvous shares are proportional to LLPR effective bandwidth:
    the degraded route's site gets a several-fold smaller share of
    single-replica placements, while two equally-reachable sites split
    evenly."""
    topo = _degraded_topology()
    master = SectorMaster(topology=topo, llpr_placement=True)
    for site in topo.sites:
        master.register(ChunkServer(f"{site}0", site, tmp_path))

    w = {s: topo.effective_bandwidth_bps("home", s) for s in topo.sites}
    assert w["near"] / w["far"] > 4          # the route really is degraded

    counts = {s: 0 for s in topo.sites}
    n_keys = 2000
    for i in range(n_keys):
        (srv,) = master.place_llpr(f"k{i}", 1, "home")
        counts[srv[:-1]] += 1
    share = {s: counts[s] / n_keys for s in topo.sites}
    expect = {s: w[s] / sum(w.values()) for s in topo.sites}
    for s in topo.sites:  # exponential-race property, +-25% relative
        assert share[s] == pytest.approx(expect[s], rel=0.25), (s, share)
    assert share["far"] < share["near"] / 3


def test_llpr_placement_is_deterministic_and_spreads_sites(tmp_path):
    """Same key -> same replica set; multi-replica placement prefers
    distinct sites before doubling up (the HashRing.place contract,
    kept under LLPR weighting)."""
    topo = _degraded_topology()
    master = SectorMaster(topology=topo, llpr_placement=True)
    for site in topo.sites:
        for k in range(2):
            master.register(ChunkServer(f"{site}{k}", site, tmp_path))
    a = master.place_llpr("some-chunk", 3, "home")
    assert a == master.place_llpr("some-chunk", 3, "home")
    assert len({s[:-1] for s in a}) == 3     # one server per site first
    b = master.place_llpr("some-chunk", 5, "home")
    assert b[:3] == a                        # growing n extends the set


def test_repair_uses_llpr_destinations(tmp_path):
    """Re-replication after a failure routes through the same LLPR
    placement: with the far route degraded, repairs of home-written
    data land on near-site servers while any are available."""
    topo = _degraded_topology()
    master = SectorMaster(topology=topo, chunk_size=1024,
                          llpr_placement=True, heartbeat_timeout=5.0)
    for site in ("home", "near"):
        for k in range(2):
            master.register(ChunkServer(f"{site}{k}", site, tmp_path))
    master.register(ChunkServer("far0", "far", tmp_path))
    master.acl.add_member("u")
    master.acl.grant_write("u")
    client = SectorClient(master, "u", "home")
    client.upload("f", bytes(4 * 1024), replication=2)

    victim = next(iter(master.chunks.values())).locations.copy().pop()
    master.deregister(victim)     # graceful loss: marks under-replicated
    plan = master.repair_plan()
    assert plan, "under-replicated chunks must produce repair work"
    for _, src, dst in plan:
        assert master.servers[dst].alive and dst != victim
        # the degraded site is the last resort, never preferred while a
        # home/near server can take the replica
        assert master.servers[dst].site != "far"


def test_distance_and_link_agree_on_unknown_pairs():
    """Regression: ``distance`` delegates to ``link``, so an unknown
    site pair gets the default-WAN RTT symmetrically — the two queries
    can never disagree about which path a pair is on (a divergent
    hand-rolled fallback once made nearest-replica reads and transfer
    pricing rank routes differently)."""
    t = TERAFLOW_TESTBED
    assert t.distance("chicago", "atlantis") == t.default_wan.rtt_s
    assert t.distance("atlantis", "chicago") == t.default_wan.rtt_s
    assert t.distance("atlantis", "atlantis") == t.local.rtt_s
    for (a, b) in t.links:
        assert t.distance(a, b) == t.distance(b, a) == t.link(a, b).rtt_s
        assert t.link_key(a, b) == t.link_key(b, a) is not None
    assert t.link_key("x", "x") is None
