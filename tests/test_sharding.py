"""Sharding rules + spec validation (no multi-device needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models import model
from repro.parallel.sharding import (ParallelConfig, param_specs_for,
                                     spec_matches, validate_spec)
from repro.utils.pytree import tree_flatten_with_paths


def test_rule_table():
    assert spec_matches("blocks/u0/attn/wq", 2) == P("data", "model")
    assert spec_matches("blocks/u0/attn/wo", 2) == P("model", "data")
    assert spec_matches("embed/w", 2) == P("model", "data")
    assert spec_matches("blocks/u3/moe/wi", 3) == P("model", "data", None)
    assert spec_matches("blocks/u0/norm1/scale", 1) == P()
    assert spec_matches("final_norm/scale", 1) == P()


def test_validate_spec_drops_nondivisible():
    sizes = {"data": 16, "model": 16, "pod": 2}
    assert validate_spec(P(("pod", "data")), (1,), sizes) == P(None)
    assert validate_spec(P(("pod", "data")), (64,), sizes) == P(("pod",
                                                                 "data"))
    assert validate_spec(P("model", None), (10, 4), sizes) == P(None, None)
    assert validate_spec(P("model", None), (32, 4), sizes) == P("model",
                                                                None)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_rank_and_divisibility(name):
    """Every param gets a spec of matching rank; every named axis divides."""
    cfg = ARCHS[name]
    import jax as _jax
    mesh = _jax.sharding.Mesh(
        __import__("numpy").array(_jax.devices()[:1]).reshape(1, 1),
        ("data", "model"))
    pcfg = ParallelConfig(mesh=mesh)
    shapes = model.param_shapes(cfg)
    specs = param_specs_for(shapes, pcfg)
    ss = dict(tree_flatten_with_paths(specs))
    for path, leaf in tree_flatten_with_paths(shapes):
        spec = ss[path]
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)


def test_stacked_blocks_get_leading_none():
    cfg = ARCHS["qwen3-8b"]
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    specs = param_specs_for(model.param_shapes(cfg),
                            ParallelConfig(mesh=mesh))
    flat = dict(tree_flatten_with_paths(specs))
    wq = flat["blocks/layer0/attn/wq"]
    assert wq[0] is None  # group dim replicated


def test_no_pod_sharding_of_params():
    """Paper rule: parameters are never sharded across the pod (WAN) axis."""
    cfg = ARCHS["qwen3-8b"]
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                             ("pod", "data", "model"))
    specs = param_specs_for(model.param_shapes(cfg),
                            ParallelConfig(mesh=mesh, multi_pod=True))
    for path, spec in tree_flatten_with_paths(specs):
        for entry in spec:
            names = entry if isinstance(entry, tuple) else (entry,)
            assert "pod" not in names, path
