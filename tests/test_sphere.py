"""Sphere engine: scheduling, stragglers, failures; k-means convergence."""
import numpy as np
import pytest

from conftest import make_cloud
from repro.core import SphereEngine, SphereJob, SphereStage, hash_partitioner
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.core.shuffle import (range_partitioner, sample_boundaries,
                                terasort_stages)


def _upload_records(client, name, n=64, rec=100, seed=0, replication=2):
    rng = np.random.default_rng(seed)
    data = rng.bytes(n * rec)
    client.upload(name, data, replication=replication)
    return data


def test_identity_job_preserves_records(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload_records(client, "f", n=100, rec=100)
    job = SphereJob("id", "f", [SphereStage("id", lambda rs: list(rs))],
                    record_size=100)
    outs, rep = SphereEngine(master, client).run(job)
    got = sorted(b"".join(outs)[i:i + 100] for i in range(0, 100 * 100, 100))
    want = sorted(data[i:i + 100] for i in range(0, 100 * 100, 100))
    assert got == want
    assert rep.tasks > 0
    assert rep.locality_fraction > 0.9  # compute went to the data


def test_straggler_speculation(tmp_path):
    """Two workers, one 50x slower, every chunk replicated on both: the
    greedy scheduler eventually queues a task on the straggler (its idle
    start beats the fast worker's deep queue), and speculation must win it
    back onto the fast replica."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000,
                                         n_servers=2)
    _upload_records(client, "f", n=400, rec=100, replication=2)
    slow = {servers[0].server_id: 0.02, servers[1].server_id: 1.0}
    eng = SphereEngine(master, client, speeds=slow, speculate_factor=1.5)
    job = SphereJob("id", "f", [SphereStage("id", lambda rs: list(rs))],
                    record_size=100)
    outs, rep = eng.run(job)
    assert rep.speculated > 0
    assert rep.speculation_wins > 0
    assert sum(len(o) for o in outs) == 400 * 100  # nothing lost


def test_worker_failure_retry(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload_records(client, "f", n=50, rec=100, replication=3)
    servers[1].kill()
    master.deregister("s1")
    job = SphereJob("id", "f", [SphereStage("id", lambda rs: list(rs))],
                    record_size=100)
    outs, rep = SphereEngine(master, client).run(job)
    assert len(b"".join(outs)) == len(data)


def test_two_stage_shuffle_wordcount_style(tmp_path):
    """Stage1 maps records to keyed partials, shuffle groups by key,
    stage2 reduces — generalized MapReduce as the paper claims."""
    master, servers, client = make_cloud(tmp_path, chunk_size=800)
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 8, size=400).astype("<u4")
    client.upload("nums", vals.tobytes(), replication=2)

    def map_udf(records):
        out = []
        for r in records:
            v = int(np.frombuffer(r, "<u4")[0])
            out.append(np.array([v % 4, 1], "<u4").tobytes())
        return out

    def reduce_udf(records):
        acc = {}
        for r in records:
            k, c = np.frombuffer(r, "<u4")
            acc[int(k)] = acc.get(int(k), 0) + int(c)
        return [np.array([k, v], "<u4").tobytes()
                for k, v in sorted(acc.items())]

    job = SphereJob("wc", "nums", [
        SphereStage("map", map_udf, partitioner=hash_partitioner(4),
                    n_buckets=4),
        SphereStage("reduce", reduce_udf),
    ], record_size=4)
    outs, rep = SphereEngine(master, client).run(job)
    counts = {}
    for blob in outs:
        for i in range(0, len(blob), 8):
            k, v = np.frombuffer(blob[i:i + 8], "<u4")
            counts[int(k)] = counts.get(int(k), 0) + int(v)
    want = {k: int((vals % 4 == k).sum()) for k in range(4)}
    assert counts == want


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_kmeans_converges(tmp_path, backend):
    master, servers, client = make_cloud(tmp_path, chunk_size=4096)
    rng = np.random.default_rng(0)
    true_c = np.array([[0, 0], [8, 8]], np.float32)
    pts = np.concatenate([rng.normal(c, 0.3, (150, 2)) for c in true_c]) \
        .astype(np.float32)
    client.upload("pts", encode_points(pts), replication=2)
    cents, rep = kmeans_sphere(SphereEngine(master, client), "pts",
                               dim=2, k=2, iters=6, backend=backend)
    cents = cents[np.argsort(cents[:, 0])]
    assert np.abs(cents - true_c).max() < 0.5
    assert rep.locality_fraction > 0.8


def test_range_partitioner_boundaries():
    recs = [bytes([i]) * 10 for i in range(100)]
    bounds = sample_boundaries(recs, 4, key_bytes=10)
    part = range_partitioner(bounds)
    ids = [part(r, 4) for r in recs]
    # partitions are contiguous and roughly balanced
    assert ids == sorted(ids)
    counts = [ids.count(i) for i in range(4)]
    assert max(counts) - min(counts) <= 30


def test_sample_boundaries_more_buckets_than_records():
    """n_buckets > len(records) used to wrap int(step*i) - 1 to -1 and
    emit the LARGEST key first — unsorted, duplicated boundaries. The
    clamped index keeps them sorted (tail buckets just stay empty)."""
    recs = [bytes([i]) * 10 for i in (5, 1, 9)]
    bounds = sample_boundaries(recs, 8, key_bytes=10)
    assert len(bounds) == 8 - 1
    assert bounds == sorted(bounds)
    assert bounds[0] == bytes([1]) * 10  # smallest key, not the largest
    part = range_partitioner(bounds)
    ids = [part(r, 8) for r in sorted(recs)]
    assert ids == sorted(ids)


# ------------------------- array record backend ---------------------------

def test_array_backend_terasort_matches_bytes(tmp_path):
    """Full two-stage sort job: both backends, byte-identical output."""
    master, servers, client = make_cloud(tmp_path, chunk_size=2000)
    rec, n = 100, 200
    data = _upload_records(client, "f", n=n, rec=rec, replication=2)
    sample = [data[i:i + rec] for i in range(0, n * rec, rec)]
    bounds = sample_boundaries(sample, 4, key_bytes=10)

    results = {}
    for backend in ("bytes", "array"):
        job = SphereJob("sort", "f", terasort_stages(bounds, backend, 4),
                        record_size=rec, backend=backend)
        outs, rep = SphereEngine(master, client).run(job)
        allrec = []
        for blob in outs:
            recs = [blob[i:i + rec] for i in range(0, len(blob), rec)]
            assert recs == sorted(recs, key=lambda r: r[:10])
            allrec.extend(recs)
        assert rep.partitioned_records == n
        results[backend] = allrec
    assert results["bytes"] == results["array"]
    keys = [r[:10] for r in results["array"]]
    assert keys == sorted(keys)  # globally sorted across buckets


def test_array_backend_bytes_udf_compat(tmp_path):
    """A stage with only a bytes udf still runs on the array backend
    (decode/re-encode path), including empty UDF outputs."""
    master, servers, client = make_cloud(tmp_path, chunk_size=800)
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 8, size=100).astype("<u4")
    client.upload("nums", vals.tobytes(), replication=2)

    def keep_even(records):
        return [r for r in records if np.frombuffer(r, "<u4")[0] % 2 == 0]

    job = SphereJob("evens", "nums",
                    [SphereStage("filter", keep_even)],
                    record_size=4, backend="array")
    outs, _ = SphereEngine(master, client).run(job)
    got = np.sort(np.frombuffer(b"".join(outs), "<u4"))
    want = np.sort(vals[vals % 2 == 0])
    np.testing.assert_array_equal(got, want)


def test_array_backend_requires_record_size():
    with pytest.raises(ValueError):
        SphereJob("bad", "f", [SphereStage("id", lambda rs: rs)],
                  record_size=0, backend="array")
    with pytest.raises(ValueError):
        SphereJob("bad", "f", [SphereStage("id", lambda rs: rs)],
                  record_size=4, backend="tensor")


def test_report_partition_throughput_fields(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload_records(client, "f", n=100, rec=100)
    job = SphereJob("shuffled", "f", [
        SphereStage("id", lambda rs: list(rs),
                    partitioner=hash_partitioner(8), n_buckets=4)],
        record_size=100)
    _, rep = SphereEngine(master, client).run(job)
    assert rep.partitioned_records == 100
    assert rep.partition_seconds > 0
