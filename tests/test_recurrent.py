"""Recurrent blocks: chunkwise-parallel forms vs sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import rglru, xlstm
from repro.models.common import materialize
from repro.models.transformer import _zero_state


@pytest.mark.parametrize("chunk", [1, 2, 4, 8])
def test_mlstm_chunkwise_vs_sequential(chunk, monkeypatch):
    cfg = ARCHS["xlstm-1.3b"].reduced()
    p = materialize(xlstm.mlstm_shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    oracle = xlstm.mlstm_sequential_oracle(p, x, cfg=cfg)
    monkeypatch.setattr(xlstm, "CHUNK", chunk)
    out, _ = xlstm.mlstm_apply(p, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)


def test_mlstm_streaming_state():
    cfg = ARCHS["xlstm-1.3b"].reduced()
    p = materialize(xlstm.mlstm_shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, cfg.d_model))
    full, _ = xlstm.mlstm_apply(p, x, cfg=cfg)
    st = _zero_state(xlstm.mlstm_state_shapes(cfg, 2))
    o1, st = xlstm.mlstm_apply(p, x[:, :7], cfg=cfg, state=st)
    o2, _ = xlstm.mlstm_apply(p, x[:, 7:], cfg=cfg, state=st)
    got = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


def test_slstm_streaming_state():
    cfg = ARCHS["xlstm-1.3b"].reduced()
    p = materialize(xlstm.slstm_shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, cfg.d_model))
    full, _ = xlstm.slstm_apply(p, x, cfg=cfg)
    st = _zero_state(xlstm.slstm_state_shapes(cfg, 2))
    o1, st = xlstm.slstm_apply(p, x[:, :5], cfg=cfg, state=st)
    o2, _ = xlstm.slstm_apply(p, x[:, 5:], cfg=cfg, state=st)
    got = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-5, atol=1e-6)


def test_rglru_streaming_vs_batch():
    cfg = ARCHS["recurrentgemma-2b"].reduced()
    p = materialize(rglru.shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, cfg.d_model))
    full, _ = rglru.apply(p, x, cfg=cfg)
    st = _zero_state(rglru.state_shapes(cfg, 2))
    o1, st = rglru.apply(p, x[:, :6], cfg=cfg, state=st)
    o2, _ = rglru.apply(p, x[:, 6:], cfg=cfg, state=st)
    got = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-4, atol=1e-5)


def test_rglru_decay_bounded():
    """RG-LRU recurrence weight a in (0, 1) for any input (stability)."""
    cfg = ARCHS["recurrentgemma-2b"].reduced()
    p = materialize(rglru.shapes(cfg), jax.random.PRNGKey(0))
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(2),
                                  (1, 8, cfg.d_model))
    out, _ = rglru.apply(p, x, cfg=cfg)
    assert bool(jnp.isfinite(out).all())
