"""Sector event bus: deterministic synchronous pub/sub.

Covers the delivery contract streams rely on — monotonic sequence
numbers, delivery order == publish order even for re-entrant publishes,
type/prefix filtering — and the master's publication points (membership,
upload completion, chunk commits), including ordering under a
"simultaneous" join + death at the same simulated time."""
import pytest

from conftest import make_cloud
from repro.sector import ChunkServer
from repro.sector.events import (CHUNK_REPLICATED, FILE_CREATED,
                                 SERVER_DIED, SERVER_JOINED, EventBus)


# ------------------------------- bus core -----------------------------------

def test_subscribe_filters_type_and_prefix():
    bus = EventBus()
    got = []
    bus.subscribe(lambda e: got.append(("typed", e.type)),
                  types=(FILE_CREATED,))
    bus.subscribe(lambda e: got.append(("prefixed", e.path)),
                  prefix="angle/")
    bus.subscribe(lambda e: got.append(("all", e.seq)))

    bus.publish(FILE_CREATED, path="angle/w0")
    bus.publish(SERVER_JOINED, path="s9")
    assert got == [("typed", FILE_CREATED), ("prefixed", "angle/w0"),
                   ("all", 0), ("all", 1)]


def test_unknown_types_rejected():
    bus = EventBus()
    with pytest.raises(ValueError, match="unknown event type"):
        bus.publish("file-craeted")
    with pytest.raises(ValueError, match="unknown event types"):
        bus.subscribe(lambda e: None, types=("server-joned",))


def test_unsubscribe_stops_delivery():
    bus = EventBus()
    got = []
    sub = bus.subscribe(lambda e: got.append(e.seq))
    bus.publish(SERVER_JOINED, path="a")
    bus.unsubscribe(sub)
    bus.unsubscribe(sub)  # idempotent
    bus.publish(SERVER_JOINED, path="b")
    assert got == [0]


def test_seq_monotonic_and_history():
    bus = EventBus(history=4)
    for i in range(6):
        bus.publish(SERVER_JOINED, path=f"s{i}", time=float(i))
    assert [e.seq for e in bus.history] == [2, 3, 4, 5]  # bounded
    assert [e.path for e in bus.history] == ["s2", "s3", "s4", "s5"]


def test_replay_bounded_and_ordered():
    bus = EventBus(history=4)
    for i in range(6):
        bus.publish(SERVER_JOINED, path=f"s{i}", time=float(i))
    # replay returns the ring's window, oldest first, in seq order
    assert [e.seq for e in bus.replay()] == [2, 3, 4, 5]
    # events that aged out of the ring are gone
    assert all(e.seq >= 2 for e in bus.replay(since_seq=-1))


def test_replay_filters_match_subscribe():
    bus = EventBus()
    bus.publish(FILE_CREATED, path="angle/w0")
    bus.publish(SERVER_JOINED, path="s1")
    bus.publish(FILE_CREATED, path="other/w1")
    bus.publish(FILE_CREATED, path="angle/w2")

    assert [e.path for e in bus.replay(types=(FILE_CREATED,))] == \
        ["angle/w0", "other/w1", "angle/w2"]
    assert [e.path for e in bus.replay(prefix="angle/")] == \
        ["angle/w0", "angle/w2"]
    assert [e.seq for e in bus.replay(since_seq=1)] == [2, 3]
    with pytest.raises(ValueError, match="unknown event types"):
        bus.replay(types=("file-craeted",))


def test_reentrant_publish_is_queued_breadth_first():
    """A publish from inside a callback must not interleave: the nested
    event is delivered to EVERY subscriber after the current event
    finishes its full delivery round, in seq order."""
    bus = EventBus()
    order = []

    def reactor(e):
        order.append(("reactor", e.type, e.seq))
        if e.type == SERVER_DIED:
            # standby replacement: publish while delivering
            bus.publish(SERVER_JOINED, path="standby", time=e.time)

    bus.subscribe(reactor)
    bus.subscribe(lambda e: order.append(("audit", e.type, e.seq)))
    bus.publish(SERVER_DIED, path="s0", time=9.0)

    assert order == [("reactor", SERVER_DIED, 0),
                     ("audit", SERVER_DIED, 0),
                     ("reactor", SERVER_JOINED, 1),
                     ("audit", SERVER_JOINED, 1)]


def test_raising_subscriber_does_not_corrupt_delivery():
    """A raising callback must not leave the bus half-delivered: later
    subscribers still see the event, queued re-entrant events still
    drain in order (nothing leaks into the next publish), and the first
    error re-raises to the publisher after the drain."""
    bus = EventBus()
    got = []

    def reactor(e):
        if e.type == SERVER_DIED:
            bus.publish(SERVER_JOINED, path="standby")  # re-entrant
            raise RuntimeError("subscriber boom")

    bus.subscribe(reactor)
    bus.subscribe(lambda e: got.append((e.type, e.seq)))
    with pytest.raises(RuntimeError, match="subscriber boom"):
        bus.publish(SERVER_DIED, path="s0")
    # both the failing event AND the queued standby join were delivered
    assert got == [(SERVER_DIED, 0), (SERVER_JOINED, 1)]
    assert not bus._queue                      # nothing left to leak
    bus.publish(SERVER_JOINED, path="later")   # clean next publish
    assert got[-1] == (SERVER_JOINED, 2)


def test_base_exception_aborts_without_leaking_queued_events():
    """A BaseException (Ctrl-C through a long window callback) aborts
    the drain — but the undelivered remainder must be dropped, not
    delivered at the front of the next unrelated publish."""
    bus = EventBus()
    got = []

    def interrupter(e):
        if e.type == SERVER_DIED:
            bus.publish(SERVER_JOINED, path="queued-behind")
            raise KeyboardInterrupt

    bus.subscribe(interrupter)
    bus.subscribe(lambda e: got.append((e.type, e.path)))
    with pytest.raises(KeyboardInterrupt):
        bus.publish(SERVER_DIED, path="s0")
    assert not bus._queue                       # aborted remainder dropped
    bus.publish(SERVER_JOINED, path="later")
    assert (SERVER_JOINED, "queued-behind") not in got
    assert got[-1] == (SERVER_JOINED, "later")


# --------------------------- master publication ------------------------------

def test_simultaneous_join_and_death_ordering(tmp_path):
    """One heartbeat sweep kills a stale server while a replacement
    registers at the same simulated instant: every subscriber observes
    the same total order (publish order, strictly increasing seq), and
    both events carry the same clock value."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"x" * 3000, replication=2)
    got = []
    master.events.subscribe(
        lambda e: got.append(e), types=(SERVER_JOINED, SERVER_DIED))

    t = master.heartbeat_timeout + 5.0
    for s in servers[1:]:
        master.heartbeat(s.server_id, t)
    servers[0].kill()
    # the same instant: replacement joins, sweep detects the death
    master.register(ChunkServer("fresh", "tokyo", tmp_path), now=t)
    dead = master.check_failures(t)
    assert dead == [servers[0].server_id]

    assert [(e.type, e.path) for e in got] == \
        [(SERVER_JOINED, "fresh"), (SERVER_DIED, servers[0].server_id)]
    assert [e.seq for e in got] == sorted(e.seq for e in got)
    assert got[0].seq < got[1].seq
    assert got[0].time == got[1].time == t


def test_upload_publishes_commits_then_file_created(tmp_path):
    """file-created trails every chunk-replicated of the file — a stream
    woken by it can read immediately — and carries size/chunk detail."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    got = []
    master.events.subscribe(lambda e: got.append(e),
                            types=(FILE_CREATED, CHUNK_REPLICATED))
    client.upload("d/f", b"z" * 2500, replication=2)

    kinds = [e.type for e in got]
    assert kinds.index(FILE_CREATED) == len(kinds) - 1  # strictly last
    assert kinds.count(CHUNK_REPLICATED) == 3 * 2       # 3 chunks x 2 replicas
    created = got[-1]
    assert created.path == "d/f"
    assert created.detail == {"size": 2500, "chunks": 3,
                              "event_time": 0.0}
    # replica counts ramp 1..replication per chunk
    per_chunk = {}
    for e in got[:-1]:
        per_chunk.setdefault(e.path, []).append(e.detail["replicas"])
    assert all(v == [1, 2] for v in per_chunk.values())


def test_repair_publishes_chunk_replicated(tmp_path):
    """Re-replication after a death re-announces the restored replicas."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"q" * 2000, replication=2)
    got = []
    master.events.subscribe(lambda e: got.append(e),
                            types=(CHUNK_REPLICATED,))
    victim = next(iter(master.chunks.values()))
    sid = next(iter(victim.locations))
    master.servers[sid].kill()
    master.deregister(sid)
    assert master.under_replicated
    client.run_repair()
    assert not master.under_replicated
    assert any(e.detail["replicas"] >= 2 for e in got)
