"""Serving engine: continuous batching == sequential decoding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import model
from repro.serve import SamplerConfig, ServeEngine


def test_continuous_batching_matches_single_stream():
    """Greedy: each request's output must equal its standalone decode."""
    cfg = ARCHS["qwen2.5-3b"].reduced().replace(param_dtype="float32",
                                                compute_dtype="float32")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 10))))
               for _ in range(5)]

    # reference: decode each prompt alone
    def solo(prompt, n_new=6):
        _, cache = model.prefill(params, {"inputs": jnp.asarray([prompt])},
                                 cfg=cfg, max_len=64)
        logits, _ = model.prefill(params, {"inputs": jnp.asarray([prompt])},
                                  cfg=cfg, max_len=64)
        out = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        for _ in range(n_new - 1):
            lg, cache = model.decode_step(
                params, cache, jnp.asarray([[out[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), cfg=cfg)
            out.append(int(jnp.argmax(lg[0])))
            pos += 1
        return out

    want = [solo(p) for p in prompts]

    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      scfg=SamplerConfig(temperature=0.0))
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    for r, w in zip(reqs, want):
        assert r.done
        assert r.out == w, (r.rid, r.out, w)


def test_slot_recycling():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32)
    reqs = [eng.submit([1, 2, 3], max_new=3) for _ in range(6)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
    assert all(s is None for s in eng.slot_req)
