"""Array-backend shuffle parity: kernel bucket ids == bytes partitioners.

The array backend is only allowed to exist because it agrees with the
bytes reference record-for-record. These tests drive both paths over the
same records — including the Pallas kernel's padded-tail blocks (record
counts not divisible by block_n) and the degenerate single-bucket case —
and a hypothesis property test when hypothesis is installed.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.records import RecordBatch, fnv1a32, scatter_by_ids
from repro.core.shuffle import (hash_partitioner, partition_batch,
                                range_partitioner, sample_boundaries,
                                shuffle_batch)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is a dev dep; CI installs it
    hypothesis = None


def _random_records(n, rec, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(n, rec), dtype=np.uint8)
    blob = data.tobytes()
    return blob, [blob[i:i + rec] for i in range(0, n * rec, rec)]


def _assert_parity(records, blob, rec, part, n, **kw):
    """Kernel ids/hist must equal the per-record bytes partitioner."""
    batch = RecordBatch.from_bytes(blob, rec)
    ids, hist = partition_batch(batch, part, n, **kw)
    ref = [part(r, n) for r in records]
    assert np.asarray(ids).tolist() == ref
    assert np.asarray(hist).tolist() == [ref.count(i) for i in range(n)]
    # and the scattered buckets preserve the bytes backend's append order
    for i, piece in enumerate(scatter_by_ids(batch, ids, hist)):
        want = b"".join(r for r, b in zip(records, ref) if b == i)
        assert piece.to_bytes() == want


@pytest.mark.parametrize("n_buckets", [1, 2, 5, 16])
@pytest.mark.parametrize("n_records,record_size", [
    (1, 8), (97, 100), (256, 12), (1000, 100)])
def test_hash_partitioner_parity(n_records, record_size, n_buckets):
    blob, records = _random_records(n_records, record_size,
                                    seed=n_records + n_buckets)
    part = hash_partitioner(key_bytes=8)
    _assert_parity(records, blob, record_size, part, n_buckets)


@pytest.mark.parametrize("key_bytes", [4, 10])
@pytest.mark.parametrize("n_buckets", [1, 2, 6, 16])
@pytest.mark.parametrize("n_records,record_size", [
    (1, 8), (97, 100), (333, 10), (1000, 100)])
def test_range_partitioner_parity(n_records, record_size, n_buckets,
                                  key_bytes):
    blob, records = _random_records(n_records, record_size,
                                    seed=7 * n_records + n_buckets)
    bounds = sample_boundaries(records[:200], n_buckets,
                               key_bytes=key_bytes)
    part = range_partitioner(bounds)
    _assert_parity(records, blob, record_size, part, n_buckets)


def test_padded_tail_blocks():
    """block_n that does not divide n_records forces the kernel's padded
    tail path: padded ids must not leak into ids or the histogram."""
    n, rec, nb = 101, 16, 4
    blob, records = _random_records(n, rec, seed=3)
    part = hash_partitioner(key_bytes=4)
    for block_n in (7, 32, 100, 101, 4096):
        _assert_parity(records, blob, rec, part, nb, block_n=block_n)


def test_single_bucket_short_circuits():
    blob, records = _random_records(50, 10, seed=5)
    batch = RecordBatch.from_bytes(blob, 10)
    for part in (hash_partitioner(4), range_partitioner([])):
        ids, hist = partition_batch(batch, part, 1)
        assert np.asarray(ids).tolist() == [0] * 50
        assert np.asarray(hist).tolist() == [50]


def test_duplicate_and_boundary_keys():
    """Records exactly equal to a boundary, plus heavy duplicates — the
    strict #{bounds < key} rule must agree on both paths."""
    bounds = [b"\x40\x00\x00\x00", b"\x80\x00\x00\x00"]
    part = range_partitioner(bounds)
    keys = ([b"\x40\x00\x00\x00"] * 5 + [b"\x3f\xff\xff\xff"] * 3
            + [b"\x80\x00\x00\x00"] * 4 + [b"\x80\x00\x00\x01"] * 2
            + [b"\x00\x00\x00\x00"] * 2 + [b"\xff\xff\xff\xff"] * 2)
    records = [k + b"pad-data" for k in keys]
    blob = b"".join(records)
    _assert_parity(records, blob, 12, part, 3)


def test_duplicate_and_boundary_keys_multiword():
    """Same strictness torture on 10-byte (3-word) boundaries: keys equal
    to a boundary, keys differing only in the zero-padded tail word, and
    duplicates — each must land identically on both paths."""
    b1 = b"\x40" * 10
    b2 = b"\x80" * 9 + b"\x00"
    part = range_partitioner([b1, b2])
    keys = ([b1] * 4                        # == boundary 1
            + [b1[:9] + b"\x3f"] * 3        # just below, tail word only
            + [b1[:9] + b"\x41"] * 3        # just above, tail word only
            + [b2] * 4 + [b2[:9] + b"\x01"] * 2
            + [b"\x00" * 10] * 2 + [b"\xff" * 10] * 2)
    records = [k + b"pp" for k in keys]
    _assert_parity(records, b"".join(records), 12, part, 3)


def test_multiword_padded_tail_blocks():
    """Multi-word keys through the kernel's padded-tail path: block_n not
    dividing n_records must not leak padded rows into ids/histogram."""
    n, rec, nb = 101, 16, 4
    blob, records = _random_records(n, rec, seed=23)
    bounds = sample_boundaries(records, nb, key_bytes=10)
    part = range_partitioner(bounds)
    for block_n in (7, 32, 100, 101, 4096):
        _assert_parity(records, blob, rec, part, nb, block_n=block_n)


def test_variable_length_boundaries_exact():
    """Boundaries of differing lengths, including one that is a strict
    prefix of another with a zero tail — Python's shorter-prefix-sorts-
    first rule, reproduced on the kernel by the trailing length word."""
    bounds = [b"\x10\x20", b"\x10\x20\x00", b"\x10\x20\x00\x00\x00\x01",
              b"\x90\x10\x20\x30\x40"]
    part = range_partitioner(bounds)
    prefixes = [b"\x00\x00", b"\x10\x1f", b"\x10\x20", b"\x10\x21",
                b"\x90\x10", b"\xff\xff"]
    records = [p + bytes([i]) * 4 for i, p in enumerate(prefixes)]
    records += [b"\x10\x20\x00\x00\x00\x00", b"\x10\x20\x00\x00\x00\x01",
                b"\x90\x10\x20\x30\x40\x00"]
    _assert_parity(records, b"".join(records), 6, part, 5)


def test_records_shorter_than_boundaries():
    """record_size < boundary length: the comparison key is the whole
    (shorter) record, which ties with longer boundaries sharing its
    prefix — the length word must break the tie exactly like bytes."""
    bounds = [b"\x20\x20\x20\x20\x00\x00", b"\x80\x80\x80\x80\x80\x80"]
    part = range_partitioner(bounds)
    records = [b"\x20\x20\x20\x20", b"\x20\x20\x20\x21", b"\x00\x00\x00\x00",
               b"\x80\x80\x80\x80", b"\xff\xff\xff\xff"]
    _assert_parity(records, b"".join(records), 4, part, 3)


def test_custom_callable_partitioner_fallback():
    """Arbitrary Python partitioners still work on the array backend via
    the host loop fallback of partition_batch."""
    blob, records = _random_records(40, 8, seed=9)
    def part(r, n):
        return r[0] % n
    batch = RecordBatch.from_bytes(blob, 8)
    ids, hist = partition_batch(batch, part, 3)
    ref = [r[0] % 3 for r in records]
    assert np.asarray(ids).tolist() == ref
    pieces = shuffle_batch(batch, part, 3)
    assert [p.num_records for p in pieces] == [ref.count(i) for i in range(3)]


def test_fnv1a32_vector_matches_scalar():
    blob, records = _random_records(64, 20, seed=11)
    batch = RecordBatch.from_bytes(blob, 20)
    for kb in (1, 4, 8, 20):
        got = np.asarray(batch.hash_keys_u32(kb)).tolist()
        assert got == [fnv1a32(r[:kb]) for r in records]


def test_sort_by_key_matches_python_sorted():
    blob, records = _random_records(200, 24, seed=13)
    batch = RecordBatch.from_bytes(blob, 24)
    for kb in (4, 10):
        got = batch.sort_by_key(kb).to_records()
        assert got == sorted(records, key=lambda r: r[:kb])


def test_sort_by_key_stable_ignores_payload():
    """Duplicate keys with differing payloads: payload bytes past
    key_bytes must not enter the sort key — ties keep input order, like
    the bytes backend's stable sorted(key=r[:kb])."""
    records = [b"KEY0000000" + p for p in (b"zz", b"aa", b"mm")]
    records += [b"KEY0000001" + p for p in (b"bb", b"aa")]
    records = records[::-1]  # keys out of order, payloads shuffled
    batch = RecordBatch.from_records(records)
    for kb in (10, 7):  # 10 = pad-to-12 tail word; 7 = pad-to-8
        got = batch.sort_by_key(kb).to_records()
        assert got == sorted(records, key=lambda r: r[:kb])


def test_long_boundaries_take_multiword_kernel_path(monkeypatch):
    """Boundaries longer than 4 bytes go through the kernel's multi-word
    lexicographic compare (NOT the per-record host fallback) and must
    match the bytes path exactly — records here share a 4-byte prefix
    and differ only past it, so a truncating single-word compare would
    collapse them all into bucket 0."""
    import repro.core.shuffle as shuffle_mod

    def boom(*a, **k):
        raise AssertionError("range bucket_ids used _host_partition")

    monkeypatch.setattr(shuffle_mod, "_host_partition", boom)
    prefix = b"\x10\x20\x30\x40"
    records = [prefix + bytes([i]) + b"x" * 5 for i in range(20)]
    blob = b"".join(records)
    bounds = sample_boundaries(records, 4, key_bytes=10)
    assert len(bounds[0]) > 4
    part = range_partitioner(bounds)
    _assert_parity(records, blob, 10, part, 4)
    assert len({part(r, 4) for r in records}) > 1


def test_record_batch_roundtrip():
    blob, records = _random_records(33, 7, seed=17)
    batch = RecordBatch.from_bytes(blob, 7)
    assert batch.num_records == 33 and batch.record_size == 7
    assert batch.to_bytes() == blob
    assert batch.to_records() == records
    assert RecordBatch.from_records(records).to_bytes() == blob
    both = RecordBatch.concat([batch, batch])
    assert both.to_bytes() == blob + blob
    with pytest.raises(ValueError):
        RecordBatch.from_bytes(blob[:-1], 7)
    with pytest.raises(ValueError):
        RecordBatch.from_records([b"ab", b"abc"])


def test_concat_single_nonempty_fast_path():
    """concat of one non-empty batch returns the batch ITSELF (no copy) —
    including a padding-resident batch, which must stay resident —
    while empties are dropped and multi-input concat materialises only
    the valid prefixes."""
    blob, records = _random_records(10, 8, seed=21)
    exact = RecordBatch.from_bytes(blob, 8)
    empty = RecordBatch.empty(8)
    assert RecordBatch.concat([exact]) is exact
    assert RecordBatch.concat([empty, exact, empty]) is exact

    junk = np.full((6, 8), 0xAB, np.uint8)
    block = np.concatenate([np.frombuffer(blob, np.uint8).reshape(10, 8),
                            junk])
    padded = RecordBatch(jnp.asarray(block), n_valid=10)
    assert RecordBatch.concat([padded]) is padded       # stays resident
    assert RecordBatch.concat([empty, padded]) is padded
    assert RecordBatch.concat([padded]).padded_rows == 16

    both = RecordBatch.concat([padded, exact])          # junk excluded
    assert both.n_valid is None
    assert both.to_bytes() == blob + blob
    assert RecordBatch.concat([empty, empty]).num_records == 0


def test_padded_batch_roundtrip():
    """Padding-resident accessors: valid-prefix codecs, nbytes = valid
    bytes (planner pricing parity), block() reuse/slice/grow, compact,
    and the validation envelope."""
    blob, records = _random_records(12, 8, seed=22)
    junk = np.full((4, 8), 0xEE, np.uint8)
    block = np.concatenate([np.frombuffer(blob, np.uint8).reshape(12, 8),
                            junk])
    b = RecordBatch(jnp.asarray(block), n_valid=12)
    assert b.num_records == 12 and b.padded_rows == 16
    assert b.nbytes == 12 * 8                  # padding is free
    assert b.to_bytes() == blob                # junk never materialises
    assert b.to_records() == records
    assert np.asarray(b.valid_data).tobytes() == blob
    c = b.compact()
    assert c.n_valid is None and c.to_bytes() == blob
    # block(): same shape reuses the resident array, larger prefix-slices
    # a bigger resident block, smaller-than-resident slices the prefix
    assert b.block(16) is b.data
    assert b.block(32).shape == (32, 8)
    assert bytes(np.asarray(b.block(32))[:12].tobytes()) == blob
    assert b.block(12).shape == (12, 8)
    with pytest.raises(ValueError):
        b.block(11)                            # can't fit 12 valid rows
    # n_valid == rows normalises to an exact batch; out-of-range rejects
    full = RecordBatch(jnp.asarray(block), n_valid=16)
    assert full.n_valid is None
    with pytest.raises(ValueError):
        RecordBatch(jnp.asarray(block), n_valid=17)
    with pytest.raises(ValueError):
        RecordBatch(jnp.asarray(block), n_valid=-1)
    # sort_by_key on a padding-resident batch sorts only valid records
    got = b.sort_by_key(8).to_records()
    assert got == sorted(records)


def test_points_roundtrip():
    pts = np.random.default_rng(19).normal(size=(40, 6)).astype(np.float32)
    batch = RecordBatch.from_points(jnp.asarray(pts))
    assert batch.record_size == 24
    np.testing.assert_array_equal(np.asarray(batch.to_points(6)), pts)


if hypothesis is not None:
    @settings(max_examples=40, deadline=None)
    @given(data=st.binary(min_size=0, max_size=400),
           rec_pow=st.integers(2, 5),
           n_buckets=st.integers(1, 9),
           which=st.sampled_from(["hash", "range"]),
           bound_len=st.integers(1, 12),
           seed=st.integers(0, 2**31 - 1))
    def test_parity_property(data, rec_pow, n_buckets, which, bound_len,
                             seed):
        rec = 1 << rec_pow
        n = max(1, len(data) // rec)
        blob = (data + bytes(n * rec))[:n * rec]
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        if which == "hash":
            part = hash_partitioner(key_bytes=min(rec, 8))
        else:
            # boundaries up to 12 bytes (multi-word kernel path), biased
            # toward collisions with record prefixes and toward the
            # duplicate / boundary-equal / zero-tail cases
            rng = np.random.default_rng(seed)
            raw = []
            for _ in range(max(n_buckets - 1, 0)):
                if records and rng.random() < 0.5:
                    b = records[rng.integers(len(records))][:bound_len]
                    if rng.random() < 0.3:
                        b = b[:max(1, bound_len // 2)] + b"\x00"
                else:
                    b = rng.bytes(bound_len)
                raw.append(b)
            part = range_partitioner(sorted(raw))
        _assert_parity(records, blob, rec, part, n_buckets, block_n=37)


def test_parity_randomized_multiword():
    """Non-hypothesis twin of the property test (runs even without the
    hypothesis dev dep): random records vs random variable-length
    boundaries seeded from record prefixes, 60 rounds."""
    rng = np.random.default_rng(42)
    for _ in range(60):
        rec = int(rng.integers(4, 33))
        n = int(rng.integers(1, 80))
        blob = rng.bytes(n * rec)
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        nb = int(rng.integers(1, 9))
        bound_len = int(rng.integers(1, 13))
        raw = []
        for _ in range(nb - 1):
            if rng.random() < 0.5:
                b = records[rng.integers(len(records))][:bound_len]
                if rng.random() < 0.3:
                    b = b[:max(1, bound_len // 2)] + b"\x00"
            else:
                b = rng.bytes(bound_len)
            raw.append(b)
        part = range_partitioner(sorted(raw))
        _assert_parity(records, blob, rec, part, nb, block_n=37)
