"""Elastic scaling: failure -> remesh -> checkpoint-restore -> resume."""
import jax
import pytest

from conftest import make_cloud
from repro.configs import ARCHS
from repro.data import DataPipeline, SectorTokenDataset, write_synthetic_corpus
from repro.parallel.sharding import ParallelConfig
from repro.train import SectorCheckpointer, Trainer, TrainerConfig
from repro.train.elastic import ElasticController, HostFailure


def _mk(tmp_path, mesh):
    master, servers, client = make_cloud(tmp_path, chunk_size=64 * 1024)
    cfg = ARCHS["qwen2.5-3b"].reduced()
    write_synthetic_corpus(client, "c", 300_000, cfg.vocab_size)
    pcfg = ParallelConfig(mesh=mesh, remat="none")
    ds = SectorTokenDataset(master, client, "c", seq_len=32)
    pipe = DataPipeline(ds, batch=4, pcfg=pcfg)
    ck = SectorCheckpointer(client, "el")
    tr = Trainer(cfg, pcfg, TrainerConfig(steps=12, ckpt_every=4,
                                          log_every=2, lr=1e-3), pipe, ck)
    return tr


def _mesh(n):
    import numpy as _np
    devs = _np.array(jax.devices()[:n]).reshape(n, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def test_elastic_restart_resumes_from_checkpoint(tmp_path):
    tr = _mk(tmp_path, _mesh(1))
    ctl = ElasticController(tr, make_mesh=_mesh)
    out = ctl.run_with_failures(12, fail_at=[6])
    assert out["restarts"] == 1
    assert out["final_step"] >= 12
    # after restart the trainer restored from the last committed ckpt (<=6)
    # and re-ran to completion; loss history must be monotone-ish overall
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]


def test_elastic_multiple_failures(tmp_path):
    tr = _mk(tmp_path, _mesh(1))
    ctl = ElasticController(tr, make_mesh=_mesh, max_restarts=3)
    out = ctl.run_with_failures(12, fail_at=[4, 8])
    assert out["restarts"] == 2
    assert out["final_step"] >= 12


def test_elastic_gives_up_after_max_restarts(tmp_path):
    tr = _mk(tmp_path, _mesh(1))
    ctl = ElasticController(tr, make_mesh=_mesh, max_restarts=1)
    with pytest.raises(HostFailure):
        ctl.run_with_failures(12, fail_at=[2, 4, 6])
