"""SphereSession: job chaining over one planner/executor.

Covers the session reuse guarantees: chained jobs share one planner and
one Sector lookup (no duplicate metadata traffic), stage-0 chunks are
fetched once for the whole chain, speculation/straggler state resets at
job boundaries, chained input feeds the previous job's partitions into
the next job without touching Sector, and the two record backends still
produce identical SphereReports when driven through a session."""
import numpy as np
import pytest

from conftest import make_cloud
from repro.core import (SphereEngine, SphereJob, SpherePlanner, SphereStage,
                        TaskSpec)
from repro.core.kmeans import encode_points, kmeans_sphere
from repro.core.shuffle import sample_boundaries, terasort_stages

REC = 100


def _upload(client, name, n, seed=0, replication=2):
    rng = np.random.default_rng(seed)
    data = rng.bytes(n * REC)
    client.upload(name, data, replication=replication)
    return data


def _identity_job(backend):
    return SphereJob("id", "f",
                     [SphereStage("id", lambda rs: list(rs),
                                  batch_udf=lambda b: b, pad_value=0xFF)],
                     record_size=REC, backend=backend)


def _report_key(rep):
    return (rep.tasks, rep.retried, rep.speculated, rep.speculation_wins,
            rep.bytes_local, rep.bytes_moved, rep.partitioned_records,
            pytest.approx(rep.sim_seconds),
            [pytest.approx(s) for s in rep.stage_seconds])


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_session_matches_engine_run(tmp_path, backend):
    """A session job is the same job: outputs and report counters equal a
    one-shot engine.run, and so does every later run of the chain (the
    cached lookup/plan re-charge identical counters)."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=60)
    eng = SphereEngine(master, client)
    ref_outs, ref_rep = eng.run(_identity_job(backend))

    sess = eng.session("f", record_size=REC, backend=backend)
    for _ in range(3):
        outs, rep = sess.run(_identity_job(backend))
        assert outs == ref_outs
        assert _report_key(rep) == _report_key(ref_rep)
    assert sess.jobs_run == 3


def test_chained_jobs_share_one_lookup_and_planner(tmp_path):
    """After the first chained job, later jobs touch the Sector master
    zero times (metadata lookup AND chunk reads are amortised across the
    chain) and keep the same planner instance; every unchained
    engine.run pays the lookups again."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=40)
    calls = []
    orig = master.lookup
    master.lookup = lambda *a, **k: calls.append(a) or orig(*a, **k)

    eng = SphereEngine(master, client)
    sess = eng.session("f", record_size=REC, backend="array")
    planner = sess.planner
    sess.run(_identity_job("array"))
    cold = len(calls)
    assert cold > 0
    for _ in range(2):
        sess.run(_identity_job("array"))
        assert sess.planner is planner
    assert len(calls) == cold  # no duplicate lookups across the chain

    eng.run(_identity_job("array"))
    assert len(calls) > cold   # the one-shot path re-looks-up


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_session_fetches_each_chunk_once(tmp_path, backend):
    """cache_chunks: the chain pays the Sector read + decode host
    round-trip once per chunk, not once per job."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=40)
    reads = []
    orig = client.read_chunk
    client.read_chunk = lambda *a, **k: reads.append(a) or orig(*a, **k)

    eng = SphereEngine(master, client)
    sess = eng.session("f", record_size=REC, backend=backend)
    sess.run(_identity_job(backend))
    per_job = len(reads)
    assert per_job > 0
    for _ in range(2):
        sess.run(_identity_job(backend))
    assert len(reads) == per_job  # cached: no further Sector reads


def test_chained_input_feeds_next_job_without_sector(tmp_path):
    """run(job, input='chained') consumes the previous job's output
    partitions in place: the chained sort matches a single two-stage
    engine.run job byte-for-byte and performs zero Sector reads."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=80, replication=3)
    sample = [data[i:i + REC] for i in range(0, 80 * REC, REC)]
    bounds = sample_boundaries(sample, 4, key_bytes=10)

    eng = SphereEngine(master, client)
    stages = terasort_stages(bounds, "array", 4)
    want, _ = eng.run(SphereJob("sort", "f", stages, record_size=REC,
                                backend="array"))

    sess = eng.session("f", record_size=REC, backend="array")
    sess.run(SphereJob("part", "f", stages[:1], record_size=REC,
                       backend="array"))
    reads = []
    orig = client.read_chunk
    client.read_chunk = lambda *a, **k: reads.append(a) or orig(*a, **k)
    got, _ = sess.run(SphereJob("sort2", "f", stages[1:], record_size=REC,
                                backend="array"), input="chained")
    assert reads == []
    assert got == want


def test_chained_without_previous_job_raises(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=10)
    sess = SphereEngine(master, client).session("f", record_size=REC,
                                                backend="array")
    with pytest.raises(RuntimeError, match="chain"):
        sess.run(_identity_job("array"), input="chained")


def test_session_rejects_mismatched_jobs(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=10)
    sess = SphereEngine(master, client).session("f", record_size=REC,
                                                backend="array")
    with pytest.raises(ValueError, match="backend"):
        sess.run(SphereJob("j", "f", [SphereStage("id", lambda rs: rs)],
                           record_size=REC, backend="bytes"))
    with pytest.raises(ValueError, match="session"):
        sess.run(SphereJob("j", "g", [SphereStage("id", lambda rs: rs,
                                                  batch_udf=lambda b: b)],
                           record_size=REC, backend="array"))


def test_planner_straggler_state_resets():
    """plan_stage records observed stragglers for the current job;
    reset_job_state forgets them at the job boundary."""
    p = SpherePlanner(speeds={"slow": 0.02, "fast": 1.0},
                      speculate_factor=1.5)
    tasks = [TaskSpec(f"c{i}", 1000, ("slow", "fast")) for i in range(40)]
    plan = p.plan_stage(tasks, ["slow", "fast"])
    assert plan.speculated > 0
    assert p.job_stragglers.get("slow", 0) > 0
    p.reset_job_state()
    assert p.job_stragglers == {}


def test_session_resets_straggler_state_between_jobs(tmp_path):
    """The shared planner's per-job speculation state must not ACCUMULATE
    across chained jobs: every job starts from a reset planner, and a job
    reusing the cached stage-0 plan replays exactly the observations that
    planning stage 0 made the first time — so after any number of jobs
    the state equals one job's worth, never a running total."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000,
                                         n_servers=2)
    _upload(client, "f", n=400, replication=2)
    slow = {servers[0].server_id: 0.02, servers[1].server_id: 1.0}
    eng = SphereEngine(master, client, speeds=slow, speculate_factor=1.5)
    sess = eng.session("f", record_size=REC, backend="array")
    _, rep = sess.run(_identity_job("array"))
    assert rep.speculated > 0
    snap = dict(sess.planner.job_stragglers)
    assert snap  # observed during stage-0 planning
    for _ in range(2):
        sess.run(_identity_job("array"))
        assert sess.planner.job_stragglers == snap  # replayed, not summed


def test_session_multistage_speculation_parity(tmp_path):
    """A chained multi-stage job with a straggling worker schedules
    exactly like a fresh engine.run every time — the cached stage-0 plan
    replays its straggler observations, so later-stage speculation sees
    the same per-job state."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000,
                                         n_servers=2)
    data = _upload(client, "f", n=200, replication=2)
    sample = [data[i:i + REC] for i in range(0, 200 * REC, REC)]
    bounds = sample_boundaries(sample, 2, key_bytes=10)
    slow = {servers[0].server_id: 0.02, servers[1].server_id: 1.0}
    eng = SphereEngine(master, client, speeds=slow, speculate_factor=1.5)

    def job():
        return SphereJob("sort", "f", terasort_stages(bounds, "array", 2),
                         record_size=REC, backend="array")

    want_outs, want_rep = eng.run(job())
    assert want_rep.speculated > 0
    sess = eng.session("f", record_size=REC, backend="array")
    for _ in range(3):
        outs, rep = sess.run(job())
        assert outs == want_outs
        assert _report_key(rep) == _report_key(want_rep)


def test_session_reports_agree_across_backends(tmp_path):
    """The planner-purity guarantee survives the session: a chained
    TeraSort run produces byte-identical outputs and identical scheduling
    reports on both backends."""
    results = {}
    for backend in ("bytes", "array"):
        sub = tmp_path / backend
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=1000)
        data = _upload(client, "f", n=100, replication=3)
        sample = [data[i:i + REC] for i in range(0, 100 * REC, REC)]
        bounds = sample_boundaries(sample, 4, key_bytes=10)
        job = SphereJob("sort", "f", terasort_stages(bounds, backend, 4),
                        record_size=REC, backend=backend)
        sess = SphereEngine(master, client).session("f", record_size=REC,
                                                    backend=backend)
        sess.run(job)
        outs, rep = sess.run(job)  # second run: cached lookup/plan/chunks
        results[backend] = (outs, rep)
    assert results["bytes"][0] == results["array"][0]
    assert (_report_key(results["bytes"][1])
            == _report_key(results["array"][1]))


def test_session_invalidates_on_join_event(tmp_path):
    """A server-joined event auto-drops the cached lookup, placement and
    chunks: the next job re-derives them against the grown cluster — no
    manual refresh() call anywhere."""
    from repro.sector import ChunkServer

    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=30, replication=3)
    reads = []
    orig_read = client.read_chunk
    client.read_chunk = lambda *a, **k: reads.append(a) or orig_read(*a, **k)

    eng = SphereEngine(master, client)
    sess = eng.session("f", record_size=REC, backend="array")
    sess.run(_identity_job("array"))
    n_reads = len(reads)
    assert n_reads > 0
    sess.run(_identity_job("array"))
    assert len(reads) == n_reads        # all cached

    master.register(ChunkServer("late", "tokyo", tmp_path))  # join event
    assert len(sess._plan) == 0         # caches dropped by the event
    outs, rep = sess.run(_identity_job("array"))
    assert len(reads) == 2 * n_reads    # re-fetched after invalidation
    assert "late" in sess.workers
    want_outs, want_rep = eng.run(_identity_job("array"))
    assert outs == want_outs            # schedules like a fresh run
    assert _report_key(rep) == _report_key(want_rep)
    assert sorted(b"".join(outs)) == sorted(data)


def test_session_invalidates_on_death_event(tmp_path):
    """After a worker dies, the server-died event re-binds the session to
    the live worker set: it schedules exactly like a fresh engine.run on
    the shrunken cluster instead of planning onto the dead worker."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=60, replication=3)
    eng = SphereEngine(master, client)
    sess = eng.session("f", record_size=REC, backend="array")
    sess.run(_identity_job("array"))

    servers[1].kill()
    master.deregister(servers[1].server_id)  # death event -> auto-invalidate
    assert servers[1].server_id not in sess.workers
    outs, rep = sess.run(_identity_job("array"))
    want_outs, want_rep = eng.run(_identity_job("array"))
    assert outs == want_outs
    assert _report_key(rep) == _report_key(want_rep)


def test_session_refresh_is_deprecated_noop(tmp_path):
    """refresh() survives as a deprecated alias that warns and keeps the
    caches intact (invalidation is the event bus's job now)."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=20, replication=3)
    reads = []
    orig_read = client.read_chunk
    client.read_chunk = lambda *a, **k: reads.append(a) or orig_read(*a, **k)

    sess = SphereEngine(master, client).session("f", record_size=REC,
                                                backend="array")
    want, _ = sess.run(_identity_job("array"))
    n_reads = len(reads)
    with pytest.warns(DeprecationWarning, match="no-op"):
        sess.refresh()
    outs, _ = sess.run(_identity_job("array"))
    assert len(reads) == n_reads        # caches survived the no-op
    assert outs == want


def test_session_chunk_cache_survives_mutating_udf(tmp_path):
    """A bytes UDF that mutates its input list in place must not corrupt
    the session's chunk cache for later jobs in the chain."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    data = _upload(client, "f", n=30)

    def hostile_udf(records):
        out = list(records)
        records.sort()      # in-place mutation
        del records[1:]     # and truncation
        return out

    job = SphereJob("hostile", "f", [SphereStage("m", hostile_udf)],
                    record_size=REC, backend="bytes")
    sess = SphereEngine(master, client).session("f", record_size=REC,
                                                backend="bytes")
    want, _ = sess.run(job)
    assert sorted(b"".join(want)) == sorted(data)
    outs, _ = sess.run(job)  # served from cache: must be unchanged
    assert outs == want


def test_kmeans_session_traces_once_and_matches_rebuild(tmp_path):
    """k-means through one session: every stage UDF compiles exactly once
    across ALL iterations, and centroids match the re-plan/re-trace
    path.  The session leg drives the raw stage/params API so it can
    assert the strong form of trace-once — the per-stage wrapper objects
    themselves report one trace after five iterations — which the
    rebuild path cannot satisfy (it builds fresh wrappers per iteration,
    so its udf_traces == 1 is per-executor, not per-chain)."""
    from jax import numpy as jnp

    from repro.core import SphereReport
    from repro.core.kmeans import _fold_outputs, make_kmeans_stages

    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal(c, 0.3, (200, 4))
                          for c in (np.zeros(4), np.full(4, 9.0))])
    res = {}

    def cloud(tag):
        sub = tmp_path / tag
        sub.mkdir()
        master, servers, client = make_cloud(sub, chunk_size=4096)
        client.upload("pts", encode_points(pts.astype(np.float32)),
                      replication=2)
        return SphereEngine(master, client)

    # rebuild baseline: fresh stages/planner/executor every iteration
    res[False], rep = kmeans_sphere(cloud("rebuild"), "pts", dim=4, k=2,
                                    iters=5, backend="array", session=False)
    assert rep.udf_traces == {"assign": 1, "fold": 1}

    # session leg: one stage pair, params updated per iteration
    eng = cloud("session")
    stages = make_kmeans_stages(4, 2, "array")
    job = SphereJob("kmeans", "pts", stages, record_size=16,
                    backend="array")
    sess = eng.session("pts", record_size=16, backend="array")
    centroids = np.random.default_rng(0).normal(size=(2, 4)) \
        .astype(np.float32)  # same init as kmeans_sphere(seed=0)
    rep = SphereReport()
    for _ in range(5):
        stages[0].params = jnp.asarray(centroids)
        outs, rep = sess.run(job, rep)
        sums, counts = _fold_outputs(outs, 4, 2, "array")
        nz = counts > 0
        centroids[nz] = (sums[nz] / counts[nz, None]).astype(np.float32)
    res[True] = centroids
    assert rep.udf_traces == {"assign": 1, "fold": 1}
    assert sess.jobs_run == 5
    # the same two wrapper objects served all five jobs, one trace each
    assert stages[0]._traced.traces == 1
    assert stages[1]._traced.traces == 1
    np.testing.assert_allclose(res[True], res[False], rtol=1e-4, atol=1e-4)


def test_kmeans_sphere_init_warm_start(tmp_path):
    """kmeans_sphere(init=...) overrides the seeded random init — the
    warm-start hook for chained window models: one iteration from a
    given model equals the numpy step from that model, and a mis-shaped
    init is rejected."""
    master, servers, client = make_cloud(tmp_path, chunk_size=4096)
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(256, 4)).astype(np.float32)
    client.upload("pts", encode_points(pts), replication=2)
    eng = SphereEngine(master, client)

    init = np.array([[-1, -1, -1, -1], [1, 1, 1, 1]], np.float32)
    cents, _ = kmeans_sphere(eng, "pts", dim=4, k=2, iters=1,
                             backend="array", init=init)
    a = ((pts[:, None, :] - init[None]) ** 2).sum(-1).argmin(1)
    want = init.copy()
    for j in range(2):
        if (a == j).any():
            want[j] = pts[a == j].mean(0)
    np.testing.assert_allclose(cents, want, rtol=1e-4, atol=1e-4)

    with pytest.raises(ValueError, match="init shape"):
        kmeans_sphere(eng, "pts", dim=4, k=2, iters=1, backend="array",
                      init=np.zeros((3, 4), np.float32))


def test_unclosed_session_is_garbage_collected(tmp_path):
    """The event bus must not keep an unclosed session alive (the
    pre-stream idiom never called close()): dropping the last reference
    frees the session and its caches, and the dead subscription
    self-unsubscribes on the next event."""
    import gc
    import weakref

    from repro.sector import ChunkServer

    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    _upload(client, "f", n=10)
    eng = SphereEngine(master, client)
    sess = eng.session("f", record_size=REC, backend="array")
    sess.run(_identity_job("array"))
    n_subs = len(master.events._subs)
    ref = weakref.ref(sess)
    del sess
    gc.collect()
    assert ref() is None                      # bus held no strong ref
    master.register(ChunkServer("late2", "tokyo", tmp_path))
    assert len(master.events._subs) < n_subs  # dead subs self-removed


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_kmeans_session_converges(tmp_path, backend):
    master, servers, client = make_cloud(tmp_path, chunk_size=4096)
    rng = np.random.default_rng(0)
    true_c = np.array([[0, 0], [8, 8]], np.float32)
    pts = np.concatenate([rng.normal(c, 0.3, (150, 2)) for c in true_c]) \
        .astype(np.float32)
    client.upload("pts", encode_points(pts), replication=2)
    eng = SphereEngine(master, client)
    sess = eng.session("pts", record_size=8 if backend == "array" else 0,
                       backend=backend)
    cents, rep = kmeans_sphere(eng, "pts", dim=2, k=2, iters=6,
                               backend=backend, session=sess)
    cents = cents[np.argsort(cents[:, 0])]
    assert np.abs(cents - true_c).max() < 0.5
    assert sess.jobs_run == 6
