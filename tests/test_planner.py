"""Pure-planner unit tests: scheduling behaviour with no Sector cloud.

The planner/executor split makes the control plane testable in
isolation — these tests drive SpherePlanner with synthetic tasks, speeds
and link costs and assert on the StagePlan alone."""
import pytest

from repro.core.planner import (PROCESS_RATE, IncrementalPlan,
                                SpherePlanner, TaskSpec)


def _tasks(sizes, locs):
    return [TaskSpec(f"c{i}", nb, tuple(ls))
            for i, (nb, ls) in enumerate(zip(sizes, locs))]


def test_locality_preferred_zero_movement():
    p = SpherePlanner(move_time=lambda nb, s, d: 99.0)
    plan = p.plan_stage(_tasks([100, 200, 300],
                               [("a",), ("b",), ("a", "b")]),
                        ["a", "b"])
    assert plan.bytes_moved == 0
    assert plan.bytes_local == 600
    for t in plan.tasks:
        assert t.worker in t.locs


def test_no_replica_moves_to_least_loaded():
    moves = []

    def move_time(nb, s, d):
        moves.append((nb, s, d))
        return 0.01

    p = SpherePlanner(move_time=move_time)
    plan = p.plan_stage(_tasks([500], [()]), ["a", "b"])
    assert plan.bytes_moved == 500 and plan.bytes_local == 0
    assert len(moves) == 1


def test_load_spreads_across_workers():
    """Many equal tasks replicated everywhere spread evenly: est-ready
    greedy never stacks a worker while another is idle."""
    n = 8
    p = SpherePlanner()
    plan = p.plan_stage(_tasks([100] * n, [("a", "b")] * n), ["a", "b"])
    per = {"a": 0, "b": 0}
    for t in plan.tasks:
        per[t.worker] += 1
    assert per == {"a": n // 2, "b": n // 2}


def test_speculation_wins_on_fast_replica():
    """A 50x-slow worker holding replicas gets tasks queued on it (the
    scheduler estimates uniform speeds); speculation must re-run the
    stragglers on the fast replica and the winner is recorded as the
    executor."""
    p = SpherePlanner(speeds={"slow": 0.02, "fast": 1.0},
                      speculate_factor=1.5)
    plan = p.plan_stage(_tasks([1000] * 40, [("slow", "fast")] * 40),
                        ["slow", "fast"])
    assert plan.speculated > 0
    assert plan.speculation_wins > 0
    rerouted = [t for t in plan.tasks if t.executor != t.worker]
    assert rerouted and all(t.executor == "fast" and t.worker == "slow"
                            for t in rerouted)


def test_plan_is_deterministic_and_pure():
    speeds = {"a": 0.5}
    tasks = _tasks([300, 100, 200, 100], [("a",), ("b",), (), ("a", "b")])
    p1 = SpherePlanner(speeds=speeds, move_time=lambda nb, s, d: nb / 1e6)
    p2 = SpherePlanner(speeds=speeds, move_time=lambda nb, s, d: nb / 1e6)
    assert p1.plan_stage(tasks, ["a", "b"]) == p1.plan_stage(tasks, ["a", "b"])
    assert p1.plan_stage(tasks, ["a", "b"]) == p2.plan_stage(tasks, ["a", "b"])


def test_stage_seconds_scale_with_speed():
    tasks = _tasks([PROCESS_RATE], [("a",)])  # 1 second on a speed-1 worker
    fast = SpherePlanner().plan_stage(tasks, ["a"])
    slow = SpherePlanner(speeds={"a": 0.5}).plan_stage(tasks, ["a"])
    assert fast.seconds == pytest.approx(1.0)
    assert slow.seconds == pytest.approx(2.0)


def test_empty_stage_plan():
    plan = SpherePlanner().plan_stage([], ["a"])
    assert plan.tasks == () and plan.seconds == 0.0


def test_incremental_plan_extend_and_retire():
    """Extend plans only the new group; retire drops a group without
    touching the survivors (same plan objects); merged() sums counters
    and takes the max makespan (groups run in parallel)."""
    p = SpherePlanner()
    inc = IncrementalPlan()
    a_plan, _ = p.extend_plan(inc, "a", _tasks([100, 200], [("w1",), ("w2",)]),
                              ["w1", "w2"])
    b_plan, _ = p.extend_plan(inc, "b", _tasks([400], [("w1",)]),
                              ["w1", "w2"])
    assert "a" in inc and "b" in inc and len(inc) == 2
    m = inc.merged()
    assert len(m.tasks) == 3
    assert m.bytes_local == 700
    assert m.seconds == pytest.approx(max(a_plan.seconds, b_plan.seconds))
    # group plans are exactly what a standalone plan would produce
    assert a_plan == p.plan_stage(_tasks([100, 200], [("w1",), ("w2",)]),
                                  ["w1", "w2"])

    assert inc.retire("a") is a_plan
    assert inc.retire("a") is None          # idempotent
    assert inc.groups["b"] is b_plan        # survivor untouched
    assert inc.merged() == b_plan

    with pytest.raises(ValueError, match="already planned"):
        p.extend_plan(inc, "b", _tasks([1], [("w1",)]), ["w1"])


def test_extend_plan_isolates_job_straggler_state():
    """Extending mid-job must not perturb the running job's straggler
    observations, and each group is planned from a clean state — its
    contribution is returned for the caller to replay."""
    p = SpherePlanner(speeds={"slow": 0.02, "fast": 1.0},
                      speculate_factor=1.5)
    p.job_stragglers["elsewhere"] = 7       # running job's state
    inc = IncrementalPlan()
    tasks = [TaskSpec(f"c{i}", 1000, ("slow", "fast")) for i in range(40)]
    plan, contrib = p.extend_plan(inc, "f", tasks, ["slow", "fast"])
    assert plan.speculated > 0
    assert contrib.get("slow", 0) > 0       # observed while planning "f"
    assert p.job_stragglers == {"elsewhere": 7}  # untouched


def test_empty_incremental_plan_merges_to_empty_stage():
    m = IncrementalPlan().merged()
    assert m.tasks == () and m.seconds == 0.0 and m.bytes_moved == 0


def test_shuffle_charges_actual_origins():
    """Local fragments are free; remote fragments are charged per flow and
    the shuffle completes when the slowest flow lands."""
    p = SpherePlanner(move_time=lambda nb, s, d: nb / 100.0)
    flows = [("a", "a", 500),   # stays put: local, no time
             ("b", "a", 200),
             ("a", "b", 400),
             ("b", "b", 0)]     # empty fragment: ignored
    seconds, moved, local = p.plan_shuffle(flows)
    assert local == 500
    assert moved == 600
    assert seconds == pytest.approx(4.0)  # slowest flow (400 bytes)


# ------------------------------------------------------------- contention

def _site_link_of(site_of):
    """Worker->site mapping to the unordered site-pair link key (what
    the engine's _link_of derives from the topology)."""
    def link_of(src, dst):
        a, b = site_of[src], site_of[dst]
        if a == b:
            return None
        return (a, b) if a <= b else (b, a)
    return link_of


def _plan(link_seconds, seconds=5.0, moved=100):
    from repro.core.planner import StagePlan
    return StagePlan((), seconds, 0, moved, 0, 0, tuple(link_seconds), 0.0)


def test_merged_serializes_groups_on_shared_bottleneck_link():
    """Transfer-group ready-time merging: two groups each needing 4s of
    the SAME link merge to the summed link time (~2x one group), not the
    old max-of-makespans."""
    inc = IncrementalPlan()
    inc.add("a", _plan([(("east", "west"), 4.0)]))
    inc.add("b", _plan([(("east", "west"), 4.0)]))
    m = inc.merged()
    assert m.seconds == pytest.approx(8.0)          # 4 + 4 on one wave
    assert dict(m.link_seconds) == {("east", "west"): 8.0}


def test_merged_disjoint_links_keep_max_of_makespans():
    """Groups whose transfers ride DISTINCT links still run in parallel:
    merged makespan is unchanged from the blind merge."""
    inc = IncrementalPlan()
    inc.add("a", _plan([(("east", "west"), 4.0)]))
    inc.add("b", _plan([(("east", "north"), 4.0)]))
    m = inc.merged()
    assert m.seconds == pytest.approx(5.0)          # max group makespan
    assert len(m.link_seconds) == 2


def test_blind_groups_merge_exactly_as_before():
    """A contention-blind planner's groups carry no link occupancy, so
    merged() reduces to the pre-contention max-of-makespans bit-for-bit."""
    inc = IncrementalPlan()
    inc.add("a", _plan([], seconds=3.0))
    inc.add("b", _plan([], seconds=7.0))
    m = inc.merged()
    assert m.seconds == pytest.approx(7.0)
    assert m.link_seconds == () and m.link_wait == 0.0


def test_plan_stage_queues_offloaded_fetches_per_link():
    """Two offloaded fetches sharing one wave serialize: the second
    transfer waits for the first (link_wait) and the link's busy time
    accumulates both."""
    site_of = {"a0": "east", "b0": "west", "b1": "west"}
    p = SpherePlanner(move_time=lambda nb, s, d: 10.0,
                      link_of=_site_link_of(site_of), offload=True,
                      speculate_factor=1e9)
    tasks = _tasks([int(PROCESS_RATE * 100)] * 3, [("a0",)] * 3)
    plan = p.plan_stage(tasks, ["a0", "b0", "b1"])
    by_worker = {t.executor for t in plan.tasks}
    assert by_worker == {"a0", "b0", "b1"}          # one task offloaded each
    assert dict(plan.link_seconds) == {("east", "west"): pytest.approx(20.0)}
    assert plan.link_wait == pytest.approx(10.0)    # 2nd transfer queued
    # makespan: local 100s; b0 move 10 + proc 100; b1 waits 10 then same
    assert plan.seconds == pytest.approx(120.0)


def test_plan_shuffle_sums_flows_sharing_a_link():
    """Flows on one wave serialize (sum); flows on distinct waves stay
    parallel (max); the blind planner keeps pure max-of-flows."""
    site_of = {"a": "east", "b": "west", "c": "west", "d": "north"}
    flows = [("a", "b", 200), ("a", "c", 400), ("a", "d", 100),
             ("a", "a", 500)]
    blind = SpherePlanner(move_time=lambda nb, s, d: nb / 100.0)
    aware = SpherePlanner(move_time=lambda nb, s, d: nb / 100.0,
                          link_of=_site_link_of(site_of))
    b_sec, b_moved, b_local = blind.plan_shuffle(flows)
    a_sec, a_moved, a_local = aware.plan_shuffle(flows)
    assert (b_moved, b_local) == (a_moved, a_local) == (700, 500)
    assert b_sec == pytest.approx(4.0)   # slowest flow, private links
    assert a_sec == pytest.approx(6.0)   # east-west carries 200+400


def test_price_plan_charges_blind_assignment_its_true_cost():
    """price_plan keeps the assignment but replays it through the link
    schedule: a blind plan that over-subscribed one wave gets its real,
    queued makespan; an aware plan prices at its own estimate."""
    site_of = {"a0": "east", "b0": "west", "b1": "west"}
    link_of = _site_link_of(site_of)
    kw = dict(move_time=lambda nb, s, d: 10.0, offload=True,
              speculate_factor=1e9)
    blind = SpherePlanner(link_of=None, **kw)
    aware = SpherePlanner(link_of=link_of, **kw)
    tasks = _tasks([int(PROCESS_RATE * 15)] * 4, [("a0",)] * 4)
    p_blind = blind.plan_stage(tasks, ["a0", "b0", "b1"])
    p_aware = aware.plan_stage(tasks, ["a0", "b0", "b1"])
    c_blind = aware.price_plan(p_blind, ["a0", "b0", "b1"])
    c_aware = aware.price_plan(p_aware, ["a0", "b0", "b1"])
    # the assignment is preserved, only the pricing changes
    assert [(t.key, t.executor) for t in sorted(c_blind.tasks,
                                                key=lambda t: t.key)] == \
           [(t.key, t.executor) for t in sorted(p_blind.tasks,
                                                key=lambda t: t.key)]
    assert c_blind.seconds > p_blind.seconds        # optimism corrected
    assert c_aware.seconds == pytest.approx(p_aware.seconds)
    assert c_blind.seconds >= c_aware.seconds       # aware plans the queue


def test_contention_knobs_off_is_bit_identical():
    """link_of=None + offload=False must reproduce the legacy planner
    exactly, including on plans with moves."""
    tasks = _tasks([300, 100, 200, 100], [("a",), ("b",), (), ("a", "b")])
    legacy = SpherePlanner(move_time=lambda nb, s, d: nb / 1e6)
    knobs = SpherePlanner(move_time=lambda nb, s, d: nb / 1e6,
                          link_of=None, offload=False)
    assert legacy.plan_stage(tasks, ["a", "b"]) == \
        knobs.plan_stage(tasks, ["a", "b"])
