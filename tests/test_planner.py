"""Pure-planner unit tests: scheduling behaviour with no Sector cloud.

The planner/executor split makes the control plane testable in
isolation — these tests drive SpherePlanner with synthetic tasks, speeds
and link costs and assert on the StagePlan alone."""
import pytest

from repro.core.planner import (PROCESS_RATE, IncrementalPlan,
                                SpherePlanner, TaskSpec)


def _tasks(sizes, locs):
    return [TaskSpec(f"c{i}", nb, tuple(ls))
            for i, (nb, ls) in enumerate(zip(sizes, locs))]


def test_locality_preferred_zero_movement():
    p = SpherePlanner(move_time=lambda nb, s, d: 99.0)
    plan = p.plan_stage(_tasks([100, 200, 300],
                               [("a",), ("b",), ("a", "b")]),
                        ["a", "b"])
    assert plan.bytes_moved == 0
    assert plan.bytes_local == 600
    for t in plan.tasks:
        assert t.worker in t.locs


def test_no_replica_moves_to_least_loaded():
    moves = []

    def move_time(nb, s, d):
        moves.append((nb, s, d))
        return 0.01

    p = SpherePlanner(move_time=move_time)
    plan = p.plan_stage(_tasks([500], [()]), ["a", "b"])
    assert plan.bytes_moved == 500 and plan.bytes_local == 0
    assert len(moves) == 1


def test_load_spreads_across_workers():
    """Many equal tasks replicated everywhere spread evenly: est-ready
    greedy never stacks a worker while another is idle."""
    n = 8
    p = SpherePlanner()
    plan = p.plan_stage(_tasks([100] * n, [("a", "b")] * n), ["a", "b"])
    per = {"a": 0, "b": 0}
    for t in plan.tasks:
        per[t.worker] += 1
    assert per == {"a": n // 2, "b": n // 2}


def test_speculation_wins_on_fast_replica():
    """A 50x-slow worker holding replicas gets tasks queued on it (the
    scheduler estimates uniform speeds); speculation must re-run the
    stragglers on the fast replica and the winner is recorded as the
    executor."""
    p = SpherePlanner(speeds={"slow": 0.02, "fast": 1.0},
                      speculate_factor=1.5)
    plan = p.plan_stage(_tasks([1000] * 40, [("slow", "fast")] * 40),
                        ["slow", "fast"])
    assert plan.speculated > 0
    assert plan.speculation_wins > 0
    rerouted = [t for t in plan.tasks if t.executor != t.worker]
    assert rerouted and all(t.executor == "fast" and t.worker == "slow"
                            for t in rerouted)


def test_plan_is_deterministic_and_pure():
    speeds = {"a": 0.5}
    tasks = _tasks([300, 100, 200, 100], [("a",), ("b",), (), ("a", "b")])
    p1 = SpherePlanner(speeds=speeds, move_time=lambda nb, s, d: nb / 1e6)
    p2 = SpherePlanner(speeds=speeds, move_time=lambda nb, s, d: nb / 1e6)
    assert p1.plan_stage(tasks, ["a", "b"]) == p1.plan_stage(tasks, ["a", "b"])
    assert p1.plan_stage(tasks, ["a", "b"]) == p2.plan_stage(tasks, ["a", "b"])


def test_stage_seconds_scale_with_speed():
    tasks = _tasks([PROCESS_RATE], [("a",)])  # 1 second on a speed-1 worker
    fast = SpherePlanner().plan_stage(tasks, ["a"])
    slow = SpherePlanner(speeds={"a": 0.5}).plan_stage(tasks, ["a"])
    assert fast.seconds == pytest.approx(1.0)
    assert slow.seconds == pytest.approx(2.0)


def test_empty_stage_plan():
    plan = SpherePlanner().plan_stage([], ["a"])
    assert plan.tasks == () and plan.seconds == 0.0


def test_incremental_plan_extend_and_retire():
    """Extend plans only the new group; retire drops a group without
    touching the survivors (same plan objects); merged() sums counters
    and takes the max makespan (groups run in parallel)."""
    p = SpherePlanner()
    inc = IncrementalPlan()
    a_plan, _ = p.extend_plan(inc, "a", _tasks([100, 200], [("w1",), ("w2",)]),
                              ["w1", "w2"])
    b_plan, _ = p.extend_plan(inc, "b", _tasks([400], [("w1",)]),
                              ["w1", "w2"])
    assert "a" in inc and "b" in inc and len(inc) == 2
    m = inc.merged()
    assert len(m.tasks) == 3
    assert m.bytes_local == 700
    assert m.seconds == pytest.approx(max(a_plan.seconds, b_plan.seconds))
    # group plans are exactly what a standalone plan would produce
    assert a_plan == p.plan_stage(_tasks([100, 200], [("w1",), ("w2",)]),
                                  ["w1", "w2"])

    assert inc.retire("a") is a_plan
    assert inc.retire("a") is None          # idempotent
    assert inc.groups["b"] is b_plan        # survivor untouched
    assert inc.merged() == b_plan

    with pytest.raises(ValueError, match="already planned"):
        p.extend_plan(inc, "b", _tasks([1], [("w1",)]), ["w1"])


def test_extend_plan_isolates_job_straggler_state():
    """Extending mid-job must not perturb the running job's straggler
    observations, and each group is planned from a clean state — its
    contribution is returned for the caller to replay."""
    p = SpherePlanner(speeds={"slow": 0.02, "fast": 1.0},
                      speculate_factor=1.5)
    p.job_stragglers["elsewhere"] = 7       # running job's state
    inc = IncrementalPlan()
    tasks = [TaskSpec(f"c{i}", 1000, ("slow", "fast")) for i in range(40)]
    plan, contrib = p.extend_plan(inc, "f", tasks, ["slow", "fast"])
    assert plan.speculated > 0
    assert contrib.get("slow", 0) > 0       # observed while planning "f"
    assert p.job_stragglers == {"elsewhere": 7}  # untouched


def test_empty_incremental_plan_merges_to_empty_stage():
    m = IncrementalPlan().merged()
    assert m.tasks == () and m.seconds == 0.0 and m.bytes_moved == 0


def test_shuffle_charges_actual_origins():
    """Local fragments are free; remote fragments are charged per flow and
    the shuffle completes when the slowest flow lands."""
    p = SpherePlanner(move_time=lambda nb, s, d: nb / 100.0)
    flows = [("a", "a", 500),   # stays put: local, no time
             ("b", "a", 200),
             ("a", "b", 400),
             ("b", "b", 0)]     # empty fragment: ignored
    seconds, moved, local = p.plan_shuffle(flows)
    assert local == 500
    assert moved == 600
    assert seconds == pytest.approx(4.0)  # slowest flow (400 bytes)
