"""Sphere tracing plane + metrics registry (ISSUE 10).

Covers the tracer's recording contract (spans, parents, instants, two
clock domains, Chrome export), the zero-cost disabled path, the metrics
registry's instrument semantics, and the two reconciliation guarantees:
``SphereReport`` fields equal the registry series the report mirrors
into, and the bytes and array backends emit identical span *counts* for
every shared (non-device) span name on the same job.
"""
import threading

import numpy as np
import pytest

from conftest import make_cloud
from repro.core import (MetricsRegistry, NULL_TRACER, SphereEngine,
                        SphereJob, Tracer)
from repro.core.planner import _MIRRORED_COUNTERS
from repro.core.shuffle import sample_boundaries, terasort_stages
from repro.core.trace import NullTracer, link_track

RECORD, KEY = 100, 10


# ------------------------------ tracer core ---------------------------------

def test_span_nesting_and_parent_links():
    t = Tracer()
    with t.span("outer", track="control") as outer:
        with t.span("inner", track="control") as inner:
            pass
        t.instant("mark", track="control")
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert t.count("outer") == 1 and t.count("inner") == 1
    assert t.count("mark") == 1
    assert t.counts_by_name() == {"outer": 1, "inner": 1, "mark": 1}


def test_span_measures_wall_seconds():
    t = Tracer()
    with t.span("timed") as sp:
        pass
    assert sp.wall_seconds >= 0.0
    assert sp.t1 >= sp.t0


def test_parent_stack_is_thread_local():
    t = Tracer()
    seen = {}

    def worker():
        with t.span("child-thread") as sp:
            seen["parent"] = sp.parent_id

    with t.span("main-thread"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    # the producer thread's span must NOT parent to the main thread's
    assert seen["parent"] is None


def test_add_span_and_instant_validate_clock():
    t = Tracer()
    t.add_span("sim-task", track="worker:w0", t0=1.0, t1=2.5, clock="sim")
    with pytest.raises(ValueError, match="unknown clock"):
        t.add_span("bad", track="x", t0=0, t1=1, clock="gps")
    with pytest.raises(ValueError, match="unknown clock"):
        t.instant("bad", track="x", clock="gps")


def test_set_attrs_merges():
    t = Tracer()
    with t.span("s", attrs={"a": 1}) as sp:
        sp.set_attrs(b=2)
    assert sp.attrs == {"a": 1, "b": 2}


def test_null_tracer_is_timer_only():
    with NULL_TRACER.span("anything", track="shuffle") as sp:
        pass
    assert sp.wall_seconds >= 0.0          # the one timing idiom still works
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.add_span("x", track="t", t0=0, t1=1) is None
    assert NULL_TRACER.instant("x", track="t") is None
    with pytest.raises(RuntimeError, match="tracing is disabled"):
        NullTracer().export_chrome("/tmp/never.json")


# ----------------------------- chrome export --------------------------------

def test_export_chrome_structure(tmp_path):
    t = Tracer()
    with t.span("outer", track="control"):
        with t.span("inner", track="control"):
            pass
    t.add_span("task:a", track="worker:w0", t0=0.0, t1=2.0, clock="sim")
    t.add_span("xfer:a", track=link_track(("x", "y")), t0=0.5, t1=1.0,
               clock="sim")
    t.instant("host-sync", track="host-sync")
    path = tmp_path / "trace.json"
    doc = t.export_chrome(str(path))
    assert path.exists()
    assert doc["otherData"]["open_spans"] == 0
    assert doc["otherData"]["spans"] == 4
    assert doc["otherData"]["instants"] == 1

    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"process_name", "thread_name", "outer", "inner", "task:a",
            "host-sync"} <= names
    # sim and wall events live in distinct processes
    pid_of = {e["name"]: e["pid"] for e in evs if e.get("ph") == "X"}
    assert pid_of["task:a"] != pid_of["outer"]
    # per-track timestamps are monotonic in document order
    last = {}
    for e in evs:
        if e.get("ph") == "M":
            continue
        key = (e["pid"], e["tid"])
        assert e["ts"] >= last.get(key, float("-inf"))
        last[key] = e["ts"]


def test_export_passes_check_trace(tmp_path):
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "check_trace", os.path.join(os.path.dirname(__file__), "..",
                                    "scripts", "check_trace.py"))
    check_trace = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_trace)

    _, rep, _, tracer = _run_terasort(tmp_path, "bytes", Tracer())
    doc = tracer.export_chrome()
    assert check_trace.check(doc, expect=["worker:", "event:", "job:"]) == []
    # a violated expectation is reported
    assert check_trace.check(doc, expect=["no-such-span"])


# ----------------------------- metrics registry -----------------------------

def test_registry_instruments():
    m = MetricsRegistry()
    m.counter("c", run="r1").inc()
    m.counter("c", run="r1").inc(2.5)
    m.counter("c", run="r2").inc(10)       # distinct labels = distinct series
    assert m.value("c", run="r1") == 3.5
    assert m.value("c", run="r2") == 10
    assert m.value("never-written") == 0.0

    m.gauge("g").set(4)
    m.gauge("g").set(7)
    assert m.value("g") == 7.0

    h = m.histogram("h")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    assert h.stats() == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0}
    with pytest.raises(TypeError, match="histogram"):
        m.value("h")


def test_registry_kind_collision():
    m = MetricsRegistry()
    m.counter("x", a="1")
    with pytest.raises(TypeError, match="already registered as a counter"):
        m.gauge("x", a="1")
    m.gauge("x", a="2")                    # different labels: fine


def test_registry_snapshot_and_series():
    m = MetricsRegistry()
    m.counter("a").inc(5)
    m.histogram("b").observe(1.0)
    snap = {row["name"]: row for row in m.snapshot()}
    assert snap["a"]["value"] == 5.0 and snap["a"]["kind"] == "counter"
    assert snap["b"]["count"] == 1
    assert [i.name for i in m.series("a")] == ["a"]
    assert m.next_run_labels() != m.next_run_labels()


# --------------------------- engine integration -----------------------------

def _gen_records(n, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 256, size=(n, KEY), dtype=np.uint8)
    payload = np.full((n, RECORD - KEY), ord("v"), np.uint8)
    return np.concatenate([keys, payload], axis=1).tobytes()


def _run_terasort(tmp_path, backend, tracer=None, n=1500):
    master, _, client = make_cloud(tmp_path / backend,
                                   chunk_size=500 * RECORD)
    data = _gen_records(n)
    client.upload("tera", data)
    recs = [data[i:i + RECORD] for i in range(0, 200 * RECORD, RECORD)]
    bounds = sample_boundaries(recs, 4, key_bytes=KEY)
    metrics = MetricsRegistry()
    eng = SphereEngine(master, client, tracer=tracer, metrics=metrics)
    job = SphereJob("tsort", "tera",
                    terasort_stages(bounds, backend, 4, key_bytes=KEY),
                    record_size=RECORD, backend=backend)
    out, rep = eng.run(job)
    return out, rep, metrics, eng.tracer


def test_report_equals_registry(tmp_path):
    _, rep, metrics, _ = _run_terasort(tmp_path, "bytes")
    labels = rep.metric_labels
    assert labels.get("backend") == "bytes" and "run" in labels
    for name in sorted(_MIRRORED_COUNTERS):
        assert metrics.value(f"sphere.{name}", **labels) == \
            pytest.approx(getattr(rep, name)), name
    assert metrics.value("sphere.locality_fraction", **labels) == \
        pytest.approx(rep.locality_fraction)
    h = metrics.histogram("sphere.stage_seconds", **labels)
    assert h.count == len(rep.stage_seconds)
    assert h.total == pytest.approx(sum(rep.stage_seconds))


def test_report_equals_registry_array(tmp_path):
    _, rep, metrics, _ = _run_terasort(tmp_path, "array")
    labels = rep.metric_labels
    for name in sorted(_MIRRORED_COUNTERS):
        assert metrics.value(f"sphere.{name}", **labels) == \
            pytest.approx(getattr(rep, name)), name
    for stage, traces in rep.udf_traces.items():
        assert metrics.value("sphere.udf_traces", stage=stage,
                             **labels) == traces


def _shared_span_counts(tracer):
    """Span counts for names both backends emit: device-only names
    (``dispatch:*`` UDF dispatches, ``host-sync`` markers) excluded."""
    return {name: c for name, c in tracer.counts_by_name().items()
            if not name.startswith("dispatch:") and name != "host-sync"}


def test_span_count_parity_bytes_vs_array(tmp_path):
    out_b, _, _, t_bytes = _run_terasort(tmp_path, "bytes", Tracer())
    out_a, _, _, t_array = _run_terasort(tmp_path, "array", Tracer())
    assert b"".join(out_b) == b"".join(out_a)
    counts_b = _shared_span_counts(t_bytes)
    counts_a = _shared_span_counts(t_array)
    assert counts_b == counts_a
    # the taxonomy's control spans are all present
    for name in ("job:tsort", "plan:partition", "exec:partition",
                 "shuffle:partition", "plan:sort", "exec:sort",
                 "shuffle-round", "fetch-chunk", "planner:plan-stage"):
        assert counts_b.get(name, 0) >= 1, name


def test_tracing_changes_no_counters(tmp_path):
    """Tracing must ride the existing data plane: identical report
    counters (host syncs above all) with the tracer on and off."""
    _, rep_off, _, _ = _run_terasort(tmp_path / "off", "array")
    _, rep_on, _, _ = _run_terasort(tmp_path / "on", "array", Tracer())
    for name in ("host_syncs", "shuffle_rounds", "device_dispatches",
                 "tasks", "sim_seconds", "bytes_moved", "bytes_local"):
        assert getattr(rep_on, name) == getattr(rep_off, name), name


def test_attach_bus_replays_history(tmp_path):
    master, _, client = make_cloud(tmp_path, chunk_size=500 * RECORD)
    client.upload("tera", _gen_records(600))
    tracer = Tracer()
    # attach AFTER the cloud was built: the bounded history replays, so
    # the timeline still shows the joins/uploads that already happened
    tracer.attach_bus(master.events)
    assert tracer.count("event:server-joined") == 6
    assert tracer.count("event:file-created") == 1
    before = tracer.count("event:chunk-replicated")
    client.upload("tera2", _gen_records(600, seed=1))
    assert tracer.count("event:chunk-replicated") > before  # live too


def test_master_instants_and_repair_span(tmp_path):
    from repro.sector.replication import ReplicationDaemon

    master, servers, client = make_cloud(tmp_path, chunk_size=500 * RECORD)
    tracer = Tracer()
    SphereEngine(master, client, tracer=tracer)  # wires master.tracer
    assert master.tracer is tracer
    client.upload("tera", _gen_records(600))
    assert tracer.count("master:placement") >= 1
    daemon = ReplicationDaemon(master, client)
    master.deregister(servers[0].server_id)
    assert tracer.count("replication-repair") == 1
    rep_span = [e for e in tracer.snapshot()
                if e.name == "replication-repair"][0]
    assert rep_span.attrs["died"] == servers[0].server_id
    assert "repaired" in rep_span.attrs
    assert tracer.count("master:repair-plan") >= 1
    assert daemon.event_repairs == rep_span.attrs["repaired"]
