"""MoE dispatch equivalence and routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe
from repro.models.common import materialize
from repro.parallel.sharding import ParallelConfig


@pytest.fixture
def setup():
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced().replace(
        param_dtype="float32", compute_dtype="float32")
    params = materialize(moe.shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, params, x


def test_einsum_vs_gather_dispatch(setup, monkeypatch):
    """With no capacity drops the two dispatch modes are numerically equal."""
    cfg, params, x = setup
    monkeypatch.setattr(moe, "CAPACITY_FACTOR", 8.0)
    out_e, aux_e = moe.apply(params, x, cfg=cfg,
                             pcfg=ParallelConfig(moe_dispatch="einsum"))
    out_g, aux_g = moe.apply(params, x, cfg=cfg,
                             pcfg=ParallelConfig(moe_dispatch="gather"))
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=1e-4, atol=1e-5)
    assert abs(float(aux_e) - float(aux_g)) < 1e-6


def test_capacity_drops_consistent(setup):
    """Both modes drop the SAME tokens under tight capacity."""
    cfg, params, x = setup
    out_e, _ = moe.apply(params, x, cfg=cfg,
                         pcfg=ParallelConfig(moe_dispatch="einsum"))
    out_g, _ = moe.apply(params, x, cfg=cfg,
                         pcfg=ParallelConfig(moe_dispatch="gather"))
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=1e-4, atol=1e-5)


def test_aux_loss_uniform_router(setup):
    """A uniform router gives aux ~= coef (perfectly balanced)."""
    cfg, params, x = setup
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    _, aux = moe.apply(params, x, cfg=cfg, pcfg=ParallelConfig())
    assert abs(float(aux) / cfg.router_aux_coef - 1.0) < 0.3


def test_grad_flows_both_modes(setup):
    cfg, params, x = setup
    for mode in ("einsum", "gather"):
        def loss(p):
            out, aux = moe.apply(p, x, cfg=cfg,
                                 pcfg=ParallelConfig(moe_dispatch=mode))
            return jnp.sum(out**2) + aux
        g = jax.grad(loss)(params)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.isfinite(leaf).all())
        assert float(jnp.abs(g["wi"]).sum()) > 0
        assert float(jnp.abs(g["router"]).sum()) > 0
