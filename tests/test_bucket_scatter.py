"""Device-resident scatter parity: ``scatter_batch`` == bytes append order.

The engine's array-backend shuffle (`ArrayExecutor.bucketize` ->
``scatter_batch`` -> ``bucket_scatter``) replaces the per-record bytes
loop, so these tests hold it to the same contract the ids/histogram
parity suite holds ``partition_batch`` to:

- **bucket boundaries**: the strict ``#{bounds < key}`` rule, including
  boundary-equal keys, zero-tail multi-word ties, and variable-length
  boundaries (the trailing length word);
- **stability**: records in the same bucket keep input order — the
  bytes backend's append order, byte for byte;
- **the kernel itself** against the numpy oracle ``bucket_scatter_ref``,
  across block counts, internal padding, and dynamic ``n_valid`` reuse
  of one traced shape.

Everything runs interpret-mode on CPU; ``requires_accelerator`` marks
the one compiled (non-interpret) case, auto-skipped off-TPU/GPU.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.records import RecordBatch
from repro.core.shuffle import (hash_partitioner, range_partitioner,
                                reduce_partitioner, sample_boundaries,
                                scatter_batch)
from repro.kernels.bucket_partition import bucket_scatter, bucket_scatter_ref

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is a dev dep; CI installs it
    hypothesis = None

# small pad floor so tests exercise the shape ladder without tracing
# 4096-row interpret-mode kernels per case
PAD = 64


def _random_records(n, rec, seed=0):
    rng = np.random.default_rng(seed)
    blob = rng.integers(0, 256, size=(n, rec), dtype=np.uint8).tobytes()
    return blob, [blob[i:i + rec] for i in range(0, n * rec, rec)]


def _assert_scatter_parity(records, blob, rec, part, n, **kw):
    """scatter_batch pieces must equal the bytes backend's buckets."""
    kw.setdefault("pad_block", PAD)
    batch = RecordBatch.from_bytes(blob, rec)
    pieces = scatter_batch(batch, part, n, **kw)
    assert len(pieces) == max(n, 1)
    want = [[] for _ in range(max(n, 1))]
    for r in records:
        want[part(r, n)].append(r)
    for piece, wb in zip(pieces, want):
        assert piece.to_bytes() == b"".join(wb)
    assert sum(p.num_records for p in pieces) == len(records)


@pytest.mark.parametrize("n_buckets", [1, 2, 5, 16])
@pytest.mark.parametrize("n_records,record_size", [(1, 8), (97, 100),
                                                   (256, 12)])
def test_hash_scatter_matches_bytes(n_records, record_size, n_buckets):
    blob, records = _random_records(n_records, record_size,
                                    seed=n_records + n_buckets)
    _assert_scatter_parity(records, blob, record_size,
                           hash_partitioner(key_bytes=8), n_buckets)


@pytest.mark.parametrize("key_bytes", [4, 10])
@pytest.mark.parametrize("n_buckets", [2, 6])
@pytest.mark.parametrize("n_records,record_size", [(97, 100), (333, 10)])
def test_range_scatter_matches_bytes(n_records, record_size, n_buckets,
                                     key_bytes):
    blob, records = _random_records(n_records, record_size,
                                    seed=7 * n_records + n_buckets)
    bounds = sample_boundaries(records[:200], n_buckets, key_bytes=key_bytes)
    _assert_scatter_parity(records, blob, record_size,
                           range_partitioner(bounds), n_buckets)


def test_scatter_stability_duplicate_keys():
    """Duplicate keys with distinct payloads: the scattered bucket must
    preserve input order exactly (counting scatter stability), not just
    bucket membership."""
    keys = [b"\x40" * 10, b"\x80" * 10, b"\x40" * 10, b"\x10" * 10]
    records = [k + bytes([i]) * 6 for i, k in enumerate(keys * 25)]
    part = range_partitioner([b"\x40" * 10, b"\x80" * 10])
    _assert_scatter_parity(records, b"".join(records), 16, part, 3)


def test_scatter_boundary_strictness_multiword():
    """Keys equal to a 3-word boundary, keys differing only in the
    zero-padded tail word, and heavy duplicates — the strict
    #{bounds < key} rule must agree with bytes on every one."""
    b1 = b"\x40" * 10
    b2 = b"\x80" * 9 + b"\x00"
    part = range_partitioner([b1, b2])
    keys = ([b1] * 4 + [b1[:9] + b"\x3f"] * 3 + [b1[:9] + b"\x41"] * 3
            + [b2] * 4 + [b2[:9] + b"\x01"] * 2
            + [b"\x00" * 10] * 2 + [b"\xff" * 10] * 2)
    records = [k + b"pp" for k in keys]
    _assert_scatter_parity(records, b"".join(records), 12, part, 3)


def test_scatter_variable_length_boundaries():
    """Boundaries of differing byte lengths, one a zero-tailed prefix of
    another: the kernel's trailing length word must reproduce Python's
    shorter-prefix-sorts-first bytes ordering."""
    bounds = [b"\x10\x20", b"\x10\x20\x00", b"\x10\x20\x00\x00\x00\x01",
              b"\x90\x10\x20\x30\x40"]
    part = range_partitioner(bounds)
    prefixes = [b"\x00\x00", b"\x10\x1f", b"\x10\x20", b"\x10\x21",
                b"\x90\x10", b"\xff\xff"]
    records = [p + bytes([i]) * 4 for i, p in enumerate(prefixes)]
    records += [b"\x10\x20\x00\x00\x00\x00", b"\x10\x20\x00\x00\x00\x01",
                b"\x90\x10\x20\x30\x40\x00"]
    _assert_scatter_parity(records, b"".join(records), 6, part, 5)


def test_scatter_degenerate_paths():
    blob, records = _random_records(50, 10, seed=5)
    batch = RecordBatch.from_bytes(blob, 10)
    # n == 1: the batch passes through untouched
    (only,) = scatter_batch(batch, hash_partitioner(4), 1)
    assert only.to_bytes() == blob
    # empty batch: n empty pieces of the right record size
    empty = RecordBatch.empty(10)
    pieces = scatter_batch(empty, hash_partitioner(4), 4)
    assert [p.num_records for p in pieces] == [0] * 4
    assert all(p.record_size == 10 for p in pieces)
    # reduce partitioner: single-bucket short circuit, no kernel call
    pieces = scatter_batch(batch, reduce_partitioner(), 3)
    assert pieces[0].to_bytes() == blob
    assert [p.num_records for p in pieces[1:]] == [0, 0]
    # arbitrary Python partitioner: host-loop fallback, same contract
    _assert_scatter_parity(records, blob, 10, lambda r, n: r[0] % n, 3)


def _lexsorted_rows(rows: np.ndarray) -> np.ndarray:
    return rows[np.lexsort(rows.T[::-1])]


def _kernel_case(n, k, n_buckets, seed):
    rng = np.random.default_rng(seed)
    # low-entropy words force duplicate keys and boundary-equal keys
    keys = rng.integers(0, 4, size=(n, k), dtype=np.uint32)
    bounds = _lexsorted_rows(
        rng.integers(0, 4, size=(n_buckets - 1, k), dtype=np.uint32))
    # payload carries a row counter so stability violations are visible
    data = np.zeros((n, 8), np.uint8)
    data[:, :4] = rng.integers(0, 256, size=(n, 4), dtype=np.uint8)
    data[:, 4] = np.arange(n) % 256
    data[:, 5] = np.arange(n) // 256
    return (jnp.asarray(data), jnp.asarray(keys), jnp.asarray(bounds))


@pytest.mark.parametrize("block_n", [7, 32, 101])
def test_kernel_scatter_vs_ref_blocks(block_n):
    """Direct kernel vs the numpy oracle across block counts, including
    block sizes that do not divide n (internal padded tail)."""
    n, nb = 101, 5
    data, keys, bounds = _kernel_case(n, 3, nb, seed=block_n)
    out, hist = bucket_scatter(data, keys, bounds, n, n_buckets=nb,
                               block_n=block_n, interpret=True)
    ref_out, ref_hist = bucket_scatter_ref(data, keys, bounds, nb)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))


def test_kernel_dynamic_n_valid_reuse():
    """One padded shape, different n_valid values: rows past n_valid
    must scatter to the tail (trash bucket) and never enter the
    histogram — the contract that lets one trace serve every record
    count."""
    data, keys, bounds = _kernel_case(128, 3, 4, seed=9)
    for nv in (128, 101, 50, 1):
        out, hist = bucket_scatter(data, keys, bounds, nv, n_buckets=4,
                                   block_n=32, interpret=True)
        ref_out, ref_hist = bucket_scatter_ref(data[:nv], keys[:nv],
                                               bounds, 4)
        assert int(np.asarray(hist).sum()) == nv
        np.testing.assert_array_equal(np.asarray(hist),
                                      np.asarray(ref_hist))
        np.testing.assert_array_equal(np.asarray(out)[:nv],
                                      np.asarray(ref_out))


@pytest.mark.requires_accelerator
def test_kernel_scatter_compiled():
    """The same oracle check through the compiled (non-interpret) kernel
    — exercises the real Mosaic/Triton lowering on TPU/GPU."""
    n, nb = 5000, 7
    data, keys, bounds = _kernel_case(n, 3, nb, seed=1)
    out, hist = bucket_scatter(data, keys, bounds, n, n_buckets=nb,
                               interpret=False)
    ref_out, ref_hist = bucket_scatter_ref(data, keys, bounds, nb)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))


def _range_case(records, rng, bound_len, n_buckets):
    """Boundaries biased toward record prefixes, zero tails, duplicates."""
    raw = []
    for _ in range(max(n_buckets - 1, 0)):
        if records and rng.random() < 0.5:
            b = records[rng.integers(len(records))][:bound_len]
            if rng.random() < 0.3:
                b = b[:max(1, bound_len // 2)] + b"\x00"
        else:
            b = rng.bytes(bound_len)
        raw.append(b)
    return range_partitioner(sorted(raw))


if hypothesis is not None:
    @settings(max_examples=25, deadline=None)
    @given(data=st.binary(min_size=0, max_size=400),
           rec=st.sampled_from([8, 16]),
           n_buckets=st.integers(1, 5),
           bound_len=st.sampled_from([4, 10]),
           seed=st.integers(0, 2**31 - 1))
    def test_scatter_property(data, rec, n_buckets, bound_len, seed):
        """Random records vs random variable-length boundaries: the
        scattered pieces equal the bytes buckets byte-for-byte (order
        included). Shapes are constrained so interpret-mode traces are
        shared across examples."""
        n = max(1, len(data) // rec)
        blob = (data + bytes(n * rec))[:n * rec]
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        part = _range_case(records, np.random.default_rng(seed),
                           bound_len, n_buckets)
        _assert_scatter_parity(records, blob, rec, part, n_buckets,
                               block_n=32)


def test_scatter_randomized():
    """Non-hypothesis twin of the property test (runs even without the
    hypothesis dev dep), 25 rounds."""
    rng = np.random.default_rng(77)
    for _ in range(25):
        rec = int(rng.choice([8, 16]))
        n = int(rng.integers(1, 60))
        blob = rng.bytes(n * rec)
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        nb = int(rng.integers(1, 6))
        part = _range_case(records, rng, int(rng.choice([4, 10])), nb)
        _assert_scatter_parity(records, blob, rec, part, nb, block_n=32)
