"""Device-resident scatter parity: ``scatter_batch`` == bytes append order.

The engine's array-backend shuffle (`ArrayExecutor.bucketize` ->
``scatter_batch`` -> ``bucket_scatter``) replaces the per-record bytes
loop, so these tests hold it to the same contract the ids/histogram
parity suite holds ``partition_batch`` to:

- **bucket boundaries**: the strict ``#{bounds < key}`` rule, including
  boundary-equal keys, zero-tail multi-word ties, and variable-length
  boundaries (the trailing length word);
- **stability**: records in the same bucket keep input order — the
  bytes backend's append order, byte for byte;
- **the kernel itself** against the numpy oracle ``bucket_scatter_ref``,
  across block counts, internal padding, and dynamic ``n_valid`` reuse
  of one traced shape.

Everything runs interpret-mode on CPU; ``requires_accelerator`` marks
the one compiled (non-interpret) case, auto-skipped off-TPU/GPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.records import RecordBatch
from repro.core.shuffle import (hash_partitioner, range_partitioner,
                                reduce_partitioner, sample_boundaries,
                                scatter_batch, scatter_dispatch,
                                scatter_pieces_dispatch)
from repro.kernels.bucket_partition import bucket_scatter, bucket_scatter_ref

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is a dev dep; CI installs it
    hypothesis = None

# small pad floor so tests exercise the shape ladder without tracing
# 4096-row interpret-mode kernels per case
PAD = 64


def _random_records(n, rec, seed=0):
    rng = np.random.default_rng(seed)
    blob = rng.integers(0, 256, size=(n, rec), dtype=np.uint8).tobytes()
    return blob, [blob[i:i + rec] for i in range(0, n * rec, rec)]


def _assert_scatter_parity(records, blob, rec, part, n, **kw):
    """scatter_batch pieces must equal the bytes backend's buckets."""
    kw.setdefault("pad_block", PAD)
    batch = RecordBatch.from_bytes(blob, rec)
    pieces = scatter_batch(batch, part, n, **kw)
    assert len(pieces) == max(n, 1)
    want = [[] for _ in range(max(n, 1))]
    for r in records:
        want[part(r, n)].append(r)
    for piece, wb in zip(pieces, want):
        assert piece.to_bytes() == b"".join(wb)
    assert sum(p.num_records for p in pieces) == len(records)


@pytest.mark.parametrize("n_buckets", [1, 2, 5, 16])
@pytest.mark.parametrize("n_records,record_size", [(1, 8), (97, 100),
                                                   (256, 12)])
def test_hash_scatter_matches_bytes(n_records, record_size, n_buckets):
    blob, records = _random_records(n_records, record_size,
                                    seed=n_records + n_buckets)
    _assert_scatter_parity(records, blob, record_size,
                           hash_partitioner(key_bytes=8), n_buckets)


@pytest.mark.parametrize("key_bytes", [4, 10])
@pytest.mark.parametrize("n_buckets", [2, 6])
@pytest.mark.parametrize("n_records,record_size", [(97, 100), (333, 10)])
def test_range_scatter_matches_bytes(n_records, record_size, n_buckets,
                                     key_bytes):
    blob, records = _random_records(n_records, record_size,
                                    seed=7 * n_records + n_buckets)
    bounds = sample_boundaries(records[:200], n_buckets, key_bytes=key_bytes)
    _assert_scatter_parity(records, blob, record_size,
                           range_partitioner(bounds), n_buckets)


def test_scatter_stability_duplicate_keys():
    """Duplicate keys with distinct payloads: the scattered bucket must
    preserve input order exactly (counting scatter stability), not just
    bucket membership."""
    keys = [b"\x40" * 10, b"\x80" * 10, b"\x40" * 10, b"\x10" * 10]
    records = [k + bytes([i]) * 6 for i, k in enumerate(keys * 25)]
    part = range_partitioner([b"\x40" * 10, b"\x80" * 10])
    _assert_scatter_parity(records, b"".join(records), 16, part, 3)


def test_scatter_boundary_strictness_multiword():
    """Keys equal to a 3-word boundary, keys differing only in the
    zero-padded tail word, and heavy duplicates — the strict
    #{bounds < key} rule must agree with bytes on every one."""
    b1 = b"\x40" * 10
    b2 = b"\x80" * 9 + b"\x00"
    part = range_partitioner([b1, b2])
    keys = ([b1] * 4 + [b1[:9] + b"\x3f"] * 3 + [b1[:9] + b"\x41"] * 3
            + [b2] * 4 + [b2[:9] + b"\x01"] * 2
            + [b"\x00" * 10] * 2 + [b"\xff" * 10] * 2)
    records = [k + b"pp" for k in keys]
    _assert_scatter_parity(records, b"".join(records), 12, part, 3)


def test_scatter_variable_length_boundaries():
    """Boundaries of differing byte lengths, one a zero-tailed prefix of
    another: the kernel's trailing length word must reproduce Python's
    shorter-prefix-sorts-first bytes ordering."""
    bounds = [b"\x10\x20", b"\x10\x20\x00", b"\x10\x20\x00\x00\x00\x01",
              b"\x90\x10\x20\x30\x40"]
    part = range_partitioner(bounds)
    prefixes = [b"\x00\x00", b"\x10\x1f", b"\x10\x20", b"\x10\x21",
                b"\x90\x10", b"\xff\xff"]
    records = [p + bytes([i]) * 4 for i, p in enumerate(prefixes)]
    records += [b"\x10\x20\x00\x00\x00\x00", b"\x10\x20\x00\x00\x00\x01",
                b"\x90\x10\x20\x30\x40\x00"]
    _assert_scatter_parity(records, b"".join(records), 6, part, 5)


def test_scatter_degenerate_paths():
    blob, records = _random_records(50, 10, seed=5)
    batch = RecordBatch.from_bytes(blob, 10)
    # n == 1: the batch passes through untouched
    (only,) = scatter_batch(batch, hash_partitioner(4), 1)
    assert only.to_bytes() == blob
    # empty batch: n empty pieces of the right record size
    empty = RecordBatch.empty(10)
    pieces = scatter_batch(empty, hash_partitioner(4), 4)
    assert [p.num_records for p in pieces] == [0] * 4
    assert all(p.record_size == 10 for p in pieces)
    # reduce partitioner: single-bucket short circuit, no kernel call
    pieces = scatter_batch(batch, reduce_partitioner(), 3)
    assert pieces[0].to_bytes() == blob
    assert [p.num_records for p in pieces[1:]] == [0, 0]
    # arbitrary Python partitioner: host-loop fallback, same contract
    _assert_scatter_parity(records, blob, 10, lambda r, n: r[0] % n, 3)


def _padded_junk_batch(blob, rec, n, pad_rows, seed=0):
    """A padding-resident batch: valid records up front, JUNK tail rows
    that must never influence any result."""
    rng = np.random.default_rng(seed)
    junk = rng.integers(0, 256, size=(pad_rows - n, rec), dtype=np.uint8)
    block = np.concatenate(
        [np.frombuffer(blob, np.uint8).reshape(n, rec), junk])
    return RecordBatch(jnp.asarray(block), n_valid=n)


def test_scatter_padded_resident_input_parity():
    """A padding-resident batch (dynamic n_valid, junk tail) scatters
    identically to the exact batch of its valid records — on the kernel
    path AND the host-loop fallback (which must slice, not leak junk)."""
    n, rec, nb = 90, 12, 5
    blob, records = _random_records(n, rec, seed=31)
    for part in (range_partitioner(sample_boundaries(records, nb,
                                                     key_bytes=10)),
                 hash_partitioner(key_bytes=8),
                 lambda r, k: r[0] % k):
        for pad_rows in (96, 128, 256):
            padded = _padded_junk_batch(blob, rec, n, pad_rows, seed=pad_rows)
            pieces = scatter_batch(padded, part, nb, pad_block=PAD)
            want = [[] for _ in range(nb)]
            for r in records:
                want[part(r, nb)].append(r)
            for piece, wb in zip(pieces, want):
                assert piece.to_bytes() == b"".join(wb)
            assert sum(p.num_records for p in pieces) == n


def test_scatter_dispatch_defers_the_histogram_sync():
    """The dispatch half returns with the kernel merely enqueued — no
    pieces yet — and harvest() with externally synced metadata (the
    executor's one-barrier-per-round path) resolves the same pieces as
    the self-syncing scatter_batch."""
    nb = 4
    blob, records = _random_records(120, 16, seed=5)
    part = range_partitioner(sample_boundaries(records, nb, key_bytes=10))
    batches = [RecordBatch.from_bytes(blob, 16) for _ in range(3)]
    disps = [scatter_dispatch(b, part, nb, pad_block=PAD) for b in batches]
    assert all(d.pending and d.pieces is None and d.host_syncs == 0
               for d in disps)
    synced = jax.device_get([d.sync_arrays for d in disps])  # ONE barrier
    for d, s in zip(disps, synced):
        pieces = d.harvest(synced=s)
        assert not d.pending
        ref = scatter_batch(RecordBatch.from_bytes(blob, 16), part, nb,
                            pad_block=PAD)
        assert [p.to_bytes() for p in pieces] == [p.to_bytes() for p in ref]


def test_scatter_dispatch_degenerates_resolve_at_dispatch():
    """Shapes with nothing to sync resolve into pieces immediately
    (pending=False, host_syncs=0); the host-loop fallback resolves too
    but reports the sync it already paid."""
    blob, _ = _random_records(40, 8, seed=6)
    batch = RecordBatch.from_bytes(blob, 8)
    for disp in (scatter_dispatch(batch, hash_partitioner(4), 1),
                 scatter_dispatch(RecordBatch.empty(8),
                                  hash_partitioner(4), 4),
                 scatter_dispatch(batch, reduce_partitioner(), 3)):
        assert not disp.pending and disp.host_syncs == 0
    host_loop = scatter_dispatch(batch, lambda r, n: r[0] % n, 3)
    assert not host_loop.pending and host_loop.host_syncs == 1


def _resident_pieces(rec, counts, rows, seed=0):
    """Padding-resident pieces at one ladder shape + their valid records
    in piece order (the executor's per-worker stage output shape)."""
    pieces, records = [], []
    for i, k in enumerate(counts):
        blob, recs = _random_records(k, rec, seed=seed + 17 * i)
        pieces.append(_padded_junk_batch(blob, rec, k, rows, seed=seed + i))
        records.extend(recs)
    return pieces, records


def test_scatter_pieces_segmented_parity():
    """Uniform resident pieces take the fused segmented path — no eager
    concat, host-invert metadata pending — and harvest exactly the
    buckets the bytes backend builds from the pieces' valid records in
    piece order."""
    rec, nb, rows = 16, 5, 96
    pieces, records = _resident_pieces(rec, [60, 11, 90, 1], rows, seed=41)
    part = range_partitioner(sample_boundaries(records, nb, key_bytes=10))
    disp = scatter_pieces_dispatch(pieces, part, nb, pad_block=PAD,
                                   interpret=True)
    assert disp.pending and disp.host_syncs == 0
    assert disp.src is not None and disp.dest is not None
    out = disp.harvest()
    want = [[] for _ in range(nb)]
    for r in records:
        want[part(r, nb)].append(r)
    for piece, wb in zip(out, want):
        assert piece.to_bytes() == b"".join(wb)
    assert sum(p.num_records for p in out) == len(records)


def test_scatter_pieces_ragged_and_single_fall_through():
    """Ragged piece shapes concatenate and fall through to the per-batch
    dispatch; a single piece delegates outright — identical buckets
    either way."""
    rec, nb = 16, 4
    ragged, records = [], []
    for i, (k, rows) in enumerate([(50, 64), (20, 96), (33, 48)]):
        blob, recs = _random_records(k, rec, seed=91 + i)
        ragged.append(_padded_junk_batch(blob, rec, k, rows, seed=i))
        records.extend(recs)
    part = range_partitioner(sample_boundaries(records, nb, key_bytes=10))
    want = [[] for _ in range(nb)]
    for r in records:
        want[part(r, nb)].append(r)
    out = scatter_pieces_dispatch(ragged, part, nb, pad_block=PAD,
                                  interpret=True).harvest()
    for piece, wb in zip(out, want):
        assert piece.to_bytes() == b"".join(wb)
    single = scatter_pieces_dispatch(ragged[:1], part, nb, pad_block=PAD,
                                     interpret=True).harvest()
    ref = scatter_batch(ragged[0], part, nb, pad_block=PAD, interpret=True)
    assert [p.to_bytes() for p in single] == [p.to_bytes() for p in ref]


def test_scatter_pieces_reduce_and_single_bucket_resolve_eagerly():
    """Degenerate rounds through the pieces API still resolve at
    dispatch with zero syncs (the host_syncs == shuffle_rounds
    accounting counts only real barriers)."""
    rec = 8
    pieces, records = _resident_pieces(rec, [30, 10], 48, seed=3)
    for part, n in ((reduce_partitioner(), 3), (hash_partitioner(4), 1)):
        disp = scatter_pieces_dispatch(pieces, part, n, pad_block=PAD,
                                       interpret=True)
        assert not disp.pending and disp.host_syncs == 0
        got = b"".join(p.to_bytes() for p in disp.harvest())
        assert got == b"".join(records)


@pytest.mark.requires_accelerator
def test_scatter_batch_defaults_to_compiled_on_accelerator():
    """With interpret unspecified, a GPU/TPU backend must take the
    compiled Pallas lowering (Triton/Mosaic) — and still match bytes."""
    from repro.kernels.bucket_partition.ops import _compiled_backend
    assert _compiled_backend()
    n, rec, nb = 3000, 16, 6
    blob, records = _random_records(n, rec, seed=8)
    part = range_partitioner(sample_boundaries(records, nb, key_bytes=10))
    _assert_scatter_parity(records, blob, rec, part, nb)


def _lexsorted_rows(rows: np.ndarray) -> np.ndarray:
    return rows[np.lexsort(rows.T[::-1])]


def _kernel_case(n, k, n_buckets, seed):
    rng = np.random.default_rng(seed)
    # low-entropy words force duplicate keys and boundary-equal keys
    keys = rng.integers(0, 4, size=(n, k), dtype=np.uint32)
    bounds = _lexsorted_rows(
        rng.integers(0, 4, size=(n_buckets - 1, k), dtype=np.uint32))
    # payload carries a row counter so stability violations are visible
    data = np.zeros((n, 8), np.uint8)
    data[:, :4] = rng.integers(0, 256, size=(n, 4), dtype=np.uint8)
    data[:, 4] = np.arange(n) % 256
    data[:, 5] = np.arange(n) // 256
    return (jnp.asarray(data), jnp.asarray(keys), jnp.asarray(bounds))


@pytest.mark.parametrize("block_n", [7, 32, 101])
def test_kernel_scatter_vs_ref_blocks(block_n):
    """Direct kernel vs the numpy oracle across block counts, including
    block sizes that do not divide n (internal padded tail)."""
    n, nb = 101, 5
    data, keys, bounds = _kernel_case(n, 3, nb, seed=block_n)
    out, hist = bucket_scatter(data, keys, bounds, n, n_buckets=nb,
                               block_n=block_n, interpret=True)
    ref_out, ref_hist = bucket_scatter_ref(data, keys, bounds, nb)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))


def test_kernel_dynamic_n_valid_reuse():
    """One padded shape, different n_valid values: rows past n_valid
    must scatter to the tail (trash bucket) and never enter the
    histogram — the contract that lets one trace serve every record
    count."""
    data, keys, bounds = _kernel_case(128, 3, 4, seed=9)
    for nv in (128, 101, 50, 1):
        out, hist = bucket_scatter(data, keys, bounds, nv, n_buckets=4,
                                   block_n=32, interpret=True)
        ref_out, ref_hist = bucket_scatter_ref(data[:nv], keys[:nv],
                                               bounds, 4)
        assert int(np.asarray(hist).sum()) == nv
        np.testing.assert_array_equal(np.asarray(hist),
                                      np.asarray(ref_hist))
        np.testing.assert_array_equal(np.asarray(out)[:nv],
                                      np.asarray(ref_out))


@pytest.mark.requires_accelerator
def test_kernel_scatter_compiled():
    """The same oracle check through the compiled (non-interpret) kernel
    — exercises the real Mosaic/Triton lowering on TPU/GPU."""
    n, nb = 5000, 7
    data, keys, bounds = _kernel_case(n, 3, nb, seed=1)
    out, hist = bucket_scatter(data, keys, bounds, n, n_buckets=nb,
                               interpret=False)
    ref_out, ref_hist = bucket_scatter_ref(data, keys, bounds, nb)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(ref_hist))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))


def _range_case(records, rng, bound_len, n_buckets):
    """Boundaries biased toward record prefixes, zero tails, duplicates."""
    raw = []
    for _ in range(max(n_buckets - 1, 0)):
        if records and rng.random() < 0.5:
            b = records[rng.integers(len(records))][:bound_len]
            if rng.random() < 0.3:
                b = b[:max(1, bound_len // 2)] + b"\x00"
        else:
            b = rng.bytes(bound_len)
        raw.append(b)
    return range_partitioner(sorted(raw))


if hypothesis is not None:
    @settings(max_examples=25, deadline=None)
    @given(data=st.binary(min_size=0, max_size=400),
           rec=st.sampled_from([8, 16]),
           n_buckets=st.integers(1, 5),
           bound_len=st.sampled_from([4, 10]),
           seed=st.integers(0, 2**31 - 1))
    def test_scatter_property(data, rec, n_buckets, bound_len, seed):
        """Random records vs random variable-length boundaries: the
        scattered pieces equal the bytes buckets byte-for-byte (order
        included). Shapes are constrained so interpret-mode traces are
        shared across examples."""
        n = max(1, len(data) // rec)
        blob = (data + bytes(n * rec))[:n * rec]
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        part = _range_case(records, np.random.default_rng(seed),
                           bound_len, n_buckets)
        _assert_scatter_parity(records, blob, rec, part, n_buckets,
                               block_n=32)


def test_scatter_randomized():
    """Non-hypothesis twin of the property test (runs even without the
    hypothesis dev dep), 25 rounds."""
    rng = np.random.default_rng(77)
    for _ in range(25):
        rec = int(rng.choice([8, 16]))
        n = int(rng.integers(1, 60))
        blob = rng.bytes(n * rec)
        records = [blob[i:i + rec] for i in range(0, n * rec, rec)]
        nb = int(rng.integers(1, 6))
        part = _range_case(records, rng, int(rng.choice([4, 10])), nb)
        _assert_scatter_parity(records, blob, rec, part, nb, block_n=32)
