"""Sector storage cloud: placement, replication, failures, ACLs, transport."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import make_cloud
from repro.sector.acl import AclError
from repro.sector.master import HashRing
from repro.sector.replication import ReplicationDaemon
from repro.sector.topology import TERAFLOW_TESTBED, Link
from repro.sector.transport import (llpr, simulate_transfer, tcp_throughput,
                                    udt_throughput)


# ------------------------------- hash ring ----------------------------------

def test_ring_minimal_movement():
    """Consistent hashing: removing 1 of n servers moves ~1/n of keys."""
    ring = HashRing()
    for i in range(10):
        ring.add(f"s{i}")
    keys = [f"file#{i}" for i in range(2000)]
    before = {k: ring.place(k, 1)[0] for k in keys}
    ring.remove("s3")
    after = {k: ring.place(k, 1)[0] for k in keys}
    moved = sum(before[k] != after[k] for k in keys)
    assert moved / len(keys) < 0.25  # ~1/10 expected, generous bound
    # keys that were NOT on s3 must not move
    for k in keys:
        if before[k] != "s3":
            assert after[k] == before[k]


@given(st.lists(st.text(min_size=1, max_size=8), min_size=1, max_size=8,
                unique=True),
       st.text(min_size=1, max_size=16))
@settings(max_examples=50, deadline=None)
def test_ring_placement_properties(servers, key):
    ring = HashRing()
    for s in servers:
        ring.add(s)
    got = ring.place(key, 3)
    assert len(got) == min(3, len(servers))
    assert len(set(got)) == len(got)           # distinct servers
    assert set(got) <= set(servers)
    assert ring.place(key, 3) == got           # deterministic


# ------------------------------ replication ---------------------------------

def test_failure_detection_and_repair(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    data = np.random.default_rng(0).bytes(20_000)
    client.upload("f", data, replication=3)
    daemon = ReplicationDaemon(master, client)
    servers[0].kill()
    servers[2].kill()
    for t in (0, 10, 20, 40):
        for s in servers:
            if s.alive:
                master.heartbeat(s.server_id, t)
    rep = daemon.tick(40.0)
    assert set(rep["failed"]) == {"s0", "s2"}
    assert master.stats()["under_replicated"] == 0
    assert client.download("f") == data


def test_death_event_triggers_repair_without_tick(tmp_path):
    """Event-driven repair: a ``server-died`` bus event (graceful
    deregistration here) makes the daemon restore replication during the
    event delivery itself — no poll tick, no scan_interval wait."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    data = np.random.default_rng(1).bytes(8_000)
    client.upload("f", data, replication=2)
    daemon = ReplicationDaemon(master, client)
    servers[0].kill()
    master.deregister("s0")       # publishes server-died
    assert daemon.event_repairs >= 1
    assert master.stats()["under_replicated"] == 0
    assert client.download("f") == data


def test_heartbeat_timeout_repairs_inside_check(tmp_path):
    """A heartbeat-timeout failure publishes server-died from inside
    ``check_failures``, so the tick's own interval scan finds nothing
    left to do — the event subscription already repaired it."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"x" * 6000, replication=2)
    daemon = ReplicationDaemon(master, client, scan_interval=10.0)
    servers[1].kill()
    for t in (0, 10, 20, 40):
        for s in servers:
            if s.alive:
                master.heartbeat(s.server_id, t)
    rep = daemon.tick(40.0)
    assert rep["failed"] == ["s1"]
    assert daemon.event_repairs >= 1
    assert rep["repaired"] == 0   # interval scan had nothing left
    assert master.stats()["under_replicated"] == 0


def test_polling_daemon_still_repairs_without_events(tmp_path):
    """event_driven=False restores the pure polling daemon (the repair
    latency A/B baseline): repair happens only at the interval scan."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"y" * 6000, replication=2)
    daemon = ReplicationDaemon(master, client, event_driven=False)
    servers[0].kill()
    master.deregister("s0")
    assert daemon.event_repairs == 0
    assert master.stats()["under_replicated"] > 0  # nothing ran yet
    rep = daemon.tick(10.0)
    assert rep["repaired"] >= 1
    assert master.stats()["under_replicated"] == 0


def test_whole_site_loss_keeps_checkpoints_readable(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1024,
                                         n_servers=8)
    data = b"y" * 9000
    client.upload("ckpt", data, replication=3)
    # replicas are placed on distinct sites -> killing one whole site is safe
    for s in servers:
        if s.site == "chicago":
            s.kill()
            master.deregister(s.server_id)
    assert client.download("ckpt") == data


def test_scrubbing_detects_corruption(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"z" * 3000, replication=2)
    daemon = ReplicationDaemon(master, client)
    ck = next(iter(master.chunks.values()))
    sid = next(iter(ck.locations))
    srv = master.servers[sid]
    srv._path(ck.chunk_id).write_bytes(b"CORRUPTED!")
    rep = daemon.verify_all()
    assert rep["bad"] == 1
    assert client.download("f") == b"z" * 3000  # healthy replica survives


def test_scrubbing_requeues_and_repair_restores(tmp_path):
    """verify_all is not just detection: a corrupt replica drops out of
    the chunk's location set and the chunk re-enters the
    under-replicated queue, so the next repair pass restores full
    replication and a re-scrub comes back clean."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"z" * 3000, replication=2)
    daemon = ReplicationDaemon(master, client)
    ck = next(iter(master.chunks.values()))
    sid = next(iter(ck.locations))
    master.servers[sid]._path(ck.chunk_id).write_bytes(b"CORRUPTED!")

    rep = daemon.verify_all()
    assert rep["bad"] == 1
    assert sid not in ck.locations              # bad replica dropped
    assert ck.chunk_id in master.under_replicated  # re-queued for repair

    assert client.run_repair() >= 1
    assert not master.under_replicated
    assert len(ck.locations) >= 2               # replication restored
    rep2 = daemon.verify_all()                  # every replica healthy now
    assert rep2["bad"] == 0
    assert rep2["ok"] == sum(len(c.locations) for c in
                             master.chunks.values())
    assert client.download("f") == b"z" * 3000


def test_scrubbing_with_all_replicas_bad_reports_loss(tmp_path):
    """Every replica corrupt: the chunk stays queued but repair has no
    clean source — verify_all must not mask the loss."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1024)
    client.upload("f", b"z" * 500, replication=2)
    ck = next(iter(master.chunks.values()))
    for sid in list(ck.locations):
        master.servers[sid]._path(ck.chunk_id).write_bytes(b"BAD")
    daemon = ReplicationDaemon(master, client)
    rep = daemon.verify_all()
    assert rep["bad"] == 2
    assert ck.chunk_id in master.under_replicated
    assert client.run_repair() == 0             # nothing clean to copy
    with pytest.raises(IOError):
        client.download("f")


def test_data_loss_reported(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1024,
                                         n_servers=3)
    client.upload("f", b"q" * 2000, replication=2)
    for s in servers:
        s.kill()
    with pytest.raises(IOError):
        client.download("f")


# ---------------------------------- ACL -------------------------------------

def test_acl_semantics(tmp_path):
    master, servers, client = make_cloud(tmp_path)
    client.upload("open-data", b"hello")
    # public CAN read
    from repro.sector import SectorClient
    pub = SectorClient(master, "stranger", "tokyo")
    assert pub.download("open-data") == b"hello"
    # public canNOT write
    with pytest.raises(AclError):
        pub.upload("evil", b"x")
    # community member without write grant canNOT write
    master.acl.add_member("bob")
    bob = SectorClient(master, "bob", "tokyo")
    with pytest.raises(AclError):
        bob.upload("bobs", b"x")
    master.acl.grant_write("bob")
    bob.upload("bobs", b"x")  # now ok
    # restricted files are community-only
    master.acl.read_restricted.add("open-data")
    with pytest.raises(AclError):
        pub.download("open-data")
    assert bob.download("open-data") == b"hello"


# ------------------------------- topology -----------------------------------

def test_unconfigured_site_pair_falls_back_to_default_wan():
    """A site pair with no configured link must not crash placement:
    link() returns the documented default WAN path, and it is worse than
    every provisioned testbed route so locality steering still prefers
    configured links."""
    wan = TERAFLOW_TESTBED.link("chicago", "atlantis")
    assert wan == TERAFLOW_TESTBED.default_wan
    assert TERAFLOW_TESTBED.link("atlantis", "mu") == wan  # both unknown
    assert TERAFLOW_TESTBED.link("a", "a") == TERAFLOW_TESTBED.local
    for (a, b), real in TERAFLOW_TESTBED.links.items():
        assert wan.bandwidth_bps < real.bandwidth_bps
        assert TERAFLOW_TESTBED.distance(a, b) <= wan.rtt_s
    t = simulate_transfer(1 << 20, wan, "udt")
    assert t.seconds > 0


def test_server_at_unknown_site_joins_and_serves(tmp_path):
    """End-to-end: a chunk server joining from a site the testbed config
    predates can receive uploads and serve reads over the default WAN
    link instead of raising KeyError during placement."""
    from repro.sector import ChunkServer

    master, servers, client = make_cloud(tmp_path, chunk_size=1024,
                                         n_servers=3)
    master.register(ChunkServer("edge", "atlantis", tmp_path))
    data = b"w" * 5000
    client.upload("f", data, replication=4)  # must reach all 4, edge too
    assert any("edge" in ck.locations for ck in master.chunks.values())
    assert client.download("f") == data


# ------------------------------- transport ----------------------------------

def test_udt_beats_tcp_on_long_fat_links():
    wan = TERAFLOW_TESTBED.link("chicago", "tokyo")
    assert udt_throughput(wan) > 10 * tcp_throughput(wan)


def test_llpr_in_paper_band():
    """Table 1: UDT LLPR between 0.5 and 1.0 on every testbed route."""
    lan = TERAFLOW_TESTBED.local
    nbytes = 10 * 1024**3
    for (a, b) in [("greenbelt", "daejeon"), ("chicago", "pasadena"),
                   ("chicago", "greenbelt"), ("chicago", "tokyo"),
                   ("tokyo", "pasadena"), ("tokyo", "chicago")]:
        wan = TERAFLOW_TESTBED.link(a, b)
        r_udt = llpr(nbytes, wan, lan, "udt")
        r_tcp = llpr(nbytes, wan, lan, "tcp")
        assert 0.5 <= r_udt <= 1.0, (a, b, r_udt)
        assert r_tcp < 0.2, (a, b, r_tcp)      # TCP collapses on the WAN
        assert r_udt > r_tcp


@given(st.floats(1e-7, 1e-3), st.floats(0.001, 0.3))
@settings(max_examples=40, deadline=None)
def test_transport_monotonicity(loss, rtt):
    """More loss or RTT never increases throughput; transfers conserve."""
    link = Link(10e9, rtt, loss)
    worse = Link(10e9, rtt, loss * 2)
    assert udt_throughput(worse) <= udt_throughput(link) + 1
    assert tcp_throughput(worse) <= tcp_throughput(link) + 1
    t = simulate_transfer(1 << 20, link, "udt")
    assert t.seconds > 0 and t.throughput_bps > 0
