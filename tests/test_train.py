"""Optimizer math, checkpoint atomicity, resume determinism, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_cloud
from repro.configs import ARCHS
from repro.data import DataPipeline, SectorTokenDataset, write_synthetic_corpus
from repro.parallel.sharding import ParallelConfig
from repro.train import SectorCheckpointer, Trainer, TrainerConfig, optim
from repro.train.checkpoint import deserialize, serialize


def test_adamw_matches_reference():
    """One AdamW step vs hand-computed update on a toy param."""
    ocfg = optim.AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                             weight_decay=0.0, grad_clip=0.0)
    params = {"layer": {"w": jnp.ones((3,), jnp.float32)}}
    grads = {"layer": {"w": jnp.asarray([0.5, -0.5, 1.0])}}
    state = optim.init_state(params, ocfg)
    new_p, new_s, _ = optim.apply_updates(params, grads, state, ocfg,
                                          lambda s: 0.1)
    g = np.asarray([0.5, -0.5, 1.0])
    m = 0.1 * g
    v = 0.001 * g**2
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    want = 1.0 - 0.1 * upd
    np.testing.assert_allclose(np.asarray(new_p["layer"]["w"]), want,
                               rtol=1e-5)


def test_weight_decay_skips_norms():
    ocfg = optim.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0)
    params = {"norm": {"scale": jnp.ones((3,))}, "mlp": {"wi": jnp.ones((3,))}}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = optim.init_state(params, ocfg)
    new_p, _, _ = optim.apply_updates(params, grads, state, ocfg,
                                      lambda s: 0.1)
    assert float(jnp.abs(new_p["norm"]["scale"] - 1.0).max()) < 1e-6
    assert float(jnp.abs(new_p["mlp"]["wi"] - 1.0).max()) > 1e-3


def test_grad_clip_effective():
    ocfg = optim.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = optim.init_state(params, ocfg)
    _, _, metrics = optim.apply_updates(params, grads, state, ocfg,
                                        lambda s: 1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_serialize_roundtrip_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 3,
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    payload, manifest = serialize(tree)
    back = deserialize(payload, manifest, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        assert bool((x == y).all())


def test_checkpoint_atomicity_corrupt_payload(tmp_path):
    """A corrupted newest checkpoint must fall back to the previous one."""
    master, servers, client = make_cloud(tmp_path, chunk_size=2048)
    ck = SectorCheckpointer(client, "t", replication=2)
    tree = {"params": {"w": jnp.ones((8,))}, "opt": {"m": jnp.zeros((8,))}}
    ck.save(1, {"params": tree["params"], "opt": tree["opt"]})
    ck.save(2, {"params": jax.tree.map(lambda x: x * 2, tree["params"]),
                "opt": tree["opt"]})
    # corrupt step 2's payload on every replica
    fm = master.files[ck._bin(2)]
    for cid in fm.chunk_ids:
        for sid in master.chunks[cid].locations:
            master.servers[sid]._path(cid).write_bytes(b"garbage")
    got = ck.restore_latest({"params": tree["params"], "opt": tree["opt"]})
    assert got is not None and got["step"] == 1
    assert float(got["params"]["w"][0]) == 1.0


def _mk_trainer(tmp_path, steps=8, seed=0, tag="tr"):
    master, servers, client = make_cloud(tmp_path, chunk_size=64 * 1024)
    cfg = ARCHS["qwen2.5-3b"].reduced()
    write_synthetic_corpus(client, "c", 300_000, cfg.vocab_size, seed=1)
    ds = SectorTokenDataset(master, client, "c", seq_len=32)
    pcfg = ParallelConfig(mesh=None, remat="none")
    pipe = DataPipeline(ds, batch=4, pcfg=pcfg)
    ck = SectorCheckpointer(client, tag)
    tr = Trainer(cfg, pcfg,
                 TrainerConfig(steps=steps, ckpt_every=4, log_every=2,
                               lr=1e-3, seed=seed),
                 pipe, ck)
    return tr, master, client


def test_resume_is_deterministic(tmp_path):
    """run(8) == run(4) + crash + restore + run(4): identical final loss."""
    tr1, *_ = _mk_trainer(tmp_path / "a", steps=8)
    h1 = tr1.run(8)

    tr2, master2, client2 = _mk_trainer(tmp_path / "b", steps=8)
    tr2.run(4)  # checkpoints at step 4 (+cursor)
    ck = SectorCheckpointer(client2, "tr")
    ds = SectorTokenDataset(master2, client2, "c", seq_len=32)
    pipe = DataPipeline(ds, batch=4,
                        pcfg=ParallelConfig(mesh=None, remat="none"))
    tr3 = Trainer(tr2.cfg, tr2.pcfg,
                  TrainerConfig(steps=8, ckpt_every=4, log_every=2, lr=1e-3),
                  pipe, ck)
    assert tr3.step_idx == 4  # restored
    h3 = tr3.run(4)
    l1 = [h for h in h1 if h["step"] == 8][0]["loss"]
    l3 = [h for h in h3 if h["step"] == 8][0]["loss"]
    assert abs(l1 - l3) < 1e-3


def test_loss_decreases(tmp_path):
    tr, *_ = _mk_trainer(tmp_path, steps=24)
    hist = tr.run(24)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3
