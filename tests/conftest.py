import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see exactly 1 device. Multi-device behaviour is tested in
# subprocesses (tests/test_spmd_subprocess.py) and by the dry-run driver.


def pytest_collection_modifyitems(config, items):
    # requires_accelerator: compiled (non-interpret) Pallas paths need a
    # real TPU/GPU backend; on the CPU CI they auto-skip instead of
    # failing inside the Mosaic/Triton lowering
    if jax.default_backend() in ("tpu", "gpu"):
        return
    skip = pytest.mark.skip(reason="needs a TPU/GPU backend "
                                   f"(have {jax.default_backend()})")
    for item in items:
        if "requires_accelerator" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def make_cloud(tmp_path, chunk_size=64 * 1024, n_servers=6, user="alice"):
    from repro.sector import ChunkServer, SectorClient, SectorMaster

    master = SectorMaster(chunk_size=chunk_size)
    sites = master.topology.sites
    servers = [ChunkServer(f"s{i}", sites[i % len(sites)], tmp_path)
               for i in range(n_servers)]
    for s in servers:
        master.register(s)
    master.acl.add_member(user)
    master.acl.grant_write(user)
    client = SectorClient(master, user, "chicago")
    return master, servers, client
