"""Multi-device behaviours, each in a subprocess with forced host devices.

(The main pytest process must keep exactly 1 device — see conftest.)
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.utils import jax_compat

ROOT = os.path.join(os.path.dirname(__file__), "..")

# version-compat preamble available to every subprocess snippet:
# mk_mesh(shape, axes) and use_mesh(mesh) work on jax 0.4.x and >= 0.5
_PREAMBLE = """
import jax
from repro.launch.mesh import make_mesh_compat as mk_mesh
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
def use_mesh(mesh):
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
"""


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", _PREAMBLE + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_sort_correct():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.spmd import distributed_sort
        from repro.launch.mesh import make_flat_mesh
        mesh = make_flat_mesh()
        keys = jax.random.randint(jax.random.PRNGKey(0), (1<<13,), 0, 1<<30,
                                  dtype=jnp.uint32)
        outp, valid = distributed_sort(keys, mesh)
        per = np.asarray(outp).reshape(8, -1)
        got = np.concatenate([p[p != 0xFFFFFFFF] for p in per])
        ref = np.sort(np.asarray(keys))
        assert np.array_equal(got, ref), 'sort mismatch'
        print('OK')
    """)
    assert "OK" in out


def test_fused_scatter_round_multidevice_matches_host():
    """The engine's fused shuffle round through shard_map + all_to_all on
    an 8-device mesh: regrouped partitions, counts and per-slot
    histograms must match a per-record host reference exactly — the
    ordering contract (bucket-ascending within a worker, slot-major then
    input order within a bucket) survives the real exchange."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.records import RecordBatch, StackedBatch
        from repro.core.shuffle import hash_partitioner
        from repro.core.spmd import fused_scatter_round
        from repro.launch.mesh import make_flat_mesh
        mesh = make_flat_mesh()                 # 8 devices on axis 'data'
        rec, n, W, S = 12, 11, 16, 24           # S slots, W workers, n buckets
        rng = np.random.default_rng(0)
        loads = rng.integers(0, 30, size=S)
        slots = [[rng.integers(0, 256, rec, dtype=np.uint8).tobytes()
                  for _ in range(k)] for k in loads]
        batches = [RecordBatch.from_records(s) if s
                   else RecordBatch.empty(rec) for s in slots]
        stacked = StackedBatch.pack(batches, pad_block=8)
        part = hash_partitioner(key_bytes=8)
        key_spec, bounds = part.scatter_spec(RecordBatch.empty(rec), n)
        parts, counts, hist = fused_scatter_round(
            stacked.data, jnp.asarray(stacked.n_valid, jnp.int32), bounds,
            key_spec=key_spec, n_buckets=n, n_workers=W, mesh=mesh)
        # host reference: bucket append order = slot-major, input order
        buckets = [[] for _ in range(n)]
        for s in slots:
            for r in s:
                buckets[part(r, n)].append(r)
        want = [b'' for _ in range(W)]
        wc = [0] * W
        for b in range(n):
            want[b % W] += b''.join(buckets[b])
            wc[b % W] += len(buckets[b])
        counts = np.asarray(counts)
        assert counts.tolist() == wc, (counts.tolist(), wc)
        got = np.asarray(parts)
        for w in range(W):
            assert got[w, :wc[w]].tobytes() == want[w], f'worker {w}'
        hist = np.asarray(hist)
        for s in range(S):
            ref = [part(r, n) for r in slots[s]]
            assert hist[s].tolist() == [ref.count(b) for b in range(n)]
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.skipif(not jax_compat.PARTIAL_MANUAL_ROBUST,
                    reason="podwise psum-over-pod inside a partial-manual "
                           "region is fatal in XLA for jax 0.4.x shard_map")
def test_podwise_mode_matches_pjit():
    """Manual-pod train step == plain pjit step (no compression)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import model
        from repro.parallel.sharding import ParallelConfig
        from repro.train import optim
        from repro.train.step import make_train_step
        mesh = mk_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg = ARCHS['qwen2.5-3b'].reduced().replace(
            param_dtype='float32', compute_dtype='float32')
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        ocfg = optim.AdamWConfig(lr=1e-2)
        opt = optim.init_state(params, ocfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {'inputs': toks, 'labels': toks}
        lr = optim.warmup_cosine(1e-2, 2, 10)
        outs = {}
        for mode in ('pjit', 'podwise'):
            pcfg = ParallelConfig(mesh=mesh, multi_pod=True, mode=mode,
                                  remat='none')
            step = make_train_step(cfg, pcfg, ocfg, lr)
            with use_mesh(mesh):
                p2, o2, m = jax.jit(step)(params, opt, batch)
            outs[mode] = (jax.device_get(p2), float(m['loss']))
        a, b = outs['pjit'], outs['podwise']
        assert abs(a[1] - b[1]) < 1e-5, (a[1], b[1])
        for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       rtol=2e-4, atol=2e-5)
        print('OK')
    """)
    assert "OK" in out


def test_compressed_cross_pod_close_to_exact():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import collectives
        from jax.sharding import PartitionSpec as P
        mesh = mk_mesh((4,), ('pod',))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
        ef = jnp.zeros((4, 256))
        def body(gl, efl):
            out, ef2 = collectives.cross_pod_mean(
                {'w': gl[0]}, compress='int8_ef', ef_state={'w': efl[0]})
            return out['w'][None], ef2['w'][None]
        fn = shard_map(body, mesh=mesh, in_specs=(P('pod'), P('pod')),
                       out_specs=(P('pod'), P('pod')))
        red, ef2 = fn(g, ef)
        exact = jnp.mean(g, axis=0)
        err = float(jnp.abs(red[0] - exact).max())
        amax = float(jnp.abs(g).max())
        assert err < amax / 64, (err, amax)   # int8 quantisation band
        # error feedback carries the residual
        assert float(jnp.abs(ef2).max()) > 0
        print('OK')
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """Same seed/batch: 4-device FSDP/TP step == 1-device step."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import model
        from repro.parallel.sharding import ParallelConfig
        from repro.train import optim
        from repro.train.step import make_train_step
        cfg = ARCHS['qwen2.5-3b'].reduced().replace(
            param_dtype='float32', compute_dtype='float32')
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        ocfg = optim.AdamWConfig(lr=1e-2)
        opt = optim.init_state(params, ocfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {'inputs': toks, 'labels': toks}
        lr = optim.warmup_cosine(1e-2, 2, 10)
        import numpy as _np
        n = jax.device_count()
        if n == 1:
            mesh = mk_mesh((1, 1), ('data', 'model'))
        else:
            mesh = mk_mesh((2, 2), ('data', 'model'))
        pcfg = ParallelConfig(mesh=mesh, remat='none')
        step = make_train_step(cfg, pcfg, ocfg, lr)
        with use_mesh(mesh):
            p2, o2, m = jax.jit(step)(params, opt, batch)
        print('LOSS', float(m['loss']))
    """
    out1 = run_py(code, devices=1)
    out4 = run_py(code, devices=4)
    l1 = float(out1.split("LOSS")[1])
    l4 = float(out4.split("LOSS")[1])
    assert abs(l1 - l4) < 1e-4, (l1, l4)
