"""Sphere Streams: windowed multi-file dataflow over the Sector event bus.

Covers the stream contract: window policies (tumbling / sliding /
count-based) over event-driven file arrivals, delta planning (a window
advance plans ONLY the new file's chunks — asserted on the
``SphereReport.planned_tasks`` / ``reused_tasks`` counters), chunk
decode-once across windows with exact retirement of expired files,
membership-event invalidation, and the acceptance workload: a
sliding-window warm-started streaming k-means over 8 arriving files with
``udf_traces == 1`` across the entire stream."""
import numpy as np
import pytest

from conftest import make_cloud
from repro.core import (SphereEngine, SphereJob, SphereStage, SphereStream,
                        WindowPolicy)
from repro.core.kmeans import StreamingKMeans, encode_points
from repro.sector import ChunkServer

REC = 100


def _upload(client, name, n, seed=0, replication=2):
    rng = np.random.default_rng(seed)
    data = rng.bytes(n * REC)
    client.upload(name, data, replication=replication)
    return data


def _identity_job(backend, input_file="s/"):
    return SphereJob("id", input_file,
                     [SphereStage("id", lambda rs: list(rs),
                                  batch_udf=lambda b: b, pad_value=0xFF)],
                     record_size=REC, backend=backend)


# ----------------------------- window policies -------------------------------

def test_window_policy_shapes():
    files = [f"f{i}" for i in range(8)]

    tum = WindowPolicy.tumbling(3)
    assert [n for n in range(1, 9) if tum.fires(n)] == [3, 6]
    assert tum.window(files[:6]) == ("f3", "f4", "f5")

    sli = WindowPolicy.sliding(4)
    assert [n for n in range(1, 9) if sli.fires(n)] == [4, 5, 6, 7, 8]
    assert sli.window(files[:5]) == ("f1", "f2", "f3", "f4")

    sli2 = WindowPolicy.sliding(4, step=2)
    assert [n for n in range(1, 9) if sli2.fires(n)] == [4, 6, 8]

    cnt = WindowPolicy.count(2)
    assert [n for n in range(1, 6) if cnt.fires(n)] == [2, 4]
    assert cnt.window(files[:4]) == tuple(files[:4])  # landmark: all so far


def test_window_policy_validates():
    with pytest.raises(ValueError, match="kind"):
        WindowPolicy("hopping", 2, 1)
    with pytest.raises(ValueError, match="size"):
        WindowPolicy.sliding(0)
    with pytest.raises(ValueError, match="step"):
        WindowPolicy("sliding", 2, 0)


# --------------------------- window formation --------------------------------

@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_stream_windows_form_on_matching_uploads(tmp_path, backend):
    """file-created events matching the prefix advance the window; other
    uploads are invisible.  The window callback fires synchronously
    during the completing upload."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend=backend)
    seen = []
    stream.on_window(lambda s, idx, files: seen.append((idx, files)))

    _upload(client, "s/a", n=20)
    assert stream.windows_formed == 0 and seen == []
    _upload(client, "other/x", n=10)       # prefix mismatch: ignored
    _upload(client, "s/b", n=20)
    _upload(client, "s/c", n=20)
    assert stream._n_arrivals == 3
    assert stream.arrivals == ["s/b", "s/c"]  # trailing window extent only
    assert seen == [(0, ("s/a", "s/b")), (1, ("s/b", "s/c"))]
    assert stream.window_files == ("s/b", "s/c")


def test_stream_tumbling_and_count_windows(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    tum = eng.stream("s/", window=WindowPolicy.tumbling(2),
                     record_size=REC, backend="array")
    cnt = eng.stream("s/", window=WindowPolicy.count(2),
                     record_size=REC, backend="array")
    tum_seen, cnt_seen = [], []
    tum.on_window(lambda s, i, f: tum_seen.append(f))
    cnt.on_window(lambda s, i, f: cnt_seen.append(f))
    for name in ("s/a", "s/b", "s/c", "s/d"):
        _upload(client, name, n=10)
    assert tum_seen == [("s/a", "s/b"), ("s/c", "s/d")]
    assert cnt_seen == [("s/a", "s/b"), ("s/a", "s/b", "s/c", "s/d")]


def test_stream_run_before_any_window_raises(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    stream = SphereEngine(master, client).stream(
        "s/", window=WindowPolicy.sliding(2), record_size=REC,
        backend="array")
    with pytest.raises(RuntimeError, match="no window"):
        stream.run(_identity_job("array"))


# ----------------------------- delta planning --------------------------------

@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_stream_plans_only_the_delta(tmp_path, backend):
    """Window advance plans the new file's chunks ONLY: surviving files
    replay their cached group plans (reused_tasks), and the Sector
    master is looked up exactly once per file, ever."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    calls = []
    orig = master.lookup
    master.lookup = lambda *a, **k: calls.append(a) or orig(*a, **k)

    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend=backend)
    data_a = _upload(client, "s/a", n=20)   # 2 chunks
    data_b = _upload(client, "s/b", n=30)   # 3 chunks
    outs, rep = stream.run(_identity_job(backend))
    assert (rep.planned_tasks, rep.reused_tasks) == (5, 0)
    assert sorted(b"".join(outs)) == sorted(data_a + data_b)

    # same window again: everything replays, nothing re-plans
    _, rep2 = stream.run(_identity_job(backend))
    assert (rep2.planned_tasks, rep2.reused_tasks) == (0, 5)

    # new file: window (b, c) — only c's 4 chunks get planned
    data_c = _upload(client, "s/c", n=40)
    outs3, rep3 = stream.run(_identity_job(backend))
    assert (rep3.planned_tasks, rep3.reused_tasks) == (4, 3)
    assert sorted(b"".join(outs3)) == sorted(data_b + data_c)
    # the stream's metadata lookups (2-arg form; the client's per-read
    # lookups carry a site argument): exactly one per file, ever
    meta = [a[0] for a in calls if len(a) == 2]
    assert sorted(meta) == ["s/a", "s/b", "s/c"]


def test_stream_decodes_chunks_once_and_retires_expired(tmp_path):
    """Across the whole stream each chunk pays the Sector read + decode
    exactly once while it is windowed; expired files are evicted without
    touching the surviving files' cached (device-resident) chunks."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    reads = []
    orig = client.read_chunk
    client.read_chunk = lambda *a, **k: reads.append(a[0]) or orig(*a, **k)

    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend="array")
    _upload(client, "s/a", n=20)
    _upload(client, "s/b", n=30)
    stream.run(_identity_job("array"))
    assert len(reads) == 5
    stream.run(_identity_job("array"))
    assert len(reads) == 5                      # all cached

    b_chunks = {t.key for t in stream._file_tasks["s/b"]}
    b_cached = {k: stream.executor._chunk_cache[k] for k in b_chunks}
    _upload(client, "s/c", n=40)                # a expires, c enters
    assert set(stream.executor._chunk_cache) == b_chunks  # a evicted
    stream.run(_identity_job("array"))
    assert len(reads) == 5 + 4                  # only c's chunks read
    for k, batch in b_cached.items():
        assert stream.executor._chunk_cache[k] is batch  # untouched


def test_stream_matches_rebuild_per_window(tmp_path):
    """The delta-planned stream produces the same outputs and the same
    scheduling counters as a cold rebuild over the same window files —
    caching changes cost, never results."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend="array")
    _upload(client, "s/seed", n=20)
    for i, n in enumerate((20, 30, 40)):
        _upload(client, f"s/{i}", n=n)
        outs, rep = stream.run(_identity_job("array"))
        rebuild = SphereStream(eng, files=stream.window_files,
                               record_size=REC, backend="array")
        want_outs, want_rep = rebuild.run(_identity_job("array",
                                                        input_file=""))
        rebuild.close()
        assert outs == want_outs
        assert rep.stage_seconds[-1] == pytest.approx(
            want_rep.stage_seconds[-1])
        assert (rep.bytes_local, rep.bytes_moved) == \
            (want_rep.bytes_local, want_rep.bytes_moved)


# ------------------------------- chaining ------------------------------------

def test_stream_chained_state_is_per_window(tmp_path):
    """input='chained' consumes the previous job's partitions within a
    window; a window advance drops them (they mix expired data)."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend="array")
    a = _upload(client, "s/a", n=20)
    b = _upload(client, "s/b", n=20)
    stream.run(_identity_job("array"))
    outs, _ = stream.run(_identity_job("array"), input="chained")
    assert sorted(b"".join(outs)) == sorted(a + b)

    _upload(client, "s/c", n=20)    # window advances -> chained state gone
    with pytest.raises(RuntimeError, match="chain"):
        stream.run(_identity_job("array"), input="chained")


def test_stream_validates_jobs(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    stream = SphereEngine(master, client).stream(
        "s/", window=WindowPolicy.sliding(1), record_size=REC,
        backend="array")
    _upload(client, "s/a", n=10)
    with pytest.raises(ValueError, match="backend"):
        stream.run(SphereJob("j", "s/", [SphereStage("id", lambda rs: rs)],
                             record_size=REC, backend="bytes"))
    with pytest.raises(ValueError, match="stream"):
        stream.run(_identity_job("array", input_file="t/"))


# --------------------------- membership events -------------------------------

def test_stream_invalidates_on_membership_change(tmp_path):
    """A server joining (or dying) drops every cached lookup/plan/chunk:
    the next run re-plans the whole window against the new cluster and
    still produces correct output."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(2),
                        record_size=REC, backend="array")
    a = _upload(client, "s/a", n=20, replication=3)
    b = _upload(client, "s/b", n=30, replication=3)
    stream.run(_identity_job("array"))
    assert len(stream._plan) == 2

    master.register(ChunkServer("late", "daejeon", tmp_path))
    assert len(stream._plan) == 0 and not stream._file_tasks
    outs, rep = stream.run(_identity_job("array"))
    assert (rep.planned_tasks, rep.reused_tasks) == (5, 0)  # full re-plan
    assert "late" in stream.workers
    assert sorted(b"".join(outs)) == sorted(a + b)

    servers[0].kill()
    master.deregister(servers[0].server_id)
    outs2, _ = stream.run(_identity_job("array"))
    assert servers[0].server_id not in stream.workers
    assert sorted(b"".join(outs2)) == sorted(a + b)


def test_last_worker_death_defers_bind_error_to_next_run(tmp_path):
    """Losing the LAST live worker must not blow up the master's failure
    sweep from inside the subscriber callback — the 'no live workers'
    error surfaces at the next run() instead, and a later join heals
    the stream."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000,
                                         n_servers=2)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(1),
                        record_size=REC, backend="array")
    _upload(client, "s/a", n=10, replication=2)
    stream.run(_identity_job("array"))

    for s in servers:
        s.kill()
        master.deregister(s.server_id)   # must not raise, even for the last
    with pytest.raises(RuntimeError, match="no live workers"):
        stream.run(_identity_job("array"))

    servers[0].revive()
    master.register(servers[0], now=1.0)  # join event re-opens the stream
    data = _upload(client, "s/b", n=10, replication=1)  # fresh window file
    outs, _ = stream.run(_identity_job("array"))
    assert sorted(b"".join(outs)) == sorted(data)


def test_closed_stream_stops_reacting(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.sliding(1),
                        record_size=REC, backend="array")
    data = _upload(client, "s/a", n=10)
    outs, _ = stream.run(_identity_job("array"))
    stream.close()
    _upload(client, "s/b", n=10)                      # not observed
    assert stream.arrivals == ["s/a"]
    assert len(stream._plan) == 1                     # caches survive close
    assert sorted(b"".join(outs)) == sorted(data)


# --------------------------- streaming k-means -------------------------------

def _np_kmeans_windows(window_pts, k, iters, seed):
    """Numpy mirror of StreamingKMeans: warm-started window chain."""
    dim = window_pts[0].shape[1]
    c = np.random.default_rng(seed).normal(size=(k, dim)).astype(np.float32)
    models = []
    for pts in window_pts:
        for _ in range(iters):
            d2 = ((pts[:, None, :] - c[None]) ** 2).sum(-1)
            a = d2.argmin(1)
            sums = np.zeros((k, dim))
            counts = np.zeros(k)
            np.add.at(sums, a, pts)
            np.add.at(counts, a, 1)
            nz = counts > 0
            c[nz] = (sums[nz] / counts[nz, None]).astype(np.float32)
        models.append(c.copy())
    return models


def test_streaming_kmeans_acceptance(tmp_path):
    """The acceptance workload: >= 8 arriving files through a
    sliding-window warm-started streaming k-means.  Every stage traces
    exactly once across ALL windows and iterations, per-window planning
    covers only the delta chunks, and each window's centroids match the
    numpy warm-start chain."""
    DIM, K, ITERS, WIN, FILES = 4, 3, 3, 4, 8
    # chunk = 4096 B = 256 records of 16 B; every file spans 3 chunks
    master, servers, client = make_cloud(tmp_path, chunk_size=4096)
    eng = SphereEngine(master, client)
    stream = eng.stream("angle/w", window=WindowPolicy.sliding(WIN),
                        record_size=4 * DIM, backend="array")
    skm = StreamingKMeans(stream, DIM, K, iters=ITERS)

    rng = np.random.default_rng(7)
    centers = rng.normal(size=(K, DIM)) * 4
    file_pts, models, deltas = [], [], []

    def on_window(s, idx, files):
        before = (skm.report.planned_tasks, skm.report.reused_tasks)
        models.append(skm.fit_window())
        after = (skm.report.planned_tasks, skm.report.reused_tasks)
        deltas.append((after[0] - before[0], after[1] - before[1]))

    stream.on_window(on_window)
    for i in range(FILES):
        pts = np.concatenate(
            [rng.normal(c, 0.3, size=(200, DIM)) for c in centers]
        ).astype(np.float32)
        file_pts.append(pts)
        client.upload(f"angle/w{i:03d}", encode_points(pts), replication=2)

    n_windows = FILES - WIN + 1
    assert stream.windows_formed == n_windows == len(models)
    chunks_per_file = -(-200 * K * 4 * DIM // 4096)  # ceil
    assert chunks_per_file == 3

    # trace-once across the ENTIRE stream (all windows, all iterations)
    assert skm.report.udf_traces == {"assign": 1, "fold": 1}
    assert skm.stages[0]._traced.traces == 1
    assert skm.stages[1]._traced.traces == 1

    # delta planning: window 0 plans all 4 files; every later window
    # plans exactly the one new file's chunks, replaying the rest —
    # iterations after the first within a window reuse everything
    w = WIN * chunks_per_file
    assert deltas[0] == (w, (ITERS - 1) * w)
    for d in deltas[1:]:
        assert d == (chunks_per_file, (ITERS - 1) * w + (WIN - 1)
                     * chunks_per_file)

    # model correctness: the warm-started chain equals the numpy mirror
    window_pts = [np.concatenate(file_pts[i:i + WIN])
                  for i in range(n_windows)]
    want = _np_kmeans_windows(window_pts, K, ITERS, seed=0)
    for got, ref in zip(models, want):
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["bytes", "array"])
def test_streaming_kmeans_backends_agree(tmp_path, backend):
    """Both record backends converge the streaming chain to the true
    cluster centers."""
    DIM, K = 2, 2
    master, servers, client = make_cloud(tmp_path, chunk_size=4096)
    eng = SphereEngine(master, client)
    stream = eng.stream("w/", window=WindowPolicy.sliding(2),
                        record_size=4 * DIM if backend == "array" else 0,
                        backend=backend)
    skm = StreamingKMeans(stream, DIM, K, iters=5)
    stream.on_window(lambda s, i, f: skm.fit_window())

    rng = np.random.default_rng(0)
    true_c = np.array([[0, 0], [8, 8]], np.float32)
    for i in range(4):
        pts = np.concatenate([rng.normal(c, 0.3, (128, DIM))
                              for c in true_c]).astype(np.float32)
        client.upload(f"w/{i}", encode_points(pts), replication=2)

    assert skm.windows_fit == 3
    cents = skm.centroids[np.argsort(skm.centroids[:, 0])]
    assert np.abs(cents - true_c).max() < 0.5


# ----------------------------- timed windows ---------------------------------

def test_timed_policy_validates():
    with pytest.raises(ValueError, match="span_s"):
        WindowPolicy.timed(0.0)
    with pytest.raises(ValueError, match="grace_s"):
        WindowPolicy.timed(10.0, grace_s=-1.0)
    assert WindowPolicy.timed(10.0).fires(99) is False  # watermark-driven


def test_timed_windows_bucket_by_event_time(tmp_path):
    """Files land in event-time buckets; a bucket fires when the
    watermark passes its end, and empty spans form no window."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.timed(10.0),
                        record_size=REC)
    seen = []
    stream.on_window(lambda s, idx, files: seen.append((idx, files)))

    _upload_at(client, "s/a", at=5.0)        # bucket 0
    assert seen == []                        # watermark 5 < bucket end 10
    _upload_at(client, "s/b", at=20.0)       # bucket 2; watermark 20
    # bucket 0 fires with [a]; EMPTY bucket 1 is skipped, not a window
    assert seen == [(0, ("s/a",))]
    _upload_at(client, "s/c", at=35.0)       # bucket 3; watermark 35
    assert seen == [(0, ("s/a",)), (1, ("s/b",))]
    assert stream.windows_formed == 2
    stream.close()


def test_timed_grace_saves_in_grace_straggler(tmp_path):
    """The watermark trails the max event time by ``grace_s``, so a
    straggler landing inside the grace period still joins its bucket."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.timed(10.0, grace_s=5.0),
                        record_size=REC)
    seen = []
    stream.on_window(lambda s, idx, files: seen.append(files))

    _upload_at(client, "s/a", at=12.0)       # bucket 1; watermark 7
    _upload_at(client, "s/late", at=9.0)     # bucket 0 — saved by grace
    assert seen == [] and stream.late_dropped == 0
    _upload_at(client, "s/b", at=16.0)       # watermark 11: bucket 0 fires
    assert seen == [("s/late",)]
    stream.close()


def test_timed_late_file_dropped_and_counted(tmp_path):
    """A file whose bucket already fired is dropped loudly: counted in
    ``late_dropped``, never a member of any window."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.timed(10.0),
                        record_size=REC)
    seen = []
    stream.on_window(lambda s, idx, files: seen.append(files))

    _upload_at(client, "s/a", at=5.0)
    _upload_at(client, "s/b", at=25.0)       # fires bucket 0
    assert seen == [("s/a",)]
    _upload_at(client, "s/tardy", at=3.0)    # bucket 0 already gone
    assert stream.late_dropped == 1
    stream.advance_watermark(100.0)          # flush everything pending
    assert all("s/tardy" not in files for files in seen)
    stream.close()


def test_advance_watermark_flushes_and_validates(tmp_path):
    """``advance_watermark`` drives the watermark without a new arrival
    (end-of-stream flush); count-based streams reject it."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.timed(10.0, grace_s=5.0),
                        record_size=REC)
    seen = []
    stream.on_window(lambda s, idx, files: seen.append(files))
    _upload_at(client, "s/a", at=2.0)
    _upload_at(client, "s/b", at=4.0)
    assert seen == []
    stream.advance_watermark(50.0)
    assert seen == [("s/a", "s/b")]
    # moving time backwards is a no-op, not a rewind
    stream.advance_watermark(1.0)
    assert stream.watermark == pytest.approx(45.0)
    stream.close()

    counted = eng.stream("s/", window=WindowPolicy.sliding(2),
                         record_size=REC)
    with pytest.raises(ValueError, match="timed"):
        counted.advance_watermark(10.0)
    counted.close()


def test_timed_window_runs_jobs(tmp_path):
    """A timed window is a full SphereStream window: jobs run against
    exactly the files the watermark admitted."""
    master, servers, client = make_cloud(tmp_path, chunk_size=1000)
    eng = SphereEngine(master, client)
    stream = eng.stream("s/", window=WindowPolicy.timed(10.0),
                        record_size=REC, backend="bytes")
    data = {}
    data["s/a"] = _upload_at(client, "s/a", at=1.0)
    data["s/b"] = _upload_at(client, "s/b", at=8.0)
    stream.advance_watermark(30.0)
    assert stream.window_files == ("s/a", "s/b")
    out, rep = stream.run(_identity_job("bytes"))
    assert b"".join(out) and sum(len(b) for b in out) == \
        sum(len(d) for d in data.values())
    stream.close()


def _upload_at(client, name, at, n=20, seed=None):
    rng = np.random.default_rng(abs(hash(name)) % 2**32 if seed is None
                                else seed)
    data = rng.bytes(n * REC)
    client.upload(name, data, replication=2, at=at)
    return data
