"""Attention implementation equivalences (scan / triangular / windowed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    init_cache, update_cache)


def ref_attention(q, k, v, causal, window):
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q, kr) / np.sqrt(D)
    tpos = jnp.arange(T)[:, None]
    spos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= spos <= tpos
    if window:
        mask &= tpos - spos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhts,bshd->bthd", p, vr)


@pytest.mark.parametrize("impl", ["scan", "triangular"])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
@pytest.mark.parametrize("T,H,K", [(32, 4, 2), (48, 4, 4), (32, 4, 1)])
def test_chunked_vs_ref(impl, causal, window, T, H, K):
    if impl == "triangular" and not causal:
        pytest.skip("triangular is causal-only")
    B, D = 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, K, D))
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=16, kv_chunk=16, impl=impl)
    ref = ref_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_decode_ring_vs_full():
    """Ring (windowed) decode == full-cache decode with window mask."""
    B, H, K, D, W, S = 1, 2, 1, 8, 6, 12
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, 1, H, D))

    full = init_cache.__wrapped__ if hasattr(init_cache, "__wrapped__") \
        else None
    from repro.configs import ARCHS
    cfg = ARCHS["recurrentgemma-2b"].reduced().replace(
        n_heads=H, n_kv_heads=K, d_head=D, local_window=W)
    ring = init_cache(cfg, B, S, ring=True, window=W)
    fullc = init_cache(cfg, B, S, ring=False)

    ks = jax.random.normal(jax.random.PRNGKey(1), (S, B, 1, K, D))
    vs = jax.random.normal(jax.random.PRNGKey(2), (S, B, 1, K, D))
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        ring = update_cache(ring, ks[t], vs[t], pos)
        fullc = update_cache(fullc, ks[t], vs[t], pos)
    pos = jnp.full((B,), S - 1, jnp.int32)
    o_ring = decode_attention(q, ring, pos, window=W)
    o_full = decode_attention(q, fullc, pos, window=W)
    np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                               rtol=1e-5, atol=1e-6)


def test_q_offset_continuation():
    """Chunked attention with q_offset == suffix of the full result."""
    B, T, H, K, D = 1, 32, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, K, D))
    full = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    tail = chunked_attention(q[:, 16:], k, v, causal=True, q_chunk=8,
                             kv_chunk=8, q_offset=16)
    np.testing.assert_allclose(np.asarray(full[:, 16:]), np.asarray(tail),
                               rtol=2e-4, atol=2e-5)


def test_scatter_vs_masked_cache_write():
    """Both cache-write modes must produce identical caches."""
    from repro.models.attention import update_cache, init_cache
    from repro.configs import ARCHS
    cfg = ARCHS["qwen3-8b"].reduced()
    B, S = 2, 8
    c1 = init_cache(cfg, B, S, ring=False)
    c2 = init_cache(cfg, B, S, ring=False)
    for t in range(5):
        kn = jax.random.normal(jax.random.PRNGKey(t), (B, 1, cfg.n_kv_heads,
                                                       cfg.d_head))
        vn = jax.random.normal(jax.random.PRNGKey(t + 99), kn.shape)
        pos = jnp.asarray([t, (t + 2) % S], jnp.int32)
        c1 = update_cache(c1, kn, vn, pos, mode="masked")
        c2 = update_cache(c2, kn, vn, pos, mode="scatter")
    for k in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))
