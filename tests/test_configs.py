"""Registry + config invariants, incl. published param-count checks."""
import pytest

from repro.configs import ARCHS, cells, get_config

# Published (approximate) parameter counts, billions.
PUBLISHED_B = {
    "qwen2.5-3b": 3.1,
    "deepseek-7b": 6.9,
    "gemma3-12b": 12.0,
    "qwen3-8b": 8.2,
    "qwen3-moe-30b-a3b": 30.5,
    "dbrx-132b": 132.0,
    "llava-next-mistral-7b": 7.3,
    "seamless-m4t-large-v2": 2.3,
    "xlstm-1.3b": 1.4,
    "recurrentgemma-2b": 2.7,
}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_count_matches_published(name):
    got = ARCHS[name].param_count() / 1e9
    want = PUBLISHED_B[name]
    assert abs(got - want) / want < 0.15, (name, got, want)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_config_valid(name):
    cfg = ARCHS[name]
    assert cfg.n_layers % cfg.pattern_len == 0
    assert cfg.n_heads % cfg.n_kv_heads == 0
    assert cfg.padded_vocab % 128 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    red = cfg.reduced()
    assert red.n_layers % red.pattern_len == 0
    assert red.param_count() < 50e6


def test_moe_active_params():
    moe = get_config("qwen3-moe-30b-a3b")
    assert moe.active_param_count() < 0.2 * moe.param_count()
    dbrx = get_config("dbrx-132b")
    assert 0.2 < dbrx.active_param_count() / dbrx.param_count() < 0.4


def test_cells_skip_rule():
    cs = cells()
    # every arch has train/prefill/decode
    for name in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert (name, s) in cs
    # long_500k only for sub-quadratic-decode archs
    long_archs = {a for a, s in cs if s == "long_500k"}
    assert long_archs == {"gemma3-12b", "xlstm-1.3b", "recurrentgemma-2b"}
    assert len(cs) == 33


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nope")
