"""End-to-end behaviour: the whole stack wired together.

Corpus -> Sector (replicated chunks) -> locality-aware pipeline ->
Sphere-staged train step -> Sector-replicated checkpoints -> kill a chunk
server mid-run -> repair -> resume -> serve the trained weights.
"""
import numpy as np

from conftest import make_cloud
from repro.configs import ARCHS
from repro.data import DataPipeline, SectorTokenDataset, write_synthetic_corpus
from repro.data.dataset import Cursor
from repro.parallel.sharding import ParallelConfig
from repro.sector.replication import ReplicationDaemon
from repro.serve import SamplerConfig, ServeEngine
from repro.train import SectorCheckpointer, Trainer, TrainerConfig


def test_full_lifecycle(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=64 * 1024,
                                         n_servers=6)
    cfg = ARCHS["qwen2.5-3b"].reduced()
    write_synthetic_corpus(client, "corpus", 400_000, cfg.vocab_size)
    ds = SectorTokenDataset(master, client, "corpus", seq_len=48)
    pcfg = ParallelConfig(mesh=None, remat="none")
    pipe = DataPipeline(ds, batch=4, pcfg=pcfg)
    ckpt = SectorCheckpointer(client, "sys")
    tr = Trainer(cfg, pcfg, TrainerConfig(steps=20, ckpt_every=10,
                                          log_every=5, lr=1e-3), pipe, ckpt)
    hist = tr.run(10)

    # --- kill a storage server mid-run; repair; data keeps flowing ----------
    daemon = ReplicationDaemon(master, client)
    servers[0].kill()
    for t in (0, 35):
        for s in servers:
            if s.alive:
                master.heartbeat(s.server_id, t)
    rep = daemon.tick(35.0)
    assert "s0" in rep["failed"]
    hist = tr.run(10)
    assert master.stats()["under_replicated"] == 0
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1  # still training fine

    # --- serve the trained weights ------------------------------------------
    eng = ServeEngine(cfg, tr.params, max_batch=2, max_len=64,
                      scfg=SamplerConfig(temperature=0.0))
    reqs = [eng.submit([5, 6, 7, 8], max_new=4) for _ in range(3)]
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)

    # --- checkpoints survived and restore ------------------------------------
    assert len(ckpt.steps()) >= 1


def test_pipeline_resume_same_batches(tmp_path):
    master, servers, client = make_cloud(tmp_path, chunk_size=32 * 1024)
    write_synthetic_corpus(client, "c2", 200_000, 1000)
    pcfg = ParallelConfig(mesh=None)

    ds1 = SectorTokenDataset(master, client, "c2", seq_len=32)
    p1 = DataPipeline(ds1, batch=4, pcfg=pcfg)
    it1 = iter(p1)
    first = [np.asarray(next(it1)["inputs"]) for _ in range(5)]
    state = p1.state_dict()   # cursor after 5 batches... (prefetch offset)

    ds2 = SectorTokenDataset(master, client, "c2", seq_len=32)
    p2 = DataPipeline(ds2, batch=4, pcfg=pcfg)
    p2.load_state_dict(state)
    # The cursor is chunk-granular: after resume we re-read from the cursor
    # chunk; batches from that chunk onward must match a fresh run that
    # skipped the same chunks.
    it2 = iter(p2)
    nxt = np.asarray(next(it2)["inputs"])
    assert nxt.shape == (4, 32)


def test_locality_aware_assignment(tmp_path):
    """A rank reads mostly chunks with replicas at its own site."""
    master, servers, client = make_cloud(tmp_path, chunk_size=8 * 1024,
                                         n_servers=12)
    write_synthetic_corpus(client, "c3", 500_000, 1000, replication=3)
    ds = SectorTokenDataset(master, client, "c3", seq_len=32)
    # consume a whole epoch's worth of chunks
    gen = ds.batches(4, Cursor())
    for _ in range(60):
        next(gen)
    # with 12 servers over 6 sites and replication 3, ~half the chunks have
    # a chicago replica; the locality counter must reflect real placement
    frac_with_local = np.mean([
        any(master.servers[s].site == "chicago" for s in m.locations)
        for m in ds.metas])
    assert abs(ds.locality_fraction - frac_with_local) < 0.35
