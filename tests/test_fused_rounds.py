"""Fused worker-axis shuffle rounds: stacked-round parity vs the bytes
reference.

The fused data plane only gets to replace the per-worker dispatch loop
because it agrees with the reference record-for-record: for every bucket,
the same records in the same order (slot-major, input order within a
slot), regrouped onto the same destination workers, with identical
origin-byte accounting.  These tests drive :func:`scatter_round_dispatch`
(both lowerings) and the shard_map twin ``spmd.fused_scatter_round`` over
ragged rounds — empty slots, empty workers, boundary-colliding keys —
against a per-record Python reference, plus a hypothesis property test
over ragged loads when hypothesis is installed.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.records import RecordBatch, StackedBatch
from repro.core.shuffle import (hash_partitioner, range_partitioner,
                                sample_boundaries, scatter_round_dispatch)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is a dev dep; CI installs it
    hypothesis = None


def _ragged_round(loads, rec, seed=0):
    """One slot of random records per entry of ``loads`` (0 = empty)."""
    rng = np.random.default_rng(seed)
    return [[rng.integers(0, 256, rec, dtype=np.uint8).tobytes()
             for _ in range(k)]
            for k in loads]


def _pack(slots, rec, pad_block=8):
    batches = [RecordBatch.from_records(s) if s else RecordBatch.empty(rec)
               for s in slots]
    return StackedBatch.pack(batches, pad_block=pad_block)


def _reference(slots, slot_workers, worker_names, part, n):
    """The bytes backend's answer: per-bucket append order (slot-major,
    input order), bucket b -> worker b % W, buckets ascending within a
    worker, origins as per-bucket per-origin-worker byte counts."""
    W = len(worker_names)
    buckets = [[] for _ in range(n)]
    origins = [{} for _ in range(n)]
    for s, recs in enumerate(slots):
        src = worker_names[slot_workers[s]]
        for r in recs:
            b = part(r, n)
            buckets[b].append(r)
            origins[b][src] = origins[b].get(src, 0) + len(r)
    parts = [b"" for _ in range(W)]
    counts = [0] * W
    for b in range(n):
        parts[b % W] += b"".join(buckets[b])
        counts[b % W] += len(buckets[b])
    return parts, counts, origins


def _assert_round_parity(slots, slot_workers, worker_names, part, n, rec,
                         **kw):
    stacked = _pack(slots, rec)
    rd = scatter_round_dispatch(stacked, part, n,
                                worker_names=worker_names,
                                slot_workers=slot_workers, pad_block=8,
                                **kw)
    assert rd is not None
    result = rd.harvest()
    want_parts, want_counts, want_origins = _reference(
        slots, slot_workers, worker_names, part, n)
    assert result.counts.tolist() == want_counts
    assert result.origins == want_origins
    if result.groups is not None:
        for w0, arr in result.groups:
            g = np.asarray(arr)
            for j in range(g.shape[0]):
                w = w0 + j
                assert g[j, :want_counts[w]].tobytes() == want_parts[w]
        return
    if result.data is None:
        assert sum(want_counts) == 0
        return
    got = np.asarray(result.data)
    for w in range(len(worker_names)):
        assert got[w, :want_counts[w]].tobytes() == want_parts[w]


WORKERS = [f"s{i}" for i in range(4)]


@pytest.mark.parametrize("loads,n_buckets", [
    ([5, 3, 7, 2], 4),            # one slot per worker
    ([9, 0, 4, 0], 6),            # empty slots / empty workers
    ([0, 0, 0, 0], 4),            # fully empty round
    ([30, 1, 1, 1, 17, 8], 3),    # more slots than workers (multi-task)
    ([12], 9),                    # single slot, buckets > records
])
@pytest.mark.parametrize("which", ["hash", "range"])
def test_stacked_round_matches_reference(loads, n_buckets, which):
    rec = 12
    slots = _ragged_round(loads, rec, seed=len(loads) * 7 + n_buckets)
    slot_workers = np.arange(len(loads)) % len(WORKERS)
    slot_workers.sort()           # worker-major ordering contract
    allrec = [r for s in slots for r in s]
    if which == "hash":
        part = hash_partitioner(key_bytes=8)
    else:
        part = range_partitioner(
            sample_boundaries(allrec or [b"\x00" * rec], n_buckets,
                              key_bytes=10))
    _assert_round_parity(slots, slot_workers, WORKERS, part, n_buckets, rec)


def test_vmapped_lowering_matches_segmented():
    """Both lowerings of the stacked round — the CPU segmented-shard
    path and the single vmapped scatter the compiled backends take —
    must produce identical regrouped partitions and origins."""
    rec, n = 16, 5
    slots = _ragged_round([11, 0, 6, 23, 2, 9], rec, seed=3)
    slot_workers = np.sort(np.arange(6) % len(WORKERS))
    part = hash_partitioner(key_bytes=4)
    for lowering in ("segmented", "vmapped"):
        _assert_round_parity(slots, slot_workers, WORKERS, part, n, rec,
                             lowering=lowering, interpret=True)


def test_round_dispatch_is_o1_in_slots():
    """The per-round dispatch count is bounded (shard cap + harvest
    gather), regardless of how many slots the round stacks."""
    rec = 8
    part = hash_partitioner(key_bytes=4)
    disp = []
    for s in (2, 16, 64):
        slots = _ragged_round([3] * s, rec, seed=s)
        stacked = _pack(slots, rec)
        rd = scatter_round_dispatch(stacked, part, 4,
                                    worker_names=WORKERS,
                                    slot_workers=np.sort(
                                        np.arange(s) % len(WORKERS)),
                                    pad_block=8)
        result = rd.harvest()
        disp.append(rd.dispatches + result.dispatches)
    from repro.core.shuffle import _ROUND_MAX_SHARDS
    assert max(disp) <= _ROUND_MAX_SHARDS + 3
    assert disp[-1] <= disp[0] + _ROUND_MAX_SHARDS  # no per-slot growth


def test_grouped_harvest_matches_reference(monkeypatch):
    """Rounds past ``_ROUND_SHARD_ROWS`` split the regroup gather into
    worker-contiguous group takes; shrink the threshold to force that
    path at test scale and check record-for-record parity."""
    from repro.core import shuffle as sh
    monkeypatch.setattr(sh, "_ROUND_SHARD_ROWS", 16)
    rec, n = 12, 8
    slots = _ragged_round([9, 17, 4, 0, 22, 6], rec, seed=13)
    slot_workers = np.sort(np.arange(6) % len(WORKERS))
    part = hash_partitioner(key_bytes=8)
    stacked = _pack(slots, rec)
    rd = sh.scatter_round_dispatch(stacked, part, n,
                                   worker_names=WORKERS,
                                   slot_workers=slot_workers, pad_block=8)
    assert rd is not None
    result = rd.harvest()
    assert result.groups is not None and len(result.groups) > 1
    want_parts, want_counts, want_origins = _reference(
        slots, slot_workers, WORKERS, part, n)
    assert result.counts.tolist() == want_counts
    assert result.origins == want_origins
    for w0, arr in result.groups:
        g = np.asarray(arr)
        for j in range(g.shape[0]):
            w = w0 + j
            assert g[j, :want_counts[w]].tobytes() == want_parts[w]


def test_ineligible_rounds_return_none():
    from repro.core.shuffle import ReducePartitioner
    rec = 8
    stacked = _pack(_ragged_round([4, 4], rec, seed=1), rec)
    # single bucket
    assert scatter_round_dispatch(stacked, hash_partitioner(4), 1,
                                  worker_names=WORKERS) is None
    # reduce shuffle
    assert scatter_round_dispatch(stacked, ReducePartitioner(), 4,
                                  worker_names=WORKERS) is None
    # host-loop partitioner (no scatter_spec)
    assert scatter_round_dispatch(stacked, lambda r, n: 0, 4,
                                  worker_names=WORKERS) is None


@pytest.mark.requires_accelerator
def test_vmapped_round_compiles_on_accelerator():
    """The vmapped stacked scatter must lower through the compiled
    (non-interpret) kernel on a real TPU/GPU backend."""
    rec, n = 16, 4
    slots = _ragged_round([7, 5, 0, 12], rec, seed=5)
    slot_workers = np.arange(4)
    part = range_partitioner(
        sample_boundaries([r for s in slots for r in s], n, key_bytes=10))
    _assert_round_parity(slots, slot_workers, WORKERS, part, n, rec,
                         lowering="vmapped", interpret=False)


def test_mesh_fused_round_matches_host_harvest():
    """``spmd.fused_scatter_round`` on a 1-device mesh: the shard_map +
    all_to_all lowering shares the host harvest's ordering contract
    exactly (multi-device meshes are covered in
    test_spmd_subprocess.py)."""
    from jax.sharding import Mesh
    from repro.core.spmd import fused_scatter_round

    rec, n, W = 12, 6, 4
    slots = _ragged_round([8, 3, 0, 14], rec, seed=9)
    slot_workers = np.arange(4)
    part = hash_partitioner(key_bytes=8)
    stacked = _pack(slots, rec)
    key_spec, bounds = part.scatter_spec(RecordBatch.empty(rec), n)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    parts_dev, counts_dev, hist_sb = fused_scatter_round(
        stacked.data, jnp.asarray(stacked.n_valid, jnp.int32),
        bounds, key_spec=key_spec, n_buckets=n, n_workers=W, mesh=mesh)
    want_parts, want_counts, _ = _reference(slots, slot_workers, WORKERS,
                                            part, n)
    counts = np.asarray(counts_dev)
    assert counts.tolist() == want_counts
    got = np.asarray(parts_dev)
    for w in range(W):
        assert got[w, :want_counts[w]].tobytes() == want_parts[w]
    # the synced histogram is the per-slot truth movement pricing needs
    hist = np.asarray(hist_sb)
    for s, recs in enumerate(slots):
        ref = [part(r, n) for r in recs]
        assert hist[s].tolist() == [ref.count(b) for b in range(n)]


if hypothesis is not None:
    @settings(max_examples=30, deadline=None)
    @given(loads=st.lists(st.integers(0, 40), min_size=1, max_size=10),
           n_buckets=st.integers(2, 9),
           rec_pow=st.integers(2, 4),
           which=st.sampled_from(["hash", "range"]),
           seed=st.integers(0, 2**31 - 1))
    def test_stacked_round_parity_property(loads, n_buckets, rec_pow,
                                           which, seed):
        rec = 1 << rec_pow
        slots = _ragged_round(loads, rec, seed=seed)
        slot_workers = np.sort(np.arange(len(loads)) % len(WORKERS))
        allrec = [r for s in slots for r in s]
        if which == "hash":
            part = hash_partitioner(key_bytes=min(rec, 8))
        else:
            part = range_partitioner(
                sample_boundaries(allrec or [b"\x00" * rec], n_buckets,
                                  key_bytes=min(rec, 10)))
        _assert_round_parity(slots, slot_workers, WORKERS, part,
                             n_buckets, rec)
