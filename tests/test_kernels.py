"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bucket_partition import (bucket_partition,
                                            bucket_partition_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.kmeans_assign import kmeans_assign, kmeans_assign_ref
from repro.kernels.rg_lru_scan import lru_scan_ref, rg_lru_scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,S,H,K,D,bq,bk", [
    (64, 64, 2, 2, 32, 16, 16),    # MHA
    (64, 64, 4, 2, 32, 32, 16),    # GQA
    (32, 96, 2, 1, 64, 16, 32),    # MQA, cross-length
    (50, 70, 2, 2, 32, 16, 16),    # non-multiple lengths (padding path)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_attention_sweep(dtype, T, S, H, K, D, bq, bk, causal, window):
    if causal and S > T:
        S = T  # causal with longer S is ill-posed in this harness
    B = 2
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    ref = attention_ref(qh, kh, vh, causal=causal, window=window) \
        .reshape(B, H, T, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,W,bw", [(1, 16, 32, 16), (2, 33, 64, 32),
                                      (3, 8, 48, 64)])
def test_rg_lru_scan_sweep(B, T, W, bw):
    a = jax.random.uniform(jax.random.PRNGKey(0), (B, T, W), jnp.float32,
                           0.7, 0.999)
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, W)) * 0.1
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, W))
    h, hl = rg_lru_scan(a, b, h0, block_w=bw, interpret=True)
    hr, hlr = lru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("N,D,K,bn", [(100, 8, 4, 32), (513, 16, 7, 128),
                                      (64, 32, 16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_sweep(N, D, K, bn, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (N, D), dtype)
    c = jax.random.normal(jax.random.PRNGKey(4), (K, D), dtype)
    ids, d2 = kmeans_assign(x, c, block_n=bn, interpret=True)
    idr, d2r = kmeans_assign_ref(x, c)
    assert (np.asarray(ids) == np.asarray(idr)).mean() > 0.99  # dtype ties
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("N,nb,bn", [(100, 4, 32), (2048, 16, 512),
                                     (777, 8, 256)])
def test_bucket_partition_sweep(N, nb, bn):
    keys = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, 1 << 30,
                              dtype=jnp.uint32)
    bounds = jnp.sort(jax.random.randint(jax.random.PRNGKey(6), (nb - 1,),
                                         0, 1 << 30, dtype=jnp.uint32))
    ids, hist = bucket_partition(keys, bounds, n_buckets=nb, block_n=bn,
                                 interpret=True)
    idr, histr = bucket_partition_ref(keys, bounds, nb)
    assert (np.asarray(ids) == np.asarray(idr)).all()
    assert (np.asarray(hist) == np.asarray(histr)).all()
    assert int(hist.sum()) == N


@pytest.mark.parametrize("k", [2, 3, 4])
@pytest.mark.parametrize("N,nb,bn", [(100, 4, 32), (777, 8, 256)])
def test_bucket_partition_multiword_sweep(N, nb, bn, k):
    """[N, k] key rows vs [nb-1, k] boundary rows: the kernel's word-by-
    word lexicographic compare against the big-int oracle. Low word
    entropy (values 0..3) forces constant prefix ties so later words and
    the strict-< rule actually decide buckets."""
    keys = jax.random.randint(jax.random.PRNGKey(7 + k), (N, k), 0, 4,
                              dtype=jnp.uint32)
    bounds = jax.random.randint(jax.random.PRNGKey(8 + k), (nb - 1, k), 0, 4,
                                dtype=jnp.uint32)
    order = np.lexsort(np.asarray(bounds).T[::-1])
    bounds = jnp.asarray(np.asarray(bounds)[order])
    ids, hist = bucket_partition(keys, bounds, n_buckets=nb, block_n=bn,
                                 interpret=True)
    idr, histr = bucket_partition_ref(keys, bounds, nb)
    assert (np.asarray(ids) == np.asarray(idr)).all()
    assert (np.asarray(hist) == np.asarray(histr)).all()
    assert int(hist.sum()) == N


def test_bucket_partition_equal_keys_are_strict():
    """bucket id = #{bounds < key} is STRICT: a key equal to a boundary
    belongs to the bucket below it, in both the single- and multi-word
    kernels and the oracle."""
    bounds = jnp.array([10, 20], jnp.uint32)
    keys = jnp.array([10, 20, 9, 11, 21], jnp.uint32)
    ids, hist = bucket_partition(keys, bounds, n_buckets=3, block_n=8,
                                 interpret=True)
    assert np.asarray(ids).tolist() == [0, 1, 0, 1, 2]
    idr, _ = bucket_partition_ref(keys, bounds, 3)
    assert np.asarray(idr).tolist() == [0, 1, 0, 1, 2]
    bounds2 = jnp.array([[1, 10], [1, 20]], jnp.uint32)
    keys2 = jnp.array([[1, 10], [1, 20], [0, 99], [1, 11], [2, 0]],
                      jnp.uint32)
    ids2, _ = bucket_partition(keys2, bounds2, n_buckets=3, block_n=8,
                               interpret=True)
    assert np.asarray(ids2).tolist() == [0, 1, 0, 1, 2]


def test_bucket_partition_word_count_mismatch():
    with pytest.raises(ValueError, match="words per row"):
        bucket_partition(jnp.zeros((4, 2), jnp.uint32),
                         jnp.zeros((3, 3), jnp.uint32), n_buckets=4,
                         interpret=True)
